//! The cluster subsystem: sharded multi-engine serving with a global
//! thermal/power arbiter and a fault-injecting supervisor.
//!
//! ```text
//!                       ┌────────────────────────────┐
//!   traffic source ──▶  │ coordinator (main thread)  │
//!                       │  consistent-hash router +  │
//!                       │  coalescing + autoscaler + │
//!                       │  supervisor + arbiter      │
//!                       └──────┬──────┬──────┬───────┘
//!            EpochPacket       │      │      │      ▲
//!            {reqs, cap, cmd}  ▼      ▼      ▼      │ EpochReport
//!                       ┌──────────┐ ┌───┐ ┌───┐    │ {peak_temp,
//!                       │ shard 0  │ │ 1 │ │ N │    │  power, ids}
//!                       │ (thread) │ │   │ │   │ ───┘
//!                       └──────────┘ └───┘ └───┘
//! ```
//!
//! One serving [`Server`] (engine + scheduler) per shard — one shard per
//! interposer — on its own worker thread. The coordinator routes each
//! epoch's arrivals by model fingerprint (consistent hashing keeps a
//! model's weights and cached profiles on one shard), coalesces
//! same-model requests into batches, tags each batch with a global
//! request id, and pushes one [`EpochPacket`] per shard through a bounded
//! mailbox. At the epoch barrier it collects exactly one [`EpochReport`]
//! per shard, settles the request-id ledger, reslices the power budget
//! headroom-weighted over the *alive* shards (hot shards lose budget to
//! cool ones, dead shards lose their whole slice), and autoscales the
//! active ring.
//!
//! ## Fault injection and supervision
//!
//! With a [`FaultPlan`] configured, a supervisor inside the coordinator
//! compiles the plan into per-shard lifecycles and applies them at epoch
//! barriers: crashes kill a shard's engine (the supervisor removes it
//! from the ring, fails its in-flight requests over to the survivors by
//! re-routing them on the shrunken ring, and restarts it from a
//! checkpoint after its down window); hangs freeze a shard — tolerated
//! for [`SUPERVISOR_PATIENCE_EPOCHS`] epochs, then escalated to a
//! crash + restart; chiplet trips, mailbox drops/delays, and report
//! losses perturb the data and telemetry planes. The request-id ledger
//! is transactional: a request id is settled exactly once (done or
//! dropped), so failover retries never double-complete —
//! at-most-once accounting. Degradation counters ([`FaultStats`]) join
//! the merged report (and its digest) only when a plan is active, so
//! fault-free digests are byte-identical to a build without this module.
//!
//! ## Determinism model
//!
//! Real threads, reproducible results: shards advance in *epoch
//! lockstep*. Within an epoch a shard is a deterministic function of its
//! seed and its packet sequence; the packet sequence is a deterministic
//! function of the source seed, the fault plan, and the (deterministic)
//! cap/autoscale history; the coordinator sorts reports by shard id
//! before rebalancing. Thread interleaving can reorder report arrival
//! but never their epoch content, so `thermos serve --shards 4 --seed S
//! [--chaos C]` twice produces byte-identical merged reports. The only
//! interleaving-dependent values — profile-cache hit/miss splits — are
//! deliberately kept out of the digested JSON.
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

pub mod arbiter;
pub mod autoscale;
pub mod router;
pub mod shard;

pub use arbiter::{package_tdp_w, Arbiter, ArbiterConfig};
pub use autoscale::{AutoscaleConfig, Autoscaler};
pub use router::{ClusterRouter, HashRing, RouteStats};
pub use shard::{EpochPacket, EpochReport, ShardParams, ShardResult, ShardSchedSpec};

pub use crate::fault::{ClusterError, FaultPlan};

use crate::arch::Arch;
use crate::fault::{FaultKind, FaultStats, ShardCmd, SUPERVISOR_PATIENCE_EPOCHS};
use crate::noi::NoiTopology;
use crate::sched::thermos::PREF_BALANCED;
use crate::serve::ingest::TrafficSource;
use crate::serve::server::{ServeConfig, Server};
use crate::serve::telemetry::{digest64, TelemetryHub};
use crate::serve::ServeRequest;
use crate::sim::{ProfileCache, SimConfig};
use crate::thermal::ThermalParams;
use crate::util::json::Json;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::mpsc;

#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Worker shards (engines). The autoscaler varies the *active* subset
    /// of the ring; workers always step so drained shards stay warm.
    pub shards: usize,
    /// Telemetry epoch: router/arbiter barrier interval (s).
    pub epoch_s: f64,
    /// Serving horizon (s).
    pub duration_s: f64,
    /// Post-horizon drain bound per shard (s).
    pub drain_max_s: f64,
    /// Total package power budget (W); `None` derives
    /// `budget_frac × TDP × shards` from the architecture.
    pub power_budget_w: Option<f64>,
    pub budget_frac: f64,
    /// Bounded mailbox depth per shard.
    pub mailbox_cap: usize,
    /// Virtual nodes per shard on the hash ring.
    pub vnodes: usize,
    /// Coalesce same-(model, tenant) requests within an epoch batch.
    pub coalesce: bool,
    pub max_batch_images: u64,
    pub noi: NoiTopology,
    /// Per-shard serve/engine knobs. Shard `i` runs with
    /// `seed + i · 0x9e37` (distinct workload state per shard,
    /// deterministic overall); snapshots are cluster-level, so per-shard
    /// snapshotting is forced off.
    pub serve: ServeConfig,
    pub sched: ShardSchedSpec,
    pub autoscale: Option<AutoscaleConfig>,
    /// Per-shard replay logs: `<base>.shard<i>.jsonl`.
    pub record_base: Option<String>,
    /// Deterministic fault schedule; `None` disables the whole fault
    /// plane (and keeps merged digests identical to pre-fault builds).
    pub faults: Option<FaultPlan>,
}

impl Default for ClusterConfig {
    fn default() -> ClusterConfig {
        ClusterConfig {
            shards: 2,
            epoch_s: 1.0,
            duration_s: 120.0,
            drain_max_s: 30.0,
            power_budget_w: None,
            budget_frac: 0.75,
            mailbox_cap: 2,
            vnodes: 16,
            coalesce: true,
            max_batch_images: 8_000,
            noi: NoiTopology::Mesh,
            serve: ServeConfig::default(),
            sched: ShardSchedSpec::Thermos { theta: None, fallback: PREF_BALANCED },
            autoscale: None,
            record_base: None,
            faults: None,
        }
    }
}

/// Fleet-wide output: merged report JSON + digest, per-epoch snapshots,
/// and profile-cache stats (observability only — interleaving-dependent,
/// never part of the digested JSON).
#[derive(Debug)]
pub struct ClusterReport {
    pub json: Json,
    pub digest: String,
    pub snapshots: Vec<Json>,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_entries: usize,
}

/// The fault supervisor: compiles a [`FaultPlan`] into per-shard
/// lifecycles and owns the request-id ledger that makes failover
/// at-most-once. Lives inside the coordinator — every decision happens
/// at an epoch barrier, on one thread, in shard-id order, so the fault
/// schedule perturbs the run deterministically.
struct Supervisor {
    n: usize,
    /// Per-shard lifecycle directive by epoch (absent ⇒ `Run`).
    schedule: Vec<BTreeMap<usize, ShardCmd>>,
    /// Epochs that begin a scheduled fault (for `faults_injected`).
    fault_starts: Vec<BTreeSet<usize>>,
    /// Epochs at which a hung shard resumes and rejoins the ring.
    rejoin: Vec<BTreeSet<usize>>,
    /// Chiplet trip transitions per shard per epoch: `(chiplet, offline)`.
    trips: Vec<BTreeMap<usize, Vec<(usize, bool)>>>,
    /// `(epoch, shard)` whose request batch is lost in transit.
    drops: BTreeSet<(usize, usize)>,
    /// `(epoch, shard)` → delay in epochs for that batch.
    delays: BTreeMap<(usize, usize), usize>,
    /// `(epoch, shard)` whose epoch report is lost before the arbiter.
    losses: BTreeSet<(usize, usize)>,
    /// Liveness as of the last applied directive.
    alive: Vec<bool>,
    /// Global request id → (owning shard, request). BTreeMap so failover
    /// re-routes in ascending gid order — deterministic retry order.
    inflight: BTreeMap<u64, (usize, ServeRequest)>,
    /// Delivery epoch → delayed batches `(original shard, tagged reqs)`.
    delayed: BTreeMap<usize, Vec<(usize, Vec<(u64, ServeRequest)>)>>,
    next_gid: u64,
    /// Ledger tracking is only paid for when a plan is active.
    track: bool,
    stats: FaultStats,
}

impl Supervisor {
    fn new(plan: &FaultPlan, n: usize, total_epochs: usize, track: bool) -> Supervisor {
        let mut sup = Supervisor {
            n,
            schedule: vec![BTreeMap::new(); n],
            fault_starts: vec![BTreeSet::new(); n],
            rejoin: vec![BTreeSet::new(); n],
            trips: vec![BTreeMap::new(); n],
            drops: BTreeSet::new(),
            delays: BTreeMap::new(),
            losses: BTreeSet::new(),
            alive: vec![true; n],
            inflight: BTreeMap::new(),
            delayed: BTreeMap::new(),
            next_gid: 0,
            track,
            stats: FaultStats::default(),
        };
        for ev in &plan.events {
            let s = ev.shard;
            if s >= n || ev.epoch >= total_epochs {
                continue;
            }
            match &ev.kind {
                FaultKind::ChipletTrip { chiplet, epochs } => {
                    let d = (*epochs).max(1);
                    sup.trips[s].entry(ev.epoch).or_default().push((*chiplet, true));
                    sup.trips[s].entry(ev.epoch + d).or_default().push((*chiplet, false));
                }
                FaultKind::ShardCrash { down_epochs } => {
                    let d = (*down_epochs).max(1);
                    // First-wins: overlapping lifecycles on one shard are
                    // dropped wholesale, never half-applied.
                    if (ev.epoch..=ev.epoch + d).any(|e| sup.schedule[s].contains_key(&e)) {
                        continue;
                    }
                    sup.schedule[s].insert(ev.epoch, ShardCmd::Crash);
                    for e in ev.epoch + 1..ev.epoch + d {
                        sup.schedule[s].insert(e, ShardCmd::Down);
                    }
                    if ev.epoch + d < total_epochs {
                        sup.schedule[s].insert(ev.epoch + d, ShardCmd::Restart);
                    }
                    sup.fault_starts[s].insert(ev.epoch);
                }
                FaultKind::ShardHang { epochs } => {
                    let k = (*epochs).max(1);
                    if k <= SUPERVISOR_PATIENCE_EPOCHS {
                        if (ev.epoch..ev.epoch + k).any(|e| sup.schedule[s].contains_key(&e)) {
                            continue;
                        }
                        for e in ev.epoch..ev.epoch + k {
                            sup.schedule[s].insert(e, ShardCmd::Hang);
                        }
                        if ev.epoch + k < total_epochs {
                            sup.rejoin[s].insert(ev.epoch + k);
                        }
                    } else {
                        // Patience exhausted: two hung epochs, then the
                        // supervisor escalates to a crash + restart.
                        if (ev.epoch..=ev.epoch + 3).any(|e| sup.schedule[s].contains_key(&e)) {
                            continue;
                        }
                        sup.schedule[s].insert(ev.epoch, ShardCmd::Hang);
                        sup.schedule[s].insert(ev.epoch + 1, ShardCmd::Hang);
                        sup.schedule[s].insert(ev.epoch + 2, ShardCmd::Crash);
                        if ev.epoch + 3 < total_epochs {
                            sup.schedule[s].insert(ev.epoch + 3, ShardCmd::Restart);
                        }
                    }
                    sup.fault_starts[s].insert(ev.epoch);
                }
                FaultKind::MailboxDrop => {
                    sup.drops.insert((ev.epoch, s));
                }
                FaultKind::MailboxDelay { epochs } => {
                    sup.delays.insert((ev.epoch, s), (*epochs).max(1));
                }
                FaultKind::ReportLoss => {
                    sup.losses.insert((ev.epoch, s));
                }
            }
        }
        sup
    }

    /// Remove an entire unapplied lifecycle starting at `start` (its cmds
    /// occupy consecutive epochs) plus its rejoin mark and start marker.
    fn unschedule_lifecycle(&mut self, s: usize, start: usize) {
        let mut e = start;
        while self.schedule[s].remove(&e).is_some() {
            e += 1;
        }
        self.rejoin[s].remove(&e);
        self.fault_starts[s].remove(&start);
    }

    /// Gids currently parked in the delayed-delivery stash; these are
    /// skipped by crash failover (the delivery path re-routes them).
    fn delayed_gids(&self) -> BTreeSet<u64> {
        self.delayed
            .values()
            .flatten()
            .flat_map(|(_, reqs)| reqs.iter().map(|&(g, _)| g))
            .collect()
    }

    /// Re-route every in-flight request of dead shard `s` onto the
    /// current (already shrunken) ring, keeping its gid — retried, never
    /// duplicated. Requests with no surviving home are dropped for good.
    fn failover(
        &mut self,
        s: usize,
        router: &ClusterRouter,
        extras: &mut [Vec<(u64, ServeRequest)>],
    ) {
        self.stats.failovers += 1;
        extras[s].clear();
        let parked = self.delayed_gids();
        let mine: Vec<(u64, ServeRequest)> = self
            .inflight
            .iter()
            .filter(|(g, (sh, _))| *sh == s && !parked.contains(g))
            .map(|(&g, (_, r))| (g, r.clone()))
            .collect();
        for (g, r) in mine {
            match router.reroute(&r) {
                Some(t) => {
                    self.inflight.insert(g, (t, r.clone()));
                    extras[t].push((g, r));
                    self.stats.retries += 1;
                }
                None => {
                    self.inflight.remove(&g);
                    self.stats.dropped_requests += 1;
                }
            }
        }
    }

    /// Apply this epoch's directives: ring membership, failover, trips,
    /// and delayed deliveries. Returns per-shard `(cmd, trips, extra
    /// requests)` for the packet build.
    #[allow(clippy::type_complexity)]
    fn directives(
        &mut self,
        epoch: usize,
        router: &mut ClusterRouter,
    ) -> (Vec<ShardCmd>, Vec<Vec<(usize, bool)>>, Vec<Vec<(u64, ServeRequest)>>) {
        let n = self.n;
        let mut cmds = vec![ShardCmd::Run; n];
        let mut trips: Vec<Vec<(usize, bool)>> = vec![Vec::new(); n];
        let mut extras: Vec<Vec<(u64, ServeRequest)>> = vec![Vec::new(); n];
        for s in 0..n {
            let mut cmd = self.schedule[s].get(&epoch).copied().unwrap_or(ShardCmd::Run);
            // A fault that would empty the ring is skipped outright (and
            // not counted): scale-to-zero is rejected, never a panic.
            if matches!(cmd, ShardCmd::Crash | ShardCmd::Hang)
                && self.alive[s]
                && router.ring.contains(s)
                && router.ring.num_shards() == 1
            {
                self.unschedule_lifecycle(s, epoch);
                cmd = ShardCmd::Run;
            }
            match cmd {
                ShardCmd::Crash => {
                    if self.fault_starts[s].contains(&epoch) {
                        self.stats.faults_injected += 1;
                    }
                    router.ring.remove(s);
                    self.alive[s] = false;
                    self.failover(s, router, &mut extras);
                }
                ShardCmd::Hang => {
                    if self.alive[s] {
                        if self.fault_starts[s].contains(&epoch) {
                            self.stats.faults_injected += 1;
                        }
                        router.ring.remove(s);
                        self.alive[s] = false;
                    }
                }
                ShardCmd::Down => {}
                ShardCmd::Restart => {
                    self.alive[s] = true;
                    router.ring.add(s);
                    self.stats.restarts += 1;
                }
                ShardCmd::Run => {
                    if self.rejoin[s].remove(&epoch) {
                        self.alive[s] = true;
                        router.ring.add(s);
                    }
                }
            }
            cmds[s] = cmd;
            // Trips ride the packet; shards that are dead this epoch
            // ignore them (a fresh engine boots with every chiplet
            // online, so a stale trip-off is a harmless no-op).
            if let Some(t) = self.trips[s].remove(&epoch) {
                if !matches!(cmds[s], ShardCmd::Crash | ShardCmd::Down) {
                    for &(_, on) in &t {
                        if on {
                            self.stats.chiplet_trips += 1;
                            self.stats.faults_injected += 1;
                        }
                    }
                    trips[s] = t;
                }
            }
        }
        // Delayed batches come due: deliver to the original shard if it
        // is serving, otherwise re-route them like failover retries.
        if let Some(batches) = self.delayed.remove(&epoch) {
            for (orig, reqs) in batches {
                if self.alive[orig] && router.ring.contains(orig) {
                    extras[orig].extend(reqs);
                } else {
                    for (g, r) in reqs {
                        match router.reroute(&r) {
                            Some(t) => {
                                if self.track {
                                    self.inflight.insert(g, (t, r.clone()));
                                }
                                extras[t].push((g, r));
                                self.stats.retries += 1;
                            }
                            None => {
                                self.inflight.remove(&g);
                                self.stats.dropped_requests += 1;
                            }
                        }
                    }
                }
            }
        }
        self.stats.downtime_epochs += self.alive.iter().filter(|&&a| !a).count() as u64;
        (cmds, trips, extras)
    }

    /// Tag a routed batch with fresh global request ids (and track them
    /// in the ledger when a plan is active).
    fn assign_gids(&mut self, shard: usize, batch: Vec<ServeRequest>) -> Vec<(u64, ServeRequest)> {
        batch
            .into_iter()
            .map(|r| {
                let g = self.next_gid;
                self.next_gid += 1;
                if self.track {
                    self.inflight.insert(g, (shard, r.clone()));
                }
                (g, r)
            })
            .collect()
    }

    /// Apply mailbox faults to this shard's freshly routed batch.
    fn intercept(&mut self, epoch: usize, shard: usize, reqs: &mut Vec<(u64, ServeRequest)>) {
        if self.drops.remove(&(epoch, shard)) {
            self.stats.faults_injected += 1;
            self.stats.dropped_requests += reqs.len() as u64;
            for (g, _) in reqs.drain(..) {
                self.inflight.remove(&g);
            }
        }
        if let Some(k) = self.delays.remove(&(epoch, shard)) {
            self.stats.faults_injected += 1;
            if !reqs.is_empty() {
                self.delayed.entry(epoch + k).or_default().push((shard, std::mem::take(reqs)));
            }
        }
    }

    /// True when this shard's epoch report is scheduled to be lost.
    fn lose_report(&mut self, epoch: usize, shard: usize) -> bool {
        if self.losses.remove(&(epoch, shard)) {
            self.stats.reports_lost += 1;
            self.stats.faults_injected += 1;
            true
        } else {
            false
        }
    }

    /// Close ledger entries: each id settles exactly once (done *or*
    /// dropped), even when the epoch's telemetry report was lost.
    fn settle(&mut self, done_ids: &[u64], dropped_ids: &[u64]) {
        if !self.track {
            return;
        }
        for g in done_ids.iter().chain(dropped_ids) {
            self.inflight.remove(g);
        }
    }
}

/// Last-known substitute used on the telemetry plane before a shard's
/// first report (only reachable when a report-loss fault hits epoch 0).
fn baseline_report(shard: usize) -> EpochReport {
    EpochReport {
        shard,
        epoch: 0,
        peak_temp_k: 0.0,
        power_w: 0.0,
        completed: 0,
        queue_depth: 0,
        fifo_depth: 0,
        throttled: false,
        cap_gated: false,
        alive: true,
        done_ids: Vec::new(),
        dropped_ids: Vec::new(),
    }
}

fn epoch_snapshot_json(
    epoch: usize,
    t_s: f64,
    reports: &[EpochReport],
    caps_w: &[f64],
    active: usize,
    down_shards: Option<usize>,
) -> Json {
    let mut pairs = vec![
        ("epoch", Json::Num(epoch as f64)),
        ("t_s", Json::Num(t_s)),
        ("active_shards", Json::Num(active as f64)),
        ("completed", Json::Num(reports.iter().map(|r| r.completed).sum::<u64>() as f64)),
        (
            "queue_depth",
            Json::Num(reports.iter().map(|r| r.queue_depth).sum::<usize>() as f64),
        ),
        (
            "peak_temp_k",
            Json::Num(reports.iter().map(|r| r.peak_temp_k).fold(0.0, f64::max)),
        ),
        ("power_w", Json::Num(reports.iter().map(|r| r.power_w).sum::<f64>())),
        ("caps_w", Json::arr_f64(caps_w)),
        (
            "throttled_shards",
            Json::Num(reports.iter().filter(|r| r.throttled).count() as f64),
        ),
        (
            "cap_gated_shards",
            Json::Num(reports.iter().filter(|r| r.cap_gated).count() as f64),
        ),
    ];
    if let Some(d) = down_shards {
        pairs.push(("down_shards", Json::Num(d as f64)));
    }
    Json::obj(pairs)
}

/// Run a sharded serving cluster to its horizon and merge the per-shard
/// telemetry into one fleet-wide report. See the module docs for the
/// architecture, the fault model, and the determinism model.
pub fn run_cluster(
    cfg: ClusterConfig,
    mut source: Box<dyn TrafficSource>,
) -> Result<ClusterReport, ClusterError> {
    assert!(cfg.shards >= 1, "cluster needs at least one shard");
    let n = cfg.shards;
    let ref_arch = Arch::paper_heterogeneous(cfg.noi);
    let budget_w = cfg
        .power_budget_w
        .unwrap_or_else(|| package_tdp_w(&ref_arch) * cfg.budget_frac * n as f64);
    let dt = ThermalParams::default().dt_s;
    let epoch_steps = ((cfg.epoch_s / dt).round() as usize).max(1);
    let total_epochs = ((cfg.duration_s / cfg.epoch_s).ceil() as usize).max(1);

    let cache = ProfileCache::new();
    let source_name = source.name().to_string();
    let scheduler_name = cfg.sched.name();
    let faults_on = cfg.faults.is_some();
    let plan = cfg.faults.clone().unwrap_or_default();
    let mut sup = Supervisor::new(&plan, n, total_epochs, faults_on);

    // Channels: bounded per-shard mailboxes in, unbounded telemetry out.
    let mut packet_txs: Vec<mpsc::SyncSender<EpochPacket>> = Vec::with_capacity(n);
    let mut packet_rxs: Vec<mpsc::Receiver<EpochPacket>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = mpsc::sync_channel(cfg.mailbox_cap.max(1));
        packet_txs.push(tx);
        packet_rxs.push(rx);
    }
    let (report_tx, report_rx) = mpsc::channel::<EpochReport>();
    let (result_tx, result_rx) = mpsc::channel::<ShardResult>();

    let mut snapshots: Vec<Json> = Vec::new();
    let mut stats = RouteStats { routed: vec![0; n], ..Default::default() };
    let mut autoscaler = cfg.autoscale.clone().map(Autoscaler::new);
    let initial_active = match &autoscaler {
        Some(a) => a.cfg.min_shards.clamp(1, n),
        None => n,
    };
    let mut router = ClusterRouter::new(
        &(0..initial_active).collect::<Vec<usize>>(),
        cfg.vnodes,
        cfg.coalesce,
        cfg.max_batch_images,
    );
    let mut arbiter = Arbiter::new(ArbiterConfig::new(budget_w), n);
    let mut last_reports: Vec<EpochReport> = (0..n).map(baseline_report).collect();

    let (mut results, run_err) = std::thread::scope(|scope| {
        for (id, rx) in packet_rxs.into_iter().enumerate() {
            let params = ShardParams {
                id,
                noi: cfg.noi,
                serve: ServeConfig {
                    snapshot_every_s: 0.0,
                    sim: SimConfig {
                        seed: cfg.serve.sim.seed.wrapping_add(id as u64 * 0x9e37),
                        ..cfg.serve.sim.clone()
                    },
                    ..cfg.serve.clone()
                },
                sched: cfg.sched.clone(),
                epoch_steps,
                drain_max_s: cfg.drain_max_s,
                record_path: cfg.record_base.as_ref().map(|b| format!("{b}.shard{id}.jsonl")),
            };
            let cache = cache.clone();
            let report_tx = report_tx.clone();
            let result_tx = result_tx.clone();
            scope.spawn(move || shard::run_shard(params, cache, rx, report_tx, result_tx));
        }
        drop(report_tx);
        drop(result_tx);

        // Coordinator: supervise, route, barrier, rebalance, autoscale.
        let mut run_err: Option<ClusterError> = None;
        let mut caps_w = vec![budget_w / n as f64; n];
        'epochs: for epoch in 0..total_epochs {
            let (cmds, mut trip_sets, mut extras) = sup.directives(epoch, &mut router);
            if router.ring.is_empty() {
                run_err = Some(ClusterError::NoActiveShards);
                break 'epochs;
            }
            let t_end = (epoch as f64 + 1.0) * cfg.epoch_s;
            let arrivals = source.arrivals_until(t_end);
            let offered_rate = arrivals.len() as f64 / cfg.epoch_s;
            let mut batches = router.route_epoch(arrivals, n, &mut stats);
            let last = epoch + 1 == total_epochs;
            for (id, tx) in packet_txs.iter().enumerate() {
                let mut reqs = sup.assign_gids(id, std::mem::take(&mut batches[id]));
                sup.intercept(epoch, id, &mut reqs);
                reqs.append(&mut extras[id]);
                let pkt = EpochPacket {
                    reqs,
                    cap_w: caps_w[id],
                    last,
                    cmd: cmds[id],
                    trips: std::mem::take(&mut trip_sets[id]),
                };
                match tx.try_send(pkt) {
                    Ok(()) => {}
                    // The lockstep protocol keeps at most one packet in
                    // flight, but fall back to a blocking send for safety.
                    Err(mpsc::TrySendError::Full(pkt)) => {
                        let _ = tx.send(pkt);
                    }
                    Err(mpsc::TrySendError::Disconnected(_)) => {}
                }
            }
            // Barrier: exactly one report per shard, dead or alive.
            let mut reports: Vec<EpochReport> = Vec::with_capacity(n);
            for _ in 0..n {
                match report_rx.recv() {
                    Ok(r) => reports.push(r),
                    Err(_) => {
                        run_err = Some(ClusterError::ShardFailed(format!(
                            "epoch {epoch}: a shard worker exited before the barrier"
                        )));
                        break 'epochs;
                    }
                }
            }
            reports.sort_by_key(|r| r.shard);
            // The id ledger settles unconditionally — report loss only
            // blinds the telemetry plane, never the accounting plane.
            for r in &reports {
                sup.settle(&r.done_ids, &r.dropped_ids);
            }
            let mut alive_mask = vec![true; n];
            for r in reports.iter_mut() {
                let s = r.shard;
                alive_mask[s] = r.alive;
                if sup.lose_report(epoch, s) {
                    let mut sub = last_reports[s].clone();
                    sub.epoch = epoch;
                    alive_mask[s] = sub.alive;
                    *r = sub;
                } else {
                    let mut known = r.clone();
                    known.done_ids = Vec::new();
                    known.dropped_ids = Vec::new();
                    last_reports[s] = known;
                }
            }
            let peaks: Vec<f64> = reports.iter().map(|r| r.peak_temp_k).collect();
            caps_w = arbiter.rebalance_masked(&peaks, &alive_mask);
            if let Some(a) = autoscaler.as_mut() {
                let active = router.ring.num_shards();
                let target = a.target(offered_rate, active).clamp(1, n);
                while router.ring.num_shards() < target {
                    match (0..n).find(|&i| !router.ring.contains(i) && sup.alive[i]) {
                        Some(i) => router.ring.add(i),
                        None => break,
                    }
                }
                // Scale-to-zero is rejected: the last shard never drains.
                while router.ring.num_shards() > target && router.ring.num_shards() > 1 {
                    match router.ring.shards().last().copied() {
                        Some(s) => router.ring.remove(s),
                        None => break,
                    }
                }
            }
            snapshots.push(epoch_snapshot_json(
                epoch,
                t_end,
                &reports,
                &caps_w,
                router.ring.num_shards(),
                faults_on.then(|| alive_mask.iter().filter(|&&a| !a).count()),
            ));
        }
        drop(packet_txs);

        let mut results: Vec<ShardResult> = Vec::with_capacity(n);
        while let Ok(r) = result_rx.recv() {
            results.push(r);
        }
        (results, run_err)
    });
    if let Some(e) = run_err {
        return Err(e);
    }
    results.sort_by_key(|r| r.id);
    // Close the ledger with ids settled during the post-horizon drain.
    for r in &results {
        sup.settle(&r.done_ids, &r.dropped_ids);
    }

    // Deterministic merge: fixed shard-id order.
    let mut merged = TelemetryHub::new();
    for r in &results {
        merged.merge(&r.hub);
    }
    let (offered_batches, admitted, rejected, shed, completed) = merged.totals();
    let num = |j: &Json, k: &str| j.get(k).as_f64().unwrap_or(0.0);
    let duration_s =
        results.iter().map(|r| num(&r.report.json, "duration_s")).fold(0.0, f64::max);
    let shards_detail: Vec<Json> = results
        .iter()
        .map(|r| {
            let j = &r.report.json;
            Json::obj(vec![
                ("id", Json::Num(r.id as f64)),
                ("offered", j.get("offered").clone()),
                ("rejected", j.get("rejected").clone()),
                ("shed", j.get("shed").clone()),
                ("shed_pressure", j.get("shed_pressure").clone()),
                ("completed", j.get("completed").clone()),
                ("images_done", j.get("images_done").clone()),
                ("max_temp_k", j.get("max_temp_k").clone()),
                ("throttle_events", j.get("throttle_events").clone()),
                ("cap_gated_steps", j.get("cap_gated_steps").clone()),
                ("system_energy_j", j.get("system_energy_j").clone()),
                ("host_stalls", j.get("host_stalls").clone()),
                ("duration_s", j.get("duration_s").clone()),
            ])
        })
        .collect();
    let autoscale_json = match &autoscaler {
        Some(a) => Json::obj(vec![
            ("scale_ups", Json::Num(a.scale_ups as f64)),
            ("scale_downs", Json::Num(a.scale_downs as f64)),
            ("active_final", Json::Num(router.ring.num_shards() as f64)),
        ]),
        None => Json::Null,
    };
    let mut pairs = vec![
        ("scheduler", Json::Str(scheduler_name.to_string())),
        ("source", Json::Str(source_name)),
        ("seed", Json::Num(cfg.serve.sim.seed as f64)),
        ("shards", Json::Num(n as f64)),
        ("epochs", Json::Num(total_epochs as f64)),
        ("epoch_s", Json::Num(cfg.epoch_s)),
        ("duration_s", Json::Num(duration_s)),
        ("offered", Json::Num(stats.offered as f64)),
        ("coalesced_requests", Json::Num(stats.coalesced as f64)),
        ("offered_batches", Json::Num(offered_batches as f64)),
        (
            "routed_per_shard",
            Json::Arr(stats.routed.iter().map(|&x| Json::Num(x as f64)).collect()),
        ),
        ("admitted", Json::Num(admitted as f64)),
        ("rejected", Json::Num(rejected as f64)),
        ("shed", Json::Num(shed as f64)),
        ("shed_pressure", Json::Num(merged.shed_pressure_total() as f64)),
        ("completed", Json::Num(completed as f64)),
        ("images_done", Json::Num(merged.images_done_total() as f64)),
        ("throughput_jobs_s", Json::Num(completed as f64 / duration_s.max(1e-9))),
        (
            "throughput_images_s",
            Json::Num(merged.images_done_total() as f64 / duration_s.max(1e-9)),
        ),
        ("latency_e2e_s", merged.e2e_all.to_json()),
        ("latency_exec_s", merged.exec_all.to_json()),
        ("energy_j", merged.energy_all.to_json()),
        ("tenants", merged.tenants_json()),
        (
            "max_temp_k",
            Json::Num(
                results.iter().map(|r| num(&r.report.json, "max_temp_k")).fold(0.0, f64::max),
            ),
        ),
        (
            "system_energy_j",
            Json::Num(results.iter().map(|r| num(&r.report.json, "system_energy_j")).sum::<f64>()),
        ),
        (
            "throttle_events",
            Json::Num(results.iter().map(|r| num(&r.report.json, "throttle_events")).sum::<f64>()),
        ),
        (
            "cap_gated_steps",
            Json::Num(results.iter().map(|r| num(&r.report.json, "cap_gated_steps")).sum::<f64>()),
        ),
        ("power_budget_w", Json::Num(budget_w)),
        (
            "arbiter",
            Json::obj(vec![
                ("budget_w", Json::Num(budget_w)),
                ("rebalances", Json::Num(arbiter.rebalances as f64)),
                ("epochs", Json::Num(arbiter.epochs as f64)),
                ("final_caps_w", Json::arr_f64(arbiter.caps_w())),
            ]),
        ),
        ("autoscaler", autoscale_json),
        ("shards_detail", Json::Arr(shards_detail)),
    ];
    // Only fault-aware runs carry the key: fault-free digests stay
    // byte-identical to builds that predate the fault plane.
    if faults_on {
        pairs.push(("faults", sup.stats.to_json()));
    }
    let json = Json::obj(pairs);
    let digest = digest64(&json.to_string_compact());
    let (cache_hits, cache_misses) = cache.stats();
    Ok(ClusterReport {
        json,
        digest,
        snapshots,
        cache_hits,
        cache_misses,
        cache_entries: cache.len(),
    })
}

/// Convenience: a single-shard "cluster" is just a [`Server`] run — used
/// by tests comparing sharded and unsharded behavior.
pub fn single_node_report(
    cfg: &ClusterConfig,
    source: Box<dyn TrafficSource>,
) -> crate::serve::server::ServeReport {
    let arch = Arch::paper_heterogeneous(cfg.noi);
    match cfg.sched.clone() {
        ShardSchedSpec::Simba => {
            let sched = crate::sched::SimbaSched::new(arch.clone());
            Server::new(&arch, sched, source, cfg.serve.clone()).run()
        }
        ShardSchedSpec::BigLittle => {
            let sched = crate::sched::BigLittleSched::new(arch.clone());
            Server::new(&arch, sched, source, cfg.serve.clone()).run()
        }
        ShardSchedSpec::Thermos { theta, fallback } => {
            use crate::sched::policy::NativeDdt;
            use crate::sched::state::{StateEncoder, NUM_CLUSTERS, STATE_DIM};
            use crate::sched::thermos::ThermosSched;
            use crate::serve::server::TenantRouter;
            let zoo = crate::workload::ModelZoo::new();
            let encoder = StateEncoder::new(&arch, &zoo, cfg.serve.sim.max_images);
            let ddt = match theta {
                Some(t) => NativeDdt::new(STATE_DIM, NUM_CLUSTERS, t),
                None => {
                    let mut rng = crate::util::rng::Rng::new(cfg.serve.sim.seed);
                    NativeDdt::init(STATE_DIM, NUM_CLUSTERS, &mut rng)
                }
            };
            let sched = TenantRouter::new(ThermosSched::new(arch.clone(), encoder, ddt, fallback));
            Server::new(&arch, sched, source, cfg.serve.clone()).run()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::FaultEvent;
    use crate::serve::{PoissonSource, TenantClass};
    use crate::workload::DnnModel;

    #[test]
    fn tiny_cluster_runs_and_reports() {
        let cfg = ClusterConfig {
            shards: 2,
            duration_s: 8.0,
            drain_max_s: 10.0,
            serve: ServeConfig {
                duration_s: 8.0,
                tenant_queue_cap: 16,
                max_wait_s: 10.0,
                snapshot_every_s: 0.0,
                pressure_depth: 24,
                sim: SimConfig {
                    warmup_s: 0.0,
                    max_images: 200,
                    seed: 3,
                    ..SimConfig::default()
                },
            },
            sched: ShardSchedSpec::Simba,
            ..ClusterConfig::default()
        };
        let source = Box::new(PoissonSource::new(2.0, 30, 200, [1.0, 1.0, 1.0], 3));
        let report = run_cluster(cfg, source).expect("cluster run");
        assert_eq!(report.digest.len(), 16);
        assert_eq!(report.snapshots.len(), 8);
        assert!(report.json.get("offered").as_f64().expect("offered") > 0.0);
        assert!(report.json.get("completed").as_f64().expect("completed") > 0.0);
        assert_eq!(report.json.get("shards").as_f64().expect("shards"), 2.0);
        // Fault-free runs carry no fault telemetry at all.
        assert!(matches!(report.json.get("faults"), Json::Null));
        // Caps always sum to the budget.
        let budget = report.json.get("power_budget_w").as_f64().expect("budget");
        let caps = match report.json.get("arbiter").get("final_caps_w") {
            Json::Arr(xs) => xs.iter().map(|x| x.as_f64().expect("cap")).sum::<f64>(),
            other => panic!("final_caps_w not an array: {other:?}"),
        };
        assert!((caps - budget).abs() < 1e-6, "caps {caps} vs budget {budget}");
        // The shared profile cache saw traffic.
        assert!(report.cache_hits + report.cache_misses > 0);
    }

    #[test]
    fn supervisor_compiles_crash_and_hang_lifecycles() {
        let plan = FaultPlan::new(vec![
            FaultEvent { epoch: 2, shard: 1, kind: FaultKind::ShardCrash { down_epochs: 2 } },
            FaultEvent { epoch: 3, shard: 0, kind: FaultKind::ShardHang { epochs: 4 } },
        ]);
        let sup = Supervisor::new(&plan, 2, 20, true);
        assert_eq!(sup.schedule[1].get(&2), Some(&ShardCmd::Crash));
        assert_eq!(sup.schedule[1].get(&3), Some(&ShardCmd::Down));
        assert_eq!(sup.schedule[1].get(&4), Some(&ShardCmd::Restart));
        // A 4-epoch hang exceeds patience (2): two hung epochs, then the
        // supervisor escalates to a crash + restart.
        assert_eq!(sup.schedule[0].get(&3), Some(&ShardCmd::Hang));
        assert_eq!(sup.schedule[0].get(&4), Some(&ShardCmd::Hang));
        assert_eq!(sup.schedule[0].get(&5), Some(&ShardCmd::Crash));
        assert_eq!(sup.schedule[0].get(&6), Some(&ShardCmd::Restart));
    }

    #[test]
    fn supervisor_skips_a_crash_that_would_empty_the_ring() {
        let plan = FaultPlan::new(vec![FaultEvent {
            epoch: 0,
            shard: 0,
            kind: FaultKind::ShardCrash { down_epochs: 1 },
        }]);
        let mut sup = Supervisor::new(&plan, 1, 10, true);
        let mut router = ClusterRouter::new(&[0], 8, false, 100);
        let (cmds, _, _) = sup.directives(0, &mut router);
        assert_eq!(cmds[0], ShardCmd::Run, "sole shard must not be crashed");
        assert_eq!(sup.stats.faults_injected, 0);
        assert!(router.ring.contains(0));
        // The lifecycle is unscheduled, not deferred: no phantom restart.
        let (cmds, _, _) = sup.directives(1, &mut router);
        assert_eq!(cmds[0], ShardCmd::Run);
        assert_eq!(sup.stats.restarts, 0);
    }

    #[test]
    fn failover_reroutes_inflight_and_settles_exactly_once() {
        let plan = FaultPlan::new(vec![FaultEvent {
            epoch: 1,
            shard: 0,
            kind: FaultKind::ShardCrash { down_epochs: 2 },
        }]);
        let mut sup = Supervisor::new(&plan, 2, 10, true);
        let mut router = ClusterRouter::new(&[0, 1], 16, false, 100);
        let req = ServeRequest {
            t_s: 0.1,
            tenant: TenantClass::Exec,
            model: DnnModel::ResNet18,
            images: 50,
        };
        let tagged = sup.assign_gids(0, vec![req]);
        assert_eq!(tagged.len(), 1);
        let gid = tagged[0].0;
        let (cmds, _trips, extras) = sup.directives(1, &mut router);
        assert_eq!(cmds[0], ShardCmd::Crash);
        assert_eq!(sup.stats.failovers, 1);
        assert_eq!(sup.stats.retries, 1);
        assert!(
            extras[1].iter().any(|(g, _)| *g == gid),
            "in-flight work must land on the survivor"
        );
        assert!(!router.ring.contains(0));
        // The survivor reports the id done: the ledger closes, no dupes.
        sup.settle(&[gid], &[]);
        assert!(sup.inflight.is_empty());
        // The restart re-joins the ring after the down window.
        let (cmds, _, _) = sup.directives(3, &mut router);
        assert_eq!(cmds[0], ShardCmd::Restart);
        assert!(router.ring.contains(0));
        assert_eq!(sup.stats.restarts, 1);
    }

    #[test]
    fn mailbox_faults_drop_or_park_the_batch() {
        let plan = FaultPlan::new(vec![
            FaultEvent { epoch: 0, shard: 0, kind: FaultKind::MailboxDrop },
            FaultEvent { epoch: 1, shard: 1, kind: FaultKind::MailboxDelay { epochs: 2 } },
        ]);
        let mut sup = Supervisor::new(&plan, 2, 10, true);
        let req = |t| ServeRequest {
            t_s: t,
            tenant: TenantClass::Energy,
            model: DnnModel::AlexNet,
            images: 10,
        };
        let mut dropped = sup.assign_gids(0, vec![req(0.0), req(0.1)]);
        sup.intercept(0, 0, &mut dropped);
        assert!(dropped.is_empty());
        assert_eq!(sup.stats.dropped_requests, 2);
        assert!(sup.inflight.is_empty(), "dropped ids leave the ledger");
        let mut delayed = sup.assign_gids(1, vec![req(1.0)]);
        sup.intercept(1, 1, &mut delayed);
        assert!(delayed.is_empty());
        // Two epochs later the batch comes due on the same shard.
        let mut router = ClusterRouter::new(&[0, 1], 16, false, 100);
        let (_, _, extras) = sup.directives(3, &mut router);
        assert_eq!(extras[1].len(), 1, "delayed batch must be delivered");
        assert_eq!(sup.stats.faults_injected, 2);
    }
}
