//! The cluster subsystem: sharded multi-engine serving with a global
//! thermal/power arbiter.
//!
//! ```text
//!                       ┌────────────────────────────┐
//!   traffic source ──▶  │ coordinator (main thread)  │
//!                       │  consistent-hash router +  │◀── caps, epoch
//!                       │  coalescing + autoscaler   │    reports
//!                       └──────┬──────┬──────┬───────┘        ▲
//!                 EpochPacket  │      │      │ (bounded       │
//!                 {reqs,cap}   ▼      ▼      ▼  mailboxes)    │
//!                       ┌──────────┐ ┌───┐ ┌───┐              │
//!                       │ shard 0  │ │ 1 │ │ N │  one engine +│
//!                       │ (thread) │ │   │ │   │  sched each  │
//!                       └────┬─────┘ └─┬─┘ └─┬─┘              │
//!                            │ EpochReport {peak_temp, power} │
//!                            ▼         ▼     ▼                │
//!                       ┌────────────────────────────┐        │
//!                       │ arbiter (thread): resplit  │────────┘
//!                       │ power budget by headroom   │
//!                       └────────────────────────────┘
//! ```
//!
//! One serving [`Server`] (engine + scheduler) per shard — one shard per
//! interposer — on its own worker thread. The coordinator routes each
//! epoch's arrivals by model fingerprint (consistent hashing keeps a
//! model's weights and cached profiles on one shard), coalesces
//! same-model requests into batches, and pushes one [`EpochPacket`] per
//! shard through a bounded mailbox. The arbiter owns the package power
//! budget: every epoch it collects one [`EpochReport`] per shard
//! (a barrier), reslices the budget headroom-weighted from reported peak
//! temperatures — hot shards lose budget to cool ones — and returns
//! per-shard caps that the engine enforces at mapping time.
//!
//! ## Determinism model
//!
//! Real threads, reproducible results: shards advance in *epoch
//! lockstep*. Within an epoch a shard is a deterministic function of its
//! seed and its packet sequence; the packet sequence is a deterministic
//! function of the source seed and the (deterministic) cap/autoscale
//! history; the arbiter sorts reports by shard id before rebalance.
//! Thread interleaving can reorder report arrival but never their epoch
//! content, so `thermos serve --shards 4 --seed S` twice produces
//! byte-identical merged reports. The only interleaving-dependent values
//! — profile-cache hit/miss splits — are deliberately kept out of the
//! digested JSON.

pub mod arbiter;
pub mod autoscale;
pub mod router;
pub mod shard;

pub use arbiter::{package_tdp_w, Arbiter, ArbiterConfig};
pub use autoscale::{AutoscaleConfig, Autoscaler};
pub use router::{ClusterRouter, HashRing, RouteStats};
pub use shard::{EpochPacket, EpochReport, ShardParams, ShardResult, ShardSchedSpec};

use crate::arch::Arch;
use crate::noi::NoiTopology;
use crate::sched::thermos::PREF_BALANCED;
use crate::serve::ingest::TrafficSource;
use crate::serve::server::{ServeConfig, Server};
use crate::serve::telemetry::{digest64, TelemetryHub};
use crate::sim::{ProfileCache, SimConfig};
use crate::thermal::ThermalParams;
use crate::util::json::Json;
use std::sync::mpsc;

#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Worker shards (engines). The autoscaler varies the *active* subset
    /// of the ring; workers always step so drained shards stay warm.
    pub shards: usize,
    /// Telemetry epoch: router/arbiter barrier interval (s).
    pub epoch_s: f64,
    /// Serving horizon (s).
    pub duration_s: f64,
    /// Post-horizon drain bound per shard (s).
    pub drain_max_s: f64,
    /// Total package power budget (W); `None` derives
    /// `budget_frac × TDP × shards` from the architecture.
    pub power_budget_w: Option<f64>,
    pub budget_frac: f64,
    /// Bounded mailbox depth per shard.
    pub mailbox_cap: usize,
    /// Virtual nodes per shard on the hash ring.
    pub vnodes: usize,
    /// Coalesce same-(model, tenant) requests within an epoch batch.
    pub coalesce: bool,
    pub max_batch_images: u64,
    pub noi: NoiTopology,
    /// Per-shard serve/engine knobs. Shard `i` runs with
    /// `seed + i · 0x9e37` (distinct workload state per shard,
    /// deterministic overall); snapshots are cluster-level, so per-shard
    /// snapshotting is forced off.
    pub serve: ServeConfig,
    pub sched: ShardSchedSpec,
    pub autoscale: Option<AutoscaleConfig>,
    /// Per-shard replay logs: `<base>.shard<i>.jsonl`.
    pub record_base: Option<String>,
}

impl Default for ClusterConfig {
    fn default() -> ClusterConfig {
        ClusterConfig {
            shards: 2,
            epoch_s: 1.0,
            duration_s: 120.0,
            drain_max_s: 30.0,
            power_budget_w: None,
            budget_frac: 0.75,
            mailbox_cap: 2,
            vnodes: 16,
            coalesce: true,
            max_batch_images: 8_000,
            noi: NoiTopology::Mesh,
            serve: ServeConfig::default(),
            sched: ShardSchedSpec::Thermos { theta: None, fallback: PREF_BALANCED },
            autoscale: None,
            record_base: None,
        }
    }
}

/// Fleet-wide output: merged report JSON + digest, per-epoch snapshots,
/// and profile-cache stats (observability only — interleaving-dependent,
/// never part of the digested JSON).
#[derive(Debug)]
pub struct ClusterReport {
    pub json: Json,
    pub digest: String,
    pub snapshots: Vec<Json>,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_entries: usize,
}

fn epoch_snapshot_json(
    epoch: usize,
    t_s: f64,
    reports: &[EpochReport],
    caps_w: &[f64],
    active: usize,
) -> Json {
    Json::obj(vec![
        ("epoch", Json::Num(epoch as f64)),
        ("t_s", Json::Num(t_s)),
        ("active_shards", Json::Num(active as f64)),
        ("completed", Json::Num(reports.iter().map(|r| r.completed).sum::<u64>() as f64)),
        (
            "queue_depth",
            Json::Num(reports.iter().map(|r| r.queue_depth).sum::<usize>() as f64),
        ),
        (
            "peak_temp_k",
            Json::Num(reports.iter().map(|r| r.peak_temp_k).fold(0.0, f64::max)),
        ),
        ("power_w", Json::Num(reports.iter().map(|r| r.power_w).sum::<f64>())),
        ("caps_w", Json::arr_f64(caps_w)),
        (
            "throttled_shards",
            Json::Num(reports.iter().filter(|r| r.throttled).count() as f64),
        ),
        (
            "cap_gated_shards",
            Json::Num(reports.iter().filter(|r| r.cap_gated).count() as f64),
        ),
    ])
}

/// Run a sharded serving cluster to its horizon and merge the per-shard
/// telemetry into one fleet-wide report. See the module docs for the
/// architecture and determinism model.
pub fn run_cluster(cfg: ClusterConfig, mut source: Box<dyn TrafficSource>) -> ClusterReport {
    assert!(cfg.shards >= 1, "cluster needs at least one shard");
    let n = cfg.shards;
    let ref_arch = Arch::paper_heterogeneous(cfg.noi);
    let budget_w = cfg
        .power_budget_w
        .unwrap_or_else(|| package_tdp_w(&ref_arch) * cfg.budget_frac * n as f64);
    let dt = ThermalParams::default().dt_s;
    let epoch_steps = ((cfg.epoch_s / dt).round() as usize).max(1);
    let total_epochs = ((cfg.duration_s / cfg.epoch_s).ceil() as usize).max(1);

    let cache = ProfileCache::new();
    let source_name = source.name().to_string();
    let scheduler_name = cfg.sched.name();

    // Channels: bounded per-shard mailboxes in, unbounded telemetry out.
    let mut packet_txs: Vec<mpsc::SyncSender<EpochPacket>> = Vec::with_capacity(n);
    let mut packet_rxs: Vec<mpsc::Receiver<EpochPacket>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = mpsc::sync_channel(cfg.mailbox_cap.max(1));
        packet_txs.push(tx);
        packet_rxs.push(rx);
    }
    let (report_tx, report_rx) = mpsc::channel::<EpochReport>();
    let (outcome_tx, outcome_rx) = mpsc::channel::<arbiter::EpochOutcome>();
    let (result_tx, result_rx) = mpsc::channel::<ShardResult>();

    let mut snapshots: Vec<Json> = Vec::new();
    let mut stats = RouteStats { routed: vec![0; n], ..Default::default() };
    let mut autoscaler = cfg.autoscale.clone().map(Autoscaler::new);
    let initial_active = match &autoscaler {
        Some(a) => a.cfg.min_shards.clamp(1, n),
        None => n,
    };
    let mut router = ClusterRouter::new(
        &(0..initial_active).collect::<Vec<usize>>(),
        cfg.vnodes,
        cfg.coalesce,
        cfg.max_batch_images,
    );

    let (mut results, arbiter) = std::thread::scope(|scope| {
        let arb = Arbiter::new(ArbiterConfig::new(budget_w), n);
        let arb_handle = scope.spawn(move || arb.run(report_rx, outcome_tx, total_epochs));

        for (id, rx) in packet_rxs.into_iter().enumerate() {
            let params = ShardParams {
                id,
                noi: cfg.noi,
                serve: ServeConfig {
                    snapshot_every_s: 0.0,
                    sim: SimConfig {
                        seed: cfg.serve.sim.seed.wrapping_add(id as u64 * 0x9e37),
                        ..cfg.serve.sim.clone()
                    },
                    ..cfg.serve.clone()
                },
                sched: cfg.sched.clone(),
                epoch_steps,
                drain_max_s: cfg.drain_max_s,
                record_path: cfg.record_base.as_ref().map(|b| format!("{b}.shard{id}.jsonl")),
            };
            let cache = cache.clone();
            let report_tx = report_tx.clone();
            let result_tx = result_tx.clone();
            scope.spawn(move || shard::run_shard(params, cache, rx, report_tx, result_tx));
        }
        drop(report_tx);
        drop(result_tx);

        // Coordinator: route arrivals, barrier with the arbiter, autoscale.
        let mut caps_w = vec![budget_w / n as f64; n];
        for epoch in 0..total_epochs {
            let t_end = (epoch as f64 + 1.0) * cfg.epoch_s;
            let arrivals = source.arrivals_until(t_end);
            let offered_rate = arrivals.len() as f64 / cfg.epoch_s;
            let mut batches = router.route_epoch(arrivals, n, &mut stats);
            let last = epoch + 1 == total_epochs;
            for (id, tx) in packet_txs.iter().enumerate() {
                let pkt =
                    EpochPacket { reqs: std::mem::take(&mut batches[id]), cap_w: caps_w[id], last };
                match tx.try_send(pkt) {
                    Ok(()) => {}
                    // The lockstep protocol keeps at most one packet in
                    // flight, but fall back to a blocking send for safety.
                    Err(mpsc::TrySendError::Full(pkt)) => {
                        let _ = tx.send(pkt);
                    }
                    Err(mpsc::TrySendError::Disconnected(_)) => {}
                }
            }
            let Ok((new_caps, reports)) = outcome_rx.recv() else { break };
            caps_w = new_caps;
            if let Some(a) = autoscaler.as_mut() {
                let active = router.ring.num_shards();
                let target = a.target(offered_rate, active).clamp(1, n);
                while router.ring.num_shards() < target {
                    match (0..n).find(|&i| !router.ring.contains(i)) {
                        Some(i) => router.ring.add(i),
                        None => break,
                    }
                }
                while router.ring.num_shards() > target {
                    let last_active = *router.ring.shards().last().unwrap();
                    router.ring.remove(last_active);
                }
            }
            snapshots.push(epoch_snapshot_json(
                epoch,
                t_end,
                &reports,
                &caps_w,
                router.ring.num_shards(),
            ));
        }
        drop(packet_txs);

        let mut results: Vec<ShardResult> = Vec::with_capacity(n);
        while let Ok(r) = result_rx.recv() {
            results.push(r);
        }
        let arbiter = arb_handle.join().expect("arbiter thread panicked");
        (results, arbiter)
    });
    results.sort_by_key(|r| r.id);

    // Deterministic merge: fixed shard-id order.
    let mut merged = TelemetryHub::new();
    for r in &results {
        merged.merge(&r.hub);
    }
    let (offered_batches, admitted, rejected, shed, completed) = merged.totals();
    let num = |j: &Json, k: &str| j.get(k).as_f64().unwrap_or(0.0);
    let duration_s =
        results.iter().map(|r| num(&r.report.json, "duration_s")).fold(0.0, f64::max);
    let shards_detail: Vec<Json> = results
        .iter()
        .map(|r| {
            let j = &r.report.json;
            Json::obj(vec![
                ("id", Json::Num(r.id as f64)),
                ("offered", j.get("offered").clone()),
                ("rejected", j.get("rejected").clone()),
                ("shed", j.get("shed").clone()),
                ("shed_pressure", j.get("shed_pressure").clone()),
                ("completed", j.get("completed").clone()),
                ("images_done", j.get("images_done").clone()),
                ("max_temp_k", j.get("max_temp_k").clone()),
                ("throttle_events", j.get("throttle_events").clone()),
                ("cap_gated_steps", j.get("cap_gated_steps").clone()),
                ("system_energy_j", j.get("system_energy_j").clone()),
                ("host_stalls", j.get("host_stalls").clone()),
                ("duration_s", j.get("duration_s").clone()),
            ])
        })
        .collect();
    let autoscale_json = match &autoscaler {
        Some(a) => Json::obj(vec![
            ("scale_ups", Json::Num(a.scale_ups as f64)),
            ("scale_downs", Json::Num(a.scale_downs as f64)),
            ("active_final", Json::Num(router.ring.num_shards() as f64)),
        ]),
        None => Json::Null,
    };
    let json = Json::obj(vec![
        ("scheduler", Json::Str(scheduler_name.to_string())),
        ("source", Json::Str(source_name)),
        ("seed", Json::Num(cfg.serve.sim.seed as f64)),
        ("shards", Json::Num(n as f64)),
        ("epochs", Json::Num(total_epochs as f64)),
        ("epoch_s", Json::Num(cfg.epoch_s)),
        ("duration_s", Json::Num(duration_s)),
        ("offered", Json::Num(stats.offered as f64)),
        ("coalesced_requests", Json::Num(stats.coalesced as f64)),
        ("offered_batches", Json::Num(offered_batches as f64)),
        (
            "routed_per_shard",
            Json::Arr(stats.routed.iter().map(|&x| Json::Num(x as f64)).collect()),
        ),
        ("admitted", Json::Num(admitted as f64)),
        ("rejected", Json::Num(rejected as f64)),
        ("shed", Json::Num(shed as f64)),
        ("shed_pressure", Json::Num(merged.shed_pressure_total() as f64)),
        ("completed", Json::Num(completed as f64)),
        ("images_done", Json::Num(merged.images_done_total() as f64)),
        ("throughput_jobs_s", Json::Num(completed as f64 / duration_s.max(1e-9))),
        (
            "throughput_images_s",
            Json::Num(merged.images_done_total() as f64 / duration_s.max(1e-9)),
        ),
        ("latency_e2e_s", merged.e2e_all.to_json()),
        ("latency_exec_s", merged.exec_all.to_json()),
        ("energy_j", merged.energy_all.to_json()),
        ("tenants", merged.tenants_json()),
        (
            "max_temp_k",
            Json::Num(
                results.iter().map(|r| num(&r.report.json, "max_temp_k")).fold(0.0, f64::max),
            ),
        ),
        (
            "system_energy_j",
            Json::Num(results.iter().map(|r| num(&r.report.json, "system_energy_j")).sum::<f64>()),
        ),
        (
            "throttle_events",
            Json::Num(results.iter().map(|r| num(&r.report.json, "throttle_events")).sum::<f64>()),
        ),
        (
            "cap_gated_steps",
            Json::Num(results.iter().map(|r| num(&r.report.json, "cap_gated_steps")).sum::<f64>()),
        ),
        ("power_budget_w", Json::Num(budget_w)),
        (
            "arbiter",
            Json::obj(vec![
                ("budget_w", Json::Num(budget_w)),
                ("rebalances", Json::Num(arbiter.rebalances as f64)),
                ("epochs", Json::Num(arbiter.epochs as f64)),
                ("final_caps_w", Json::arr_f64(arbiter.caps_w())),
            ]),
        ),
        ("autoscaler", autoscale_json),
        ("shards_detail", Json::Arr(shards_detail)),
    ]);
    let digest = digest64(&json.to_string_compact());
    let (cache_hits, cache_misses) = cache.stats();
    ClusterReport {
        json,
        digest,
        snapshots,
        cache_hits,
        cache_misses,
        cache_entries: cache.len(),
    }
}

/// Convenience: a single-shard "cluster" is just a [`Server`] run — used
/// by tests comparing sharded and unsharded behavior.
pub fn single_node_report(
    cfg: &ClusterConfig,
    source: Box<dyn TrafficSource>,
) -> crate::serve::server::ServeReport {
    let arch = Arch::paper_heterogeneous(cfg.noi);
    match cfg.sched.clone() {
        ShardSchedSpec::Simba => {
            let sched = crate::sched::SimbaSched::new(arch.clone());
            Server::new(&arch, sched, source, cfg.serve.clone()).run()
        }
        ShardSchedSpec::BigLittle => {
            let sched = crate::sched::BigLittleSched::new(arch.clone());
            Server::new(&arch, sched, source, cfg.serve.clone()).run()
        }
        ShardSchedSpec::Thermos { theta, fallback } => {
            use crate::sched::policy::NativeDdt;
            use crate::sched::state::{StateEncoder, NUM_CLUSTERS, STATE_DIM};
            use crate::sched::thermos::ThermosSched;
            use crate::serve::server::TenantRouter;
            let zoo = crate::workload::ModelZoo::new();
            let encoder = StateEncoder::new(&arch, &zoo, cfg.serve.sim.max_images);
            let ddt = match theta {
                Some(t) => NativeDdt::new(STATE_DIM, NUM_CLUSTERS, t),
                None => {
                    let mut rng = crate::util::rng::Rng::new(cfg.serve.sim.seed);
                    NativeDdt::init(STATE_DIM, NUM_CLUSTERS, &mut rng)
                }
            };
            let sched = TenantRouter::new(ThermosSched::new(arch.clone(), encoder, ddt, fallback));
            Server::new(&arch, sched, source, cfg.serve.clone()).run()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::PoissonSource;

    #[test]
    fn tiny_cluster_runs_and_reports() {
        let cfg = ClusterConfig {
            shards: 2,
            duration_s: 8.0,
            drain_max_s: 10.0,
            serve: ServeConfig {
                duration_s: 8.0,
                tenant_queue_cap: 16,
                max_wait_s: 10.0,
                snapshot_every_s: 0.0,
                pressure_depth: 24,
                sim: SimConfig {
                    warmup_s: 0.0,
                    max_images: 200,
                    seed: 3,
                    ..SimConfig::default()
                },
            },
            sched: ShardSchedSpec::Simba,
            ..ClusterConfig::default()
        };
        let source = Box::new(PoissonSource::new(2.0, 30, 200, [1.0, 1.0, 1.0], 3));
        let report = run_cluster(cfg, source);
        assert_eq!(report.digest.len(), 16);
        assert_eq!(report.snapshots.len(), 8);
        assert!(report.json.get("offered").as_f64().unwrap() > 0.0);
        assert!(report.json.get("completed").as_f64().unwrap() > 0.0);
        assert_eq!(report.json.get("shards").as_f64().unwrap(), 2.0);
        // Caps always sum to the budget.
        let budget = report.json.get("power_budget_w").as_f64().unwrap();
        let caps = match report.json.get("arbiter").get("final_caps_w") {
            Json::Arr(xs) => xs.iter().map(|x| x.as_f64().unwrap()).sum::<f64>(),
            other => panic!("final_caps_w not an array: {other:?}"),
        };
        assert!((caps - budget).abs() < 1e-6, "caps {caps} vs budget {budget}");
        // The shared profile cache saw traffic.
        assert!(report.cache_hits + report.cache_misses > 0);
    }
}
