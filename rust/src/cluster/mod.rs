//! The cluster subsystem: sharded multi-engine serving with a global
//! thermal/power arbiter, a fault-injecting supervisor, warm-standby
//! spares, and deterministic work-stealing between shards.
//!
//! ```text
//!                       ┌────────────────────────────┐
//!   traffic source ──▶  │ coordinator (main thread)  │
//!                       │  consistent-hash router +  │
//!                       │  coalescing + autoscaler + │
//!                       │  supervisor + arbiter +    │
//!                       │  steal planner             │
//!                       └──────┬──────┬──────┬───────┘
//!            EpochPacket       │      │      │      ▲
//!            {reqs, cap, cmd}  ▼      ▼      ▼      │ EpochReport
//!                       ┌──────────┐ ┌───┐ ┌───┐    │ {peak_temp,
//!                       │ shard 0  │ │ 1 │ │ N │    │  power, ids,
//!                       │ (pooled) │ │   │ │   │ ───┘  stolen}
//!                       └──────────┘ └───┘ └───┘
//! ```
//!
//! One serving [`Server`] (engine + scheduler) per shard — one shard per
//! interposer — each held in a [`ShardSlot`](shard::ShardSlot) and
//! stepped on the shared [`WorkPool`] (one pooled task per slot per
//! epoch). The coordinator routes each epoch's arrivals by model
//! fingerprint (consistent hashing keeps a model's weights and cached
//! profiles on one shard), coalesces same-model requests into batches,
//! tags each batch with a global request id, and hands one
//! [`EpochPacket`] per slot to the pool. At the epoch barrier it
//! collects exactly one [`EpochReport`] per shard, settles the
//! request-id ledger, reslices the power budget headroom-weighted over
//! the *alive* shards (hot shards lose budget to cool ones, dead shards
//! lose their whole slice), and autoscales the active ring.
//!
//! ## Work-stealing
//!
//! Consistent hashing concentrates a hot model's load on one shard.
//! With a [`StealConfig`] set, the coordinator estimates each shard's
//! backlog in seconds (ledger in-flight plus this epoch's fresh batch,
//! priced by the canonical [`CostModel`]) and plans a seeded,
//! order-stable [`steal_schedule`] from most- to least-loaded shards.
//! Donors surrender whole queued requests (keeping their gids) up to
//! the planned quota at the end of their epoch; the coordinator
//! reassigns them at the barrier and delivers them with the next
//! epoch's packets. Steal counters join the merged report (and its
//! digest) only when stealing is on, so `--steal off` digests are
//! byte-identical to builds that predate the steal plane.
//!
//! ## Fault injection, supervision, and warm standby
//!
//! With a [`FaultPlan`] configured, a supervisor inside the coordinator
//! compiles the plan into per-shard lifecycles and applies them at epoch
//! barriers: crashes kill a shard's engine (the supervisor removes it
//! from the ring, fails its in-flight requests over to the survivors by
//! re-routing them on the shrunken ring, and restarts it from a
//! checkpoint after its down window); hangs freeze a shard — tolerated
//! for [`SUPERVISOR_PATIENCE_EPOCHS`] epochs, then escalated to a
//! crash + restart; chiplet trips, mailbox drops/delays, and report
//! losses perturb the data and telemetry planes. With `spares > 0` the
//! supervisor keeps that many prebuilt engines idle in physical slots
//! `n..n+spares`; a crash is then absorbed by *promotion* — the standby
//! adopts the dead shard's ring position, checkpoint clock, and
//! in-flight ids at the same barrier, so the shard never leaves the
//! ring and pays no `downtime_epochs`. The demoted slot re-warms as the
//! next standby. The request-id ledger is transactional: a request id
//! is settled exactly once (done or dropped), so failover retries and
//! steal migrations never double-complete — at-most-once accounting.
//! Degradation counters ([`FaultStats`]) join the merged report (and
//! its digest) only when a plan is active, so fault-free digests are
//! byte-identical to a build without this module.
//!
//! ## Determinism model
//!
//! Real threads, reproducible results: shards advance in *epoch
//! lockstep* on the work pool. Within an epoch a shard is a
//! deterministic function of its seed and its packet sequence; the
//! packet sequence is a deterministic function of the source seed, the
//! fault plan, the steal schedule (itself a pure function of
//! `(seed, epoch, loads)`), and the (deterministic) cap/autoscale
//! history; the coordinator reads reports in shard-id order. Thread
//! interleaving can reorder slot execution but never epoch content, so
//! `thermos serve --shards 4 --seed S [--chaos C] [--steal]
//! [--spares K]` twice produces byte-identical merged reports — and the
//! same holds across `--threads 1` and `--threads 4`. The only
//! interleaving-dependent values — profile-cache hit/miss splits — are
//! deliberately kept out of the digested JSON.
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

pub mod arbiter;
pub mod autoscale;
pub mod router;
pub mod shard;
pub mod steal;

pub use arbiter::{package_tdp_w, Arbiter, ArbiterConfig};
pub use autoscale::{AutoscaleConfig, Autoscaler};
pub use router::{ClusterRouter, HashRing, RouteStats};
pub use shard::{EpochPacket, EpochReport, ShardParams, ShardResult, ShardSchedSpec};
pub use steal::{steal_schedule, CostModel, StealConfig, StealMove, StealStats};

pub use crate::fault::{ClusterError, FaultPlan};

use crate::arch::Arch;
use crate::fault::{FaultKind, FaultStats, ShardCmd, SUPERVISOR_PATIENCE_EPOCHS};
use crate::noi::NoiTopology;
use crate::sched::thermos::PREF_BALANCED;
use crate::serve::ingest::TrafficSource;
use crate::serve::server::{ServeConfig, ServeSched, Server};
use crate::serve::telemetry::{digest64, TelemetryHub};
use crate::serve::ServeRequest;
use crate::sim::{ProfileCache, SimConfig};
use crate::thermal::ThermalParams;
use crate::util::json::Json;
use crate::util::pool::WorkPool;
use crate::util::sync::lock_recover;
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::{Arc, Mutex};

#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Worker shards (engines). The autoscaler varies the *active* subset
    /// of the ring; workers always step so drained shards stay warm.
    pub shards: usize,
    /// Telemetry epoch: router/arbiter barrier interval (s).
    pub epoch_s: f64,
    /// Serving horizon (s).
    pub duration_s: f64,
    /// Post-horizon drain bound per shard (s).
    pub drain_max_s: f64,
    /// Total package power budget (W); `None` derives
    /// `budget_frac × TDP × shards` from the architecture.
    pub power_budget_w: Option<f64>,
    pub budget_frac: f64,
    /// Vestigial mailbox depth from the channel-based coordinator; kept
    /// for config compatibility (the pooled barrier has no mailboxes).
    pub mailbox_cap: usize,
    /// Virtual nodes per shard on the hash ring.
    pub vnodes: usize,
    /// Coalesce same-(model, tenant) requests within an epoch batch.
    pub coalesce: bool,
    pub max_batch_images: u64,
    pub noi: NoiTopology,
    /// Per-shard serve/engine knobs. Physical slot `i` runs with
    /// `seed + i · 0x9e37` (distinct workload state per shard,
    /// deterministic overall); snapshots are cluster-level, so per-shard
    /// snapshotting is forced off.
    pub serve: ServeConfig,
    pub sched: ShardSchedSpec,
    pub autoscale: Option<AutoscaleConfig>,
    /// Per-shard replay logs: `<base>.shard<i>.jsonl`.
    pub record_base: Option<String>,
    /// Deterministic fault schedule; `None` disables the whole fault
    /// plane (and keeps merged digests identical to pre-fault builds).
    pub faults: Option<FaultPlan>,
    /// Warm-standby spares: prebuilt idle engines in physical slots
    /// `shards..shards+spares` that absorb crashes by promotion.
    pub spares: usize,
    /// Work-stealing knobs; `None` disables the steal plane (and keeps
    /// merged digests identical to pre-steal builds).
    pub steal: Option<StealConfig>,
    /// Pool width for per-shard epoch stepping; `None` uses the global
    /// thread configuration (`--threads` / `THERMOS_THREADS` / cores).
    /// Results are byte-identical at any width.
    pub threads: Option<usize>,
}

impl Default for ClusterConfig {
    fn default() -> ClusterConfig {
        ClusterConfig {
            shards: 2,
            epoch_s: 1.0,
            duration_s: 120.0,
            drain_max_s: 30.0,
            power_budget_w: None,
            budget_frac: 0.75,
            mailbox_cap: 2,
            vnodes: 16,
            coalesce: true,
            max_batch_images: 8_000,
            noi: NoiTopology::Mesh,
            serve: ServeConfig::default(),
            sched: ShardSchedSpec::Thermos { theta: None, fallback: PREF_BALANCED },
            autoscale: None,
            record_base: None,
            faults: None,
            spares: 0,
            steal: None,
            threads: None,
        }
    }
}

/// Fleet-wide output: merged report JSON + digest, per-epoch snapshots,
/// and profile-cache stats (observability only — interleaving-dependent,
/// never part of the digested JSON).
#[derive(Debug)]
pub struct ClusterReport {
    pub json: Json,
    pub digest: String,
    pub snapshots: Vec<Json>,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_entries: usize,
}

/// The fault supervisor: compiles a [`FaultPlan`] into per-shard
/// lifecycles, owns the request-id ledger that makes failover and steal
/// migration at-most-once, and manages the logical-shard → physical-slot
/// assignment that warm-standby promotion rewires. Lives inside the
/// coordinator — every decision happens at an epoch barrier, on one
/// thread, in shard-id order, so the fault schedule perturbs the run
/// deterministically.
struct Supervisor {
    n: usize,
    /// Per-shard lifecycle directive by epoch (absent ⇒ `Run`).
    schedule: Vec<BTreeMap<usize, ShardCmd>>,
    /// Epochs that begin a scheduled fault (for `faults_injected`).
    fault_starts: Vec<BTreeSet<usize>>,
    /// Epochs at which a hung shard resumes and rejoins the ring.
    rejoin: Vec<BTreeSet<usize>>,
    /// Chiplet trip transitions per shard per epoch: `(chiplet, offline)`.
    trips: Vec<BTreeMap<usize, Vec<(usize, bool)>>>,
    /// `(epoch, shard)` whose request batch is lost in transit.
    drops: BTreeSet<(usize, usize)>,
    /// `(epoch, shard)` → delay in epochs for that batch.
    delays: BTreeMap<(usize, usize), usize>,
    /// `(epoch, shard)` whose epoch report is lost before the arbiter.
    losses: BTreeSet<(usize, usize)>,
    /// Liveness as of the last applied directive.
    alive: Vec<bool>,
    /// Global request id → (owning shard, request). BTreeMap so failover
    /// re-routes in ascending gid order — deterministic retry order.
    inflight: BTreeMap<u64, (usize, ServeRequest)>,
    /// Delivery epoch → delayed batches `(original shard, tagged reqs)`.
    delayed: BTreeMap<usize, Vec<(usize, Vec<(u64, ServeRequest)>)>>,
    /// Gids stolen at the last barrier, awaiting delivery with the next
    /// epoch's packets. Failover and promotion skip these — the steal
    /// delivery path re-routes them itself — so a crash between plan and
    /// delivery can never deliver a request twice.
    pending_gids: BTreeSet<u64>,
    /// Logical shard → physical slot; promotion rewires one entry.
    assignment: Vec<usize>,
    /// Idle prebuilt physical slots, FIFO by warm-up order.
    spare_pool: VecDeque<usize>,
    /// Physical slots demoted at this barrier: they get a `Crash` packet
    /// this epoch, then recycle into `spare_pool` at the next barrier
    /// (re-warming via `Standby` packets).
    demoted: Vec<usize>,
    next_gid: u64,
    /// Ledger tracking is only paid for when a plan or stealing is
    /// active.
    track: bool,
    stats: FaultStats,
}

impl Supervisor {
    fn new(
        plan: &FaultPlan,
        n: usize,
        total_epochs: usize,
        track: bool,
        spares: usize,
    ) -> Supervisor {
        let mut sup = Supervisor {
            n,
            schedule: vec![BTreeMap::new(); n],
            fault_starts: vec![BTreeSet::new(); n],
            rejoin: vec![BTreeSet::new(); n],
            trips: vec![BTreeMap::new(); n],
            drops: BTreeSet::new(),
            delays: BTreeMap::new(),
            losses: BTreeSet::new(),
            alive: vec![true; n],
            inflight: BTreeMap::new(),
            delayed: BTreeMap::new(),
            pending_gids: BTreeSet::new(),
            assignment: (0..n).collect(),
            spare_pool: (n..n + spares).collect(),
            demoted: Vec::new(),
            next_gid: 0,
            track,
            stats: FaultStats::default(),
        };
        for ev in &plan.events {
            let s = ev.shard;
            if s >= n || ev.epoch >= total_epochs {
                continue;
            }
            match &ev.kind {
                FaultKind::ChipletTrip { chiplet, epochs } => {
                    let d = (*epochs).max(1);
                    sup.trips[s].entry(ev.epoch).or_default().push((*chiplet, true));
                    sup.trips[s].entry(ev.epoch + d).or_default().push((*chiplet, false));
                }
                FaultKind::ShardCrash { down_epochs } => {
                    let d = (*down_epochs).max(1);
                    // First-wins: overlapping lifecycles on one shard are
                    // dropped wholesale, never half-applied.
                    if (ev.epoch..=ev.epoch + d).any(|e| sup.schedule[s].contains_key(&e)) {
                        continue;
                    }
                    sup.schedule[s].insert(ev.epoch, ShardCmd::Crash);
                    for e in ev.epoch + 1..ev.epoch + d {
                        sup.schedule[s].insert(e, ShardCmd::Down);
                    }
                    if ev.epoch + d < total_epochs {
                        sup.schedule[s].insert(ev.epoch + d, ShardCmd::Restart);
                    }
                    sup.fault_starts[s].insert(ev.epoch);
                }
                FaultKind::ShardHang { epochs } => {
                    let k = (*epochs).max(1);
                    if k <= SUPERVISOR_PATIENCE_EPOCHS {
                        if (ev.epoch..ev.epoch + k).any(|e| sup.schedule[s].contains_key(&e)) {
                            continue;
                        }
                        for e in ev.epoch..ev.epoch + k {
                            sup.schedule[s].insert(e, ShardCmd::Hang);
                        }
                        if ev.epoch + k < total_epochs {
                            sup.rejoin[s].insert(ev.epoch + k);
                        }
                    } else {
                        // Patience exhausted: two hung epochs, then the
                        // supervisor escalates to a crash + restart.
                        if (ev.epoch..=ev.epoch + 3).any(|e| sup.schedule[s].contains_key(&e)) {
                            continue;
                        }
                        sup.schedule[s].insert(ev.epoch, ShardCmd::Hang);
                        sup.schedule[s].insert(ev.epoch + 1, ShardCmd::Hang);
                        sup.schedule[s].insert(ev.epoch + 2, ShardCmd::Crash);
                        if ev.epoch + 3 < total_epochs {
                            sup.schedule[s].insert(ev.epoch + 3, ShardCmd::Restart);
                        }
                    }
                    sup.fault_starts[s].insert(ev.epoch);
                }
                FaultKind::MailboxDrop => {
                    sup.drops.insert((ev.epoch, s));
                }
                FaultKind::MailboxDelay { epochs } => {
                    sup.delays.insert((ev.epoch, s), (*epochs).max(1));
                }
                FaultKind::ReportLoss => {
                    sup.losses.insert((ev.epoch, s));
                }
            }
        }
        sup
    }

    /// Remove an entire unapplied lifecycle starting at `start` (its cmds
    /// occupy consecutive epochs) plus its rejoin mark and start marker.
    fn unschedule_lifecycle(&mut self, s: usize, start: usize) {
        let mut e = start;
        while self.schedule[s].remove(&e).is_some() {
            e += 1;
        }
        self.rejoin[s].remove(&e);
        self.fault_starts[s].remove(&start);
    }

    /// Gids currently parked in the delayed-delivery stash; these are
    /// skipped by crash failover (the delivery path re-routes them).
    fn delayed_gids(&self) -> BTreeSet<u64> {
        self.delayed
            .values()
            .flatten()
            .flat_map(|(_, reqs)| reqs.iter().map(|&(g, _)| g))
            .collect()
    }

    /// Re-route every in-flight request of dead shard `s` onto the
    /// current (already shrunken) ring, keeping its gid — retried, never
    /// duplicated. Requests with no surviving home are dropped for good.
    fn failover(
        &mut self,
        s: usize,
        router: &ClusterRouter,
        extras: &mut [Vec<(u64, ServeRequest)>],
    ) {
        self.stats.failovers += 1;
        extras[s].clear();
        let parked = self.delayed_gids();
        let mine: Vec<(u64, ServeRequest)> = self
            .inflight
            .iter()
            .filter(|(g, (sh, _))| {
                *sh == s && !parked.contains(g) && !self.pending_gids.contains(g)
            })
            .map(|(&g, (_, r))| (g, r.clone()))
            .collect();
        for (g, r) in mine {
            match router.reroute(&r) {
                Some(t) => {
                    self.inflight.insert(g, (t, r.clone()));
                    extras[t].push((g, r));
                    self.stats.retries += 1;
                }
                None => {
                    self.inflight.remove(&g);
                    self.stats.dropped_requests += 1;
                }
            }
        }
    }

    /// Apply this epoch's directives: ring membership, failover or
    /// standby promotion, trips, and delayed deliveries. Returns
    /// per-shard `(cmd, trips, extra requests)` for the packet build.
    #[allow(clippy::type_complexity)]
    fn directives(
        &mut self,
        epoch: usize,
        router: &mut ClusterRouter,
    ) -> (Vec<ShardCmd>, Vec<Vec<(usize, bool)>>, Vec<Vec<(u64, ServeRequest)>>) {
        // Last barrier's demotions recycle into the spare pool; their
        // slots have been re-warming via `Standby` packets since.
        let recycled: Vec<usize> = self.demoted.drain(..).collect();
        self.spare_pool.extend(recycled);
        let n = self.n;
        let mut cmds = vec![ShardCmd::Run; n];
        let mut trips: Vec<Vec<(usize, bool)>> = vec![Vec::new(); n];
        let mut extras: Vec<Vec<(u64, ServeRequest)>> = vec![Vec::new(); n];
        for s in 0..n {
            let mut cmd = self.schedule[s].get(&epoch).copied().unwrap_or(ShardCmd::Run);
            // A fault that would empty the ring is skipped outright (and
            // not counted): scale-to-zero is rejected, never a panic. A
            // crash with a standby available keeps the ring full, so it
            // is allowed through to the promotion path.
            let ring_emptying = cmd == ShardCmd::Hang
                || (cmd == ShardCmd::Crash && self.spare_pool.is_empty());
            if ring_emptying
                && self.alive[s]
                && router.ring.contains(s)
                && router.ring.num_shards() == 1
            {
                self.unschedule_lifecycle(s, epoch);
                cmd = ShardCmd::Run;
            }
            match cmd {
                ShardCmd::Crash => {
                    if self.fault_starts[s].contains(&epoch) {
                        self.stats.faults_injected += 1;
                    }
                    if let Some(spare) = self.spare_pool.pop_front() {
                        // Warm promotion: the standby adopts the shard's
                        // ring position and in-flight ids at this same
                        // barrier — no ring shrink, no downtime epochs.
                        let old = self.assignment[s];
                        self.assignment[s] = spare;
                        self.demoted.push(old);
                        self.stats.standby_promotions += 1;
                        if !self.alive[s] {
                            // An escalated hang crashed a shard already
                            // off the ring — promotion revives it now.
                            self.alive[s] = true;
                            router.ring.add(s);
                        }
                        let parked = self.delayed_gids();
                        let mine: Vec<(u64, ServeRequest)> = self
                            .inflight
                            .iter()
                            .filter(|(g, (sh, _))| {
                                *sh == s && !parked.contains(g) && !self.pending_gids.contains(g)
                            })
                            .map(|(&g, (_, r))| (g, r.clone()))
                            .collect();
                        self.stats.retries += mine.len() as u64;
                        extras[s].extend(mine);
                        // The cold Down/Restart tail is moot: the shard
                        // never left service.
                        self.unschedule_lifecycle(s, epoch);
                        cmd = ShardCmd::Adopt;
                    } else {
                        router.ring.remove(s);
                        self.alive[s] = false;
                        self.failover(s, router, &mut extras);
                    }
                }
                ShardCmd::Hang => {
                    if self.alive[s] {
                        if self.fault_starts[s].contains(&epoch) {
                            self.stats.faults_injected += 1;
                        }
                        router.ring.remove(s);
                        self.alive[s] = false;
                    }
                }
                ShardCmd::Down => {}
                ShardCmd::Restart => {
                    self.alive[s] = true;
                    router.ring.add(s);
                    self.stats.restarts += 1;
                }
                ShardCmd::Run => {
                    if self.rejoin[s].remove(&epoch) {
                        self.alive[s] = true;
                        router.ring.add(s);
                    }
                }
                // Never scheduled for logical shards: `Standby` is the
                // pool fallback for unassigned slots, `Adopt` is set
                // above by promotion.
                ShardCmd::Standby | ShardCmd::Adopt => {}
            }
            cmds[s] = cmd;
            // Trips ride the packet; shards that are dead this epoch
            // ignore them (a fresh engine boots with every chiplet
            // online, so a stale trip-off is a harmless no-op).
            if let Some(t) = self.trips[s].remove(&epoch) {
                if !matches!(cmds[s], ShardCmd::Crash | ShardCmd::Down) {
                    for &(_, on) in &t {
                        if on {
                            self.stats.chiplet_trips += 1;
                            self.stats.faults_injected += 1;
                        }
                    }
                    trips[s] = t;
                }
            }
        }
        // Delayed batches come due: deliver to the original shard if it
        // is serving, otherwise re-route them like failover retries.
        if let Some(batches) = self.delayed.remove(&epoch) {
            for (orig, reqs) in batches {
                if self.alive[orig] && router.ring.contains(orig) {
                    extras[orig].extend(reqs);
                } else {
                    for (g, r) in reqs {
                        match router.reroute(&r) {
                            Some(t) => {
                                if self.track {
                                    self.inflight.insert(g, (t, r.clone()));
                                }
                                extras[t].push((g, r));
                                self.stats.retries += 1;
                            }
                            None => {
                                self.inflight.remove(&g);
                                self.stats.dropped_requests += 1;
                            }
                        }
                    }
                }
            }
        }
        self.stats.downtime_epochs += self.alive.iter().filter(|&&a| !a).count() as u64;
        (cmds, trips, extras)
    }

    /// Tag a routed batch with fresh global request ids (and track them
    /// in the ledger when a plan is active).
    fn assign_gids(&mut self, shard: usize, batch: Vec<ServeRequest>) -> Vec<(u64, ServeRequest)> {
        batch
            .into_iter()
            .map(|r| {
                let g = self.next_gid;
                self.next_gid += 1;
                if self.track {
                    self.inflight.insert(g, (shard, r.clone()));
                }
                (g, r)
            })
            .collect()
    }

    /// Apply mailbox faults to this shard's freshly routed batch.
    fn intercept(&mut self, epoch: usize, shard: usize, reqs: &mut Vec<(u64, ServeRequest)>) {
        if self.drops.remove(&(epoch, shard)) {
            self.stats.faults_injected += 1;
            self.stats.dropped_requests += reqs.len() as u64;
            for (g, _) in reqs.drain(..) {
                self.inflight.remove(&g);
            }
        }
        if let Some(k) = self.delays.remove(&(epoch, shard)) {
            self.stats.faults_injected += 1;
            if !reqs.is_empty() {
                self.delayed.entry(epoch + k).or_default().push((shard, std::mem::take(reqs)));
            }
        }
    }

    /// True when this shard's epoch report is scheduled to be lost.
    fn lose_report(&mut self, epoch: usize, shard: usize) -> bool {
        if self.losses.remove(&(epoch, shard)) {
            self.stats.reports_lost += 1;
            self.stats.faults_injected += 1;
            true
        } else {
            false
        }
    }

    /// Close ledger entries: each id settles exactly once (done *or*
    /// dropped), even when the epoch's telemetry report was lost.
    fn settle(&mut self, done_ids: &[u64], dropped_ids: &[u64]) {
        if !self.track {
            return;
        }
        for g in done_ids.iter().chain(dropped_ids) {
            self.inflight.remove(g);
        }
    }
}

/// Last-known substitute used on the telemetry plane before a shard's
/// first report (only reachable when a report-loss fault hits epoch 0).
fn baseline_report(shard: usize) -> EpochReport {
    EpochReport {
        shard,
        epoch: 0,
        peak_temp_k: 0.0,
        power_w: 0.0,
        completed: 0,
        queue_depth: 0,
        fifo_depth: 0,
        throttled: false,
        cap_gated: false,
        alive: true,
        done_ids: Vec::new(),
        dropped_ids: Vec::new(),
        stolen: Vec::new(),
    }
}

fn epoch_snapshot_json(
    epoch: usize,
    t_s: f64,
    reports: &[EpochReport],
    caps_w: &[f64],
    active: usize,
    down_shards: Option<usize>,
    stolen_requests: Option<usize>,
) -> Json {
    let mut pairs = vec![
        ("epoch", Json::Num(epoch as f64)),
        ("t_s", Json::Num(t_s)),
        ("active_shards", Json::Num(active as f64)),
        ("completed", Json::Num(reports.iter().map(|r| r.completed).sum::<u64>() as f64)),
        (
            "queue_depth",
            Json::Num(reports.iter().map(|r| r.queue_depth).sum::<usize>() as f64),
        ),
        (
            "peak_temp_k",
            Json::Num(reports.iter().map(|r| r.peak_temp_k).fold(0.0, f64::max)),
        ),
        ("power_w", Json::Num(reports.iter().map(|r| r.power_w).sum::<f64>())),
        ("caps_w", Json::arr_f64(caps_w)),
        (
            "throttled_shards",
            Json::Num(reports.iter().filter(|r| r.throttled).count() as f64),
        ),
        (
            "cap_gated_shards",
            Json::Num(reports.iter().filter(|r| r.cap_gated).count() as f64),
        ),
    ];
    if let Some(d) = down_shards {
        pairs.push(("down_shards", Json::Num(d as f64)));
    }
    if let Some(m) = stolen_requests {
        pairs.push(("stolen_requests", Json::Num(m as f64)));
    }
    Json::obj(pairs)
}

/// Run a sharded serving cluster to its horizon and merge the per-shard
/// telemetry into one fleet-wide report. See the module docs for the
/// architecture, the fault model, the steal plane, and the determinism
/// model.
pub fn run_cluster(
    cfg: ClusterConfig,
    source: Box<dyn TrafficSource>,
) -> Result<ClusterReport, ClusterError> {
    match cfg.sched.clone() {
        ShardSchedSpec::Simba => run_cluster_typed(cfg, source, |_slot, arch: &Arch, _seed| {
            crate::sched::SimbaSched::new(arch.clone())
        }),
        ShardSchedSpec::BigLittle => run_cluster_typed(cfg, source, |_slot, arch: &Arch, _seed| {
            crate::sched::BigLittleSched::new(arch.clone())
        }),
        ShardSchedSpec::Thermos { theta, fallback } => {
            use crate::sched::policy::NativeDdt;
            use crate::sched::state::{StateEncoder, NUM_CLUSTERS, STATE_DIM};
            use crate::sched::thermos::ThermosSched;
            use crate::serve::server::TenantRouter;
            let max_images = cfg.serve.sim.max_images;
            run_cluster_typed(cfg, source, move |_slot, arch: &Arch, seed| {
                let zoo = crate::workload::ModelZoo::new();
                let encoder = StateEncoder::new(arch, &zoo, max_images);
                let ddt = match &theta {
                    Some(t) => NativeDdt::new(STATE_DIM, NUM_CLUSTERS, t.clone()),
                    None => {
                        let mut rng = crate::util::rng::Rng::new(seed);
                        NativeDdt::init(STATE_DIM, NUM_CLUSTERS, &mut rng)
                    }
                };
                TenantRouter::new(ThermosSched::new(arch.clone(), encoder, ddt, fallback))
            })
        }
    }
}

/// Monomorphic cluster driver: one scheduler type for the whole fleet,
/// built per physical slot by `make(slot, arch, seed)`.
fn run_cluster_typed<S, F>(
    cfg: ClusterConfig,
    mut source: Box<dyn TrafficSource>,
    make: F,
) -> Result<ClusterReport, ClusterError>
where
    S: ServeSched + Send,
    F: Fn(usize, &Arch, u64) -> S + Sync,
{
    assert!(cfg.shards >= 1, "cluster needs at least one shard");
    let n = cfg.shards;
    let n_phys = n + cfg.spares;
    let ref_arch = Arch::paper_heterogeneous(cfg.noi);
    let budget_w = cfg
        .power_budget_w
        .unwrap_or_else(|| package_tdp_w(&ref_arch) * cfg.budget_frac * n as f64);
    let dt = ThermalParams::default().dt_s;
    let epoch_steps = ((cfg.epoch_s / dt).round() as usize).max(1);
    let total_epochs = ((cfg.duration_s / cfg.epoch_s).ceil() as usize).max(1);

    let cache = ProfileCache::new();
    let source_name = source.name().to_string();
    let scheduler_name = cfg.sched.name();
    let faults_on = cfg.faults.is_some();
    let steal_cfg = cfg.steal.clone();
    let steal_on = steal_cfg.is_some();
    let plan = cfg.faults.clone().unwrap_or_default();
    let mut sup = Supervisor::new(&plan, n, total_epochs, faults_on || steal_on, cfg.spares);
    let cost: Option<Arc<CostModel>> =
        steal_on.then(|| Arc::new(CostModel::new(&ref_arch, &cache)));
    let pool = match cfg.threads {
        Some(t) => WorkPool::new(t),
        None => WorkPool::global(),
    };

    // Slots borrow their arch; `archs` is declared first so it outlives
    // (and is dropped after) the slots.
    let archs: Vec<Arch> = (0..n_phys).map(|_| Arch::paper_heterogeneous(cfg.noi)).collect();
    let make = &make;
    let slots: Vec<Mutex<shard::ShardSlot<'_, S>>> = (0..n_phys)
        .map(|i| {
            let seed = cfg.serve.sim.seed.wrapping_add(i as u64 * 0x9e37);
            let params = ShardParams {
                id: i,
                noi: cfg.noi,
                serve: ServeConfig {
                    snapshot_every_s: 0.0,
                    sim: SimConfig { seed, ..cfg.serve.sim.clone() },
                    ..cfg.serve.clone()
                },
                sched: cfg.sched.clone(),
                epoch_steps,
                drain_max_s: cfg.drain_max_s,
                record_path: cfg.record_base.as_ref().map(|b| format!("{b}.shard{i}.jsonl")),
            };
            let arch = &archs[i];
            Mutex::new(shard::ShardSlot::new(
                params,
                cache.clone(),
                arch,
                Box::new(move || make(i, arch, seed)),
                cost.clone(),
            ))
        })
        .collect();

    let mut snapshots: Vec<Json> = Vec::new();
    let mut stats = RouteStats { routed: vec![0; n], ..Default::default() };
    let mut autoscaler = cfg.autoscale.clone().map(Autoscaler::new);
    let initial_active = match &autoscaler {
        Some(a) => a.cfg.min_shards.clamp(1, n),
        None => n,
    };
    let mut router = ClusterRouter::new(
        &(0..initial_active).collect::<Vec<usize>>(),
        cfg.vnodes,
        cfg.coalesce,
        cfg.max_batch_images,
    );
    let mut arbiter = Arbiter::new(ArbiterConfig::new(budget_w), n);
    let mut last_reports: Vec<EpochReport> = (0..n).map(baseline_report).collect();
    let mut caps_w = vec![budget_w / n as f64; n];
    let mut steal_stats = StealStats::default();
    // Stolen work reassigned at barrier `e` is delivered as extras at
    // `e + 1` (the donor's engine has already run epoch `e`).
    let mut pending_migrations: Vec<Vec<(u64, ServeRequest)>> = vec![Vec::new(); n];
    let mut run_err: Option<ClusterError> = None;

    // Coordinator: supervise, route, plan steals, barrier on the pool,
    // rebalance, autoscale.
    for epoch in 0..total_epochs {
        let (cmds, mut trip_sets, mut extras) = sup.directives(epoch, &mut router);
        if router.ring.is_empty() {
            run_err = Some(ClusterError::NoActiveShards);
            break;
        }
        // Deliver last barrier's steal migrations. The recipient may
        // have crashed since the plan was made — re-route those like
        // failover retries (their gids were skipped by failover exactly
        // so this path owns them).
        for to in 0..n {
            if pending_migrations[to].is_empty() {
                continue;
            }
            let due = std::mem::take(&mut pending_migrations[to]);
            for (g, r) in due {
                sup.pending_gids.remove(&g);
                if sup.inflight.get(&g).map(|e| e.0) != Some(to) {
                    continue;
                }
                if sup.alive[to] && router.ring.contains(to) {
                    extras[to].push((g, r));
                } else {
                    match router.reroute(&r) {
                        Some(t) => {
                            sup.inflight.insert(g, (t, r.clone()));
                            extras[t].push((g, r));
                            sup.stats.retries += 1;
                        }
                        None => {
                            sup.inflight.remove(&g);
                            sup.stats.dropped_requests += 1;
                        }
                    }
                }
            }
        }
        let t_end = (epoch as f64 + 1.0) * cfg.epoch_s;
        let arrivals = source.arrivals_until(t_end);
        let offered_rate = arrivals.len() as f64 / cfg.epoch_s;
        let mut batches = router.route_epoch(arrivals, n, &mut stats);
        let last = epoch + 1 == total_epochs;
        // Plan this epoch's steals from estimated backlogs (never on the
        // final epoch — delivery needs a next epoch to land in).
        let mut quota = vec![0.0; n];
        let mut planned: Vec<StealMove> = Vec::new();
        if let (Some(sc), Some(cm), false) = (&steal_cfg, &cost, last) {
            let eligible: Vec<usize> =
                (0..n).filter(|&s| sup.alive[s] && router.ring.contains(s)).collect();
            if eligible.len() >= 2 {
                let mut loads = vec![0.0; eligible.len()];
                // Ledger backlog: everything in flight on an eligible
                // shard (extras are already tracked there).
                for (owner, r) in sup.inflight.values() {
                    if let Some(k) = eligible.iter().position(|&e| e == *owner) {
                        loads[k] += cm.cost(r);
                    }
                }
                // Plus this epoch's freshly routed batch (gids not yet
                // assigned, so not yet in the ledger).
                for (k, &s) in eligible.iter().enumerate() {
                    loads[k] += batches[s].iter().map(|r| cm.cost(r)).sum::<f64>();
                }
                planned = steal_schedule(sc.seed, epoch as u64, &loads, sc.slack)
                    .into_iter()
                    .map(|m| StealMove {
                        from: eligible[m.from],
                        to: eligible[m.to],
                        cost_s: m.cost_s,
                    })
                    .collect();
                for m in &planned {
                    quota[m.from] += m.cost_s;
                }
            }
        }
        // Build this epoch's packets at their physical slots. Unfilled
        // slots (idle spares) fall back to `Standby` in the pool task.
        let mut pkts: Vec<Option<EpochPacket>> = (0..n_phys).map(|_| None).collect();
        for s in 0..n {
            let mut reqs = sup.assign_gids(s, std::mem::take(&mut batches[s]));
            sup.intercept(epoch, s, &mut reqs);
            reqs.append(&mut extras[s]);
            pkts[sup.assignment[s]] = Some(EpochPacket {
                reqs,
                cap_w: caps_w[s],
                last,
                cmd: cmds[s],
                trips: std::mem::take(&mut trip_sets[s]),
                steal_cost_s: quota[s],
            });
        }
        // Freshly demoted slots take the crash their shard absorbed.
        for &p in &sup.demoted {
            pkts[p] = Some(EpochPacket {
                reqs: Vec::new(),
                cap_w: 0.0,
                last,
                cmd: ShardCmd::Crash,
                trips: Vec::new(),
                steal_cost_s: 0.0,
            });
        }
        // Barrier: every slot steps once on the pool; exactly one report
        // per slot, dead, idle, or alive.
        let cells: Vec<Mutex<Option<EpochPacket>>> = pkts.into_iter().map(Mutex::new).collect();
        let phys_reports: Vec<EpochReport> = pool.run(n_phys, |p| {
            let pkt = lock_recover(&cells[p]).take().unwrap_or_else(|| EpochPacket {
                reqs: Vec::new(),
                cap_w: 0.0,
                last,
                cmd: ShardCmd::Standby,
                trips: Vec::new(),
                steal_cost_s: 0.0,
            });
            lock_recover(&slots[p]).epoch(pkt)
        });
        let mut reports: Vec<EpochReport> = (0..n)
            .map(|s| {
                let mut r = phys_reports[sup.assignment[s]].clone();
                r.shard = s;
                r
            })
            .collect();
        // The id ledger settles unconditionally — report loss only
        // blinds the telemetry plane, never the accounting plane. The
        // stolen backlog is harvested here for the same reason.
        let mut stolen_by_donor: Vec<Vec<(u64, ServeRequest)>> = vec![Vec::new(); n];
        for r in reports.iter_mut() {
            sup.settle(&r.done_ids, &r.dropped_ids);
            stolen_by_donor[r.shard] = std::mem::take(&mut r.stolen);
        }
        let mut alive_mask = vec![true; n];
        for r in reports.iter_mut() {
            let s = r.shard;
            alive_mask[s] = r.alive;
            if sup.lose_report(epoch, s) {
                let mut sub = last_reports[s].clone();
                sub.epoch = epoch;
                alive_mask[s] = sub.alive;
                *r = sub;
            } else {
                let mut known = r.clone();
                known.done_ids = Vec::new();
                known.dropped_ids = Vec::new();
                last_reports[s] = known;
            }
        }
        // Reassign the surrendered backlog along the planned routes;
        // delivery happens with the next epoch's packets.
        let mut migrated_now = 0usize;
        if !planned.is_empty() {
            steal_stats.planned_moves += planned.len() as u64;
            let mut routes: BTreeMap<usize, Vec<(usize, f64)>> = BTreeMap::new();
            for m in &planned {
                routes.entry(m.from).or_default().push((m.to, m.cost_s));
            }
            for (donor, route) in &routes {
                let mut di = 0;
                let mut acc = 0.0;
                for (g, r) in stolen_by_donor[*donor].drain(..) {
                    while di + 1 < route.len() && acc + 1e-12 >= route[di].1 {
                        di += 1;
                        acc = 0.0;
                    }
                    let to = route[di].0;
                    let c = cost.as_ref().map(|cm| cm.cost(&r)).unwrap_or(0.0);
                    acc += c;
                    migrated_now += 1;
                    steal_stats.migrated_cost_s += c;
                    sup.inflight.insert(g, (to, r.clone()));
                    sup.pending_gids.insert(g);
                    pending_migrations[to].push((g, r));
                }
            }
            steal_stats.migrated_requests += migrated_now as u64;
            if migrated_now > 0 {
                steal_stats.steal_epochs += 1;
            }
        }
        let peaks: Vec<f64> = reports.iter().map(|r| r.peak_temp_k).collect();
        caps_w = arbiter.rebalance_masked(&peaks, &alive_mask);
        if let Some(a) = autoscaler.as_mut() {
            let active = router.ring.num_shards();
            let target = a.target(offered_rate, active).clamp(1, n);
            while router.ring.num_shards() < target {
                match (0..n).find(|&i| !router.ring.contains(i) && sup.alive[i]) {
                    Some(i) => router.ring.add(i),
                    None => break,
                }
            }
            // Scale-to-zero is rejected: the last shard never drains.
            while router.ring.num_shards() > target && router.ring.num_shards() > 1 {
                match router.ring.shards().last().copied() {
                    Some(s) => router.ring.remove(s),
                    None => break,
                }
            }
        }
        snapshots.push(epoch_snapshot_json(
            epoch,
            t_end,
            &reports,
            &caps_w,
            router.ring.num_shards(),
            faults_on.then(|| alive_mask.iter().filter(|&&a| !a).count()),
            steal_on.then_some(migrated_now),
        ));
    }
    if let Some(e) = run_err {
        return Err(e);
    }

    // Drain every slot on the pool; spares drain trivially (no work).
    let mut results: Vec<ShardResult> = pool.run(n_phys, |p| lock_recover(&slots[p]).finish());
    results.sort_by_key(|r| r.id);
    // Close the ledger with ids settled during the post-horizon drain.
    for r in &results {
        sup.settle(&r.done_ids, &r.dropped_ids);
    }

    // Deterministic merge: fixed shard-id order.
    let mut merged = TelemetryHub::new();
    for r in &results {
        merged.merge(&r.hub);
    }
    let (offered_batches, admitted, rejected, shed, completed) = merged.totals();
    let num = |j: &Json, k: &str| j.get(k).as_f64().unwrap_or(0.0);
    let duration_s =
        results.iter().map(|r| num(&r.report.json, "duration_s")).fold(0.0, f64::max);
    let shards_detail: Vec<Json> = results
        .iter()
        .map(|r| {
            let j = &r.report.json;
            Json::obj(vec![
                ("id", Json::Num(r.id as f64)),
                ("offered", j.get("offered").clone()),
                ("rejected", j.get("rejected").clone()),
                ("shed", j.get("shed").clone()),
                ("shed_pressure", j.get("shed_pressure").clone()),
                ("completed", j.get("completed").clone()),
                ("images_done", j.get("images_done").clone()),
                ("max_temp_k", j.get("max_temp_k").clone()),
                ("throttle_events", j.get("throttle_events").clone()),
                ("cap_gated_steps", j.get("cap_gated_steps").clone()),
                ("system_energy_j", j.get("system_energy_j").clone()),
                ("host_stalls", j.get("host_stalls").clone()),
                ("duration_s", j.get("duration_s").clone()),
            ])
        })
        .collect();
    let autoscale_json = match &autoscaler {
        Some(a) => Json::obj(vec![
            ("scale_ups", Json::Num(a.scale_ups as f64)),
            ("scale_downs", Json::Num(a.scale_downs as f64)),
            ("active_final", Json::Num(router.ring.num_shards() as f64)),
        ]),
        None => Json::Null,
    };
    let mut pairs = vec![
        ("scheduler", Json::Str(scheduler_name.to_string())),
        ("source", Json::Str(source_name)),
        ("seed", Json::Num(cfg.serve.sim.seed as f64)),
        ("shards", Json::Num(n as f64)),
        ("epochs", Json::Num(total_epochs as f64)),
        ("epoch_s", Json::Num(cfg.epoch_s)),
        ("duration_s", Json::Num(duration_s)),
        ("offered", Json::Num(stats.offered as f64)),
        ("coalesced_requests", Json::Num(stats.coalesced as f64)),
        ("offered_batches", Json::Num(offered_batches as f64)),
        (
            "routed_per_shard",
            Json::Arr(stats.routed.iter().map(|&x| Json::Num(x as f64)).collect()),
        ),
        ("admitted", Json::Num(admitted as f64)),
        ("rejected", Json::Num(rejected as f64)),
        ("shed", Json::Num(shed as f64)),
        ("shed_pressure", Json::Num(merged.shed_pressure_total() as f64)),
        ("completed", Json::Num(completed as f64)),
        ("images_done", Json::Num(merged.images_done_total() as f64)),
        ("throughput_jobs_s", Json::Num(completed as f64 / duration_s.max(1e-9))),
        (
            "throughput_images_s",
            Json::Num(merged.images_done_total() as f64 / duration_s.max(1e-9)),
        ),
        ("latency_e2e_s", merged.e2e_all.to_json()),
        ("latency_exec_s", merged.exec_all.to_json()),
        ("energy_j", merged.energy_all.to_json()),
        ("tenants", merged.tenants_json()),
        (
            "max_temp_k",
            Json::Num(
                results.iter().map(|r| num(&r.report.json, "max_temp_k")).fold(0.0, f64::max),
            ),
        ),
        (
            "system_energy_j",
            Json::Num(results.iter().map(|r| num(&r.report.json, "system_energy_j")).sum::<f64>()),
        ),
        (
            "throttle_events",
            Json::Num(results.iter().map(|r| num(&r.report.json, "throttle_events")).sum::<f64>()),
        ),
        (
            "cap_gated_steps",
            Json::Num(results.iter().map(|r| num(&r.report.json, "cap_gated_steps")).sum::<f64>()),
        ),
        ("power_budget_w", Json::Num(budget_w)),
        (
            "arbiter",
            Json::obj(vec![
                ("budget_w", Json::Num(budget_w)),
                ("rebalances", Json::Num(arbiter.rebalances as f64)),
                ("epochs", Json::Num(arbiter.epochs as f64)),
                ("final_caps_w", Json::arr_f64(arbiter.caps_w())),
            ]),
        ),
        ("autoscaler", autoscale_json),
        ("shards_detail", Json::Arr(shards_detail)),
    ];
    // Mode-gated keys: fault-free, steal-free, spare-free digests stay
    // byte-identical to builds that predate each plane.
    if faults_on {
        pairs.push(("faults", sup.stats.to_json()));
    }
    if steal_on {
        pairs.push(("steal", steal_stats.to_json()));
    }
    if cfg.spares > 0 {
        pairs.push((
            "spares",
            Json::obj(vec![
                ("configured", Json::Num(cfg.spares as f64)),
                ("standby_promotions", Json::Num(sup.stats.standby_promotions as f64)),
                ("idle_final", Json::Num(sup.spare_pool.len() as f64)),
            ]),
        ));
    }
    let json = Json::obj(pairs);
    let digest = digest64(&json.to_string_compact());
    let (cache_hits, cache_misses) = cache.stats();
    Ok(ClusterReport {
        json,
        digest,
        snapshots,
        cache_hits,
        cache_misses,
        cache_entries: cache.len(),
    })
}

/// Convenience: a single-shard "cluster" is just a [`Server`] run — used
/// by tests comparing sharded and unsharded behavior.
pub fn single_node_report(
    cfg: &ClusterConfig,
    source: Box<dyn TrafficSource>,
) -> crate::serve::server::ServeReport {
    let arch = Arch::paper_heterogeneous(cfg.noi);
    match cfg.sched.clone() {
        ShardSchedSpec::Simba => {
            let sched = crate::sched::SimbaSched::new(arch.clone());
            Server::new(&arch, sched, source, cfg.serve.clone()).run()
        }
        ShardSchedSpec::BigLittle => {
            let sched = crate::sched::BigLittleSched::new(arch.clone());
            Server::new(&arch, sched, source, cfg.serve.clone()).run()
        }
        ShardSchedSpec::Thermos { theta, fallback } => {
            use crate::sched::policy::NativeDdt;
            use crate::sched::state::{StateEncoder, NUM_CLUSTERS, STATE_DIM};
            use crate::sched::thermos::ThermosSched;
            use crate::serve::server::TenantRouter;
            let zoo = crate::workload::ModelZoo::new();
            let encoder = StateEncoder::new(&arch, &zoo, cfg.serve.sim.max_images);
            let ddt = match theta {
                Some(t) => NativeDdt::new(STATE_DIM, NUM_CLUSTERS, t),
                None => {
                    let mut rng = crate::util::rng::Rng::new(cfg.serve.sim.seed);
                    NativeDdt::init(STATE_DIM, NUM_CLUSTERS, &mut rng)
                }
            };
            let sched = TenantRouter::new(ThermosSched::new(arch.clone(), encoder, ddt, fallback));
            Server::new(&arch, sched, source, cfg.serve.clone()).run()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::FaultEvent;
    use crate::serve::{PoissonSource, TenantClass};
    use crate::workload::DnnModel;

    #[test]
    fn tiny_cluster_runs_and_reports() {
        let cfg = ClusterConfig {
            shards: 2,
            duration_s: 8.0,
            drain_max_s: 10.0,
            serve: ServeConfig {
                duration_s: 8.0,
                tenant_queue_cap: 16,
                max_wait_s: 10.0,
                snapshot_every_s: 0.0,
                pressure_depth: 24,
                sim: SimConfig {
                    warmup_s: 0.0,
                    max_images: 200,
                    seed: 3,
                    ..SimConfig::default()
                },
            },
            sched: ShardSchedSpec::Simba,
            ..ClusterConfig::default()
        };
        let source = Box::new(PoissonSource::new(2.0, 30, 200, [1.0, 1.0, 1.0], 3));
        let report = run_cluster(cfg, source).expect("cluster run");
        assert_eq!(report.digest.len(), 16);
        assert_eq!(report.snapshots.len(), 8);
        assert!(report.json.get("offered").as_f64().expect("offered") > 0.0);
        assert!(report.json.get("completed").as_f64().expect("completed") > 0.0);
        assert_eq!(report.json.get("shards").as_f64().expect("shards"), 2.0);
        // Fault-free runs carry no fault telemetry at all — and no steal
        // or spare telemetry either when those planes are off.
        assert!(matches!(report.json.get("faults"), Json::Null));
        assert!(matches!(report.json.get("steal"), Json::Null));
        assert!(matches!(report.json.get("spares"), Json::Null));
        // Caps always sum to the budget.
        let budget = report.json.get("power_budget_w").as_f64().expect("budget");
        let caps = match report.json.get("arbiter").get("final_caps_w") {
            Json::Arr(xs) => xs.iter().map(|x| x.as_f64().expect("cap")).sum::<f64>(),
            other => panic!("final_caps_w not an array: {other:?}"),
        };
        assert!((caps - budget).abs() < 1e-6, "caps {caps} vs budget {budget}");
        // The shared profile cache saw traffic.
        assert!(report.cache_hits + report.cache_misses > 0);
    }

    #[test]
    fn steal_defaults_are_off() {
        let cfg = ClusterConfig::default();
        assert!(cfg.steal.is_none());
        assert_eq!(cfg.spares, 0);
        assert!(cfg.threads.is_none());
    }

    #[test]
    fn supervisor_compiles_crash_and_hang_lifecycles() {
        let plan = FaultPlan::new(vec![
            FaultEvent { epoch: 2, shard: 1, kind: FaultKind::ShardCrash { down_epochs: 2 } },
            FaultEvent { epoch: 3, shard: 0, kind: FaultKind::ShardHang { epochs: 4 } },
        ]);
        let sup = Supervisor::new(&plan, 2, 20, true, 0);
        assert_eq!(sup.schedule[1].get(&2), Some(&ShardCmd::Crash));
        assert_eq!(sup.schedule[1].get(&3), Some(&ShardCmd::Down));
        assert_eq!(sup.schedule[1].get(&4), Some(&ShardCmd::Restart));
        // A 4-epoch hang exceeds patience (2): two hung epochs, then the
        // supervisor escalates to a crash + restart.
        assert_eq!(sup.schedule[0].get(&3), Some(&ShardCmd::Hang));
        assert_eq!(sup.schedule[0].get(&4), Some(&ShardCmd::Hang));
        assert_eq!(sup.schedule[0].get(&5), Some(&ShardCmd::Crash));
        assert_eq!(sup.schedule[0].get(&6), Some(&ShardCmd::Restart));
    }

    #[test]
    fn supervisor_skips_a_crash_that_would_empty_the_ring() {
        let plan = FaultPlan::new(vec![FaultEvent {
            epoch: 0,
            shard: 0,
            kind: FaultKind::ShardCrash { down_epochs: 1 },
        }]);
        let mut sup = Supervisor::new(&plan, 1, 10, true, 0);
        let mut router = ClusterRouter::new(&[0], 8, false, 100);
        let (cmds, _, _) = sup.directives(0, &mut router);
        assert_eq!(cmds[0], ShardCmd::Run, "sole shard must not be crashed");
        assert_eq!(sup.stats.faults_injected, 0);
        assert!(router.ring.contains(0));
        // The lifecycle is unscheduled, not deferred: no phantom restart.
        let (cmds, _, _) = sup.directives(1, &mut router);
        assert_eq!(cmds[0], ShardCmd::Run);
        assert_eq!(sup.stats.restarts, 0);
    }

    #[test]
    fn failover_reroutes_inflight_and_settles_exactly_once() {
        let plan = FaultPlan::new(vec![FaultEvent {
            epoch: 1,
            shard: 0,
            kind: FaultKind::ShardCrash { down_epochs: 2 },
        }]);
        let mut sup = Supervisor::new(&plan, 2, 10, true, 0);
        let mut router = ClusterRouter::new(&[0, 1], 16, false, 100);
        let req = ServeRequest {
            t_s: 0.1,
            tenant: TenantClass::Exec,
            model: DnnModel::ResNet18,
            images: 50,
        };
        let tagged = sup.assign_gids(0, vec![req]);
        assert_eq!(tagged.len(), 1);
        let gid = tagged[0].0;
        let (cmds, _trips, extras) = sup.directives(1, &mut router);
        assert_eq!(cmds[0], ShardCmd::Crash);
        assert_eq!(sup.stats.failovers, 1);
        assert_eq!(sup.stats.retries, 1);
        assert!(
            extras[1].iter().any(|(g, _)| *g == gid),
            "in-flight work must land on the survivor"
        );
        assert!(!router.ring.contains(0));
        // The survivor reports the id done: the ledger closes, no dupes.
        sup.settle(&[gid], &[]);
        assert!(sup.inflight.is_empty());
        // The restart re-joins the ring after the down window.
        let (cmds, _, _) = sup.directives(3, &mut router);
        assert_eq!(cmds[0], ShardCmd::Restart);
        assert!(router.ring.contains(0));
        assert_eq!(sup.stats.restarts, 1);
    }

    #[test]
    fn warm_standby_promotes_instead_of_cold_restart() {
        let plan = FaultPlan::new(vec![FaultEvent {
            epoch: 2,
            shard: 1,
            kind: FaultKind::ShardCrash { down_epochs: 2 },
        }]);
        let mut sup = Supervisor::new(&plan, 2, 20, true, 1);
        assert_eq!(sup.spare_pool, VecDeque::from(vec![2]));
        let mut router = ClusterRouter::new(&[0, 1], 16, false, 100);
        let req = ServeRequest {
            t_s: 0.2,
            tenant: TenantClass::Balanced,
            model: DnnModel::MobileNetV3Large,
            images: 8,
        };
        let gid = sup.assign_gids(1, vec![req])[0].0;
        let (cmds, _, extras) = sup.directives(2, &mut router);
        // The crash is absorbed: the standby adopts the shard's slot.
        assert_eq!(cmds[1], ShardCmd::Adopt);
        assert_eq!(sup.assignment[1], 2);
        assert_eq!(sup.demoted, vec![1]);
        assert!(sup.alive[1], "promoted shard never leaves service");
        assert!(router.ring.contains(1));
        assert_eq!(sup.stats.standby_promotions, 1);
        assert_eq!(sup.stats.failovers, 0, "no cold failover happened");
        assert_eq!(sup.stats.retries, 1);
        assert!(
            extras[1].iter().any(|(g, _)| *g == gid),
            "in-flight work redelivers to the adopted slot"
        );
        // Next barrier: the demoted slot recycles into the spare pool
        // and the cold Down/Restart tail was unscheduled.
        let (cmds, _, _) = sup.directives(3, &mut router);
        assert_eq!(sup.spare_pool, VecDeque::from(vec![1]));
        assert_eq!(cmds[1], ShardCmd::Run);
        assert_eq!(sup.stats.restarts, 0);
        assert_eq!(sup.stats.downtime_epochs, 0);
    }

    #[test]
    fn mailbox_faults_drop_or_park_the_batch() {
        let plan = FaultPlan::new(vec![
            FaultEvent { epoch: 0, shard: 0, kind: FaultKind::MailboxDrop },
            FaultEvent { epoch: 1, shard: 1, kind: FaultKind::MailboxDelay { epochs: 2 } },
        ]);
        let mut sup = Supervisor::new(&plan, 2, 10, true, 0);
        let req = |t| ServeRequest {
            t_s: t,
            tenant: TenantClass::Energy,
            model: DnnModel::AlexNet,
            images: 10,
        };
        let mut dropped = sup.assign_gids(0, vec![req(0.0), req(0.1)]);
        sup.intercept(0, 0, &mut dropped);
        assert!(dropped.is_empty());
        assert_eq!(sup.stats.dropped_requests, 2);
        assert!(sup.inflight.is_empty(), "dropped ids leave the ledger");
        let mut delayed = sup.assign_gids(1, vec![req(1.0)]);
        sup.intercept(1, 1, &mut delayed);
        assert!(delayed.is_empty());
        // Two epochs later the batch comes due on the same shard.
        let mut router = ClusterRouter::new(&[0, 1], 16, false, 100);
        let (_, _, extras) = sup.directives(3, &mut router);
        assert_eq!(extras[1].len(), 1, "delayed batch must be delivered");
        assert_eq!(sup.stats.faults_injected, 2);
    }
}
