//! Deterministic work-stealing between shards.
//!
//! Consistent-hash routing keeps a model's weights and cached profiles
//! resident on one shard — and concentrates a *hot* model's entire load
//! there too. This module rebalances that skew at epoch barriers: the
//! coordinator estimates each shard's backlog in seconds (queued
//! requests × a cached canonical [`ExecProfile`] cost), computes a
//! seeded, order-stable steal schedule from most- to least-loaded
//! shards, and migrates whole requests (keeping their global ids, so
//! at-most-once settlement is untouched).
//!
//! Everything here is pure data + arithmetic: the schedule is a
//! function of `(seed, epoch, loads, slack)` alone, independent of
//! thread interleaving, so `serve --shards N --seed S --steal` is
//! digest-reproducible run-to-run and across `--threads` widths. The
//! schedule is also *permutation-stable*: relabeling shard ids permutes
//! the moves but never changes who-steals-from-whom by load (donors and
//! recipients are ordered by backlog value, ids only break exact ties) —
//! pinned by a property test in `util::testkit`.
//!
//! [`ExecProfile`]: crate::sim::ExecProfile
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

use crate::arch::Arch;
use crate::pim::ComputeModel;
use crate::serve::ServeRequest;
use crate::sim::{LayerAssignment, Mapping, ProfileCache};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::{DnnModel, ModelZoo};

/// Work-stealing knobs; `None` in the cluster config disables the whole
/// plane (and keeps merged digests byte-identical to non-stealing runs).
#[derive(Clone, Debug)]
pub struct StealConfig {
    /// Seed for the rotation of the recipient scan (the CLI defaults it
    /// to the run seed; `--steal-seed` overrides).
    pub seed: u64,
    /// Imbalance dead-band as a fraction of the mean backlog: shards
    /// within `mean · (1 ± slack)` are neither donors nor recipients, so
    /// near-balanced epochs migrate nothing.
    pub slack: f64,
}

impl Default for StealConfig {
    fn default() -> StealConfig {
        StealConfig { seed: 0, slack: 0.25 }
    }
}

/// One planned migration: pour up to `cost_s` seconds of backlog from
/// shard `from` to shard `to`. Shards surrender whole requests until the
/// quota is met, so actual migrated cost can undershoot the plan.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StealMove {
    pub from: usize,
    pub to: usize,
    pub cost_s: f64,
}

/// Steal counters for the merged report; only emitted (and digested)
/// when stealing is on.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StealStats {
    /// Planned donor→recipient moves over the run.
    pub planned_moves: u64,
    /// Whole requests actually migrated at barriers.
    pub migrated_requests: u64,
    /// Estimated backlog seconds carried by the migrated requests.
    pub migrated_cost_s: f64,
    /// Epochs in which at least one request migrated.
    pub steal_epochs: u64,
}

impl StealStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("planned_moves", Json::Num(self.planned_moves as f64)),
            ("migrated_requests", Json::Num(self.migrated_requests as f64)),
            ("migrated_cost_s", Json::Num(self.migrated_cost_s)),
            ("steal_epochs", Json::Num(self.steal_epochs as f64)),
        ])
    }
}

/// Per-model backlog cost oracle: seconds-per-image from the *canonical*
/// execution profile — every layer mapped wholly onto chiplet 0 of the
/// reference architecture — computed once per model through the shared
/// [`ProfileCache`]. The absolute number is a relative weight, not a
/// latency prediction: only backlog *ratios* matter to the schedule, and
/// the canonical mapping makes the estimate identical on every shard
/// (and so deterministic regardless of which shard computed it first).
#[derive(Clone, Debug)]
pub struct CostModel {
    /// `(model, estimated seconds per image)` — six entries, linear scan.
    per_image_s: Vec<(DnnModel, f64)>,
}

impl CostModel {
    pub fn new(arch: &Arch, cache: &ProfileCache) -> CostModel {
        let cm = ComputeModel::default();
        let zoo = ModelZoo::new();
        let per_image_s = DnnModel::all()
            .into_iter()
            .map(|m| {
                let dcg = zoo.dcg(m);
                let mapping = Mapping {
                    layers: dcg
                        .layers
                        .iter()
                        .map(|l| LayerAssignment { parts: vec![(0, l.weight_bits)] })
                        .collect(),
                };
                let p = cache.get_or_compute(arch, &cm, &dcg, &mapping);
                (m, p.bottleneck_s.max(1e-12))
            })
            .collect();
        CostModel { per_image_s }
    }

    /// Estimated backlog seconds for one queued request:
    /// `images × canonical seconds-per-image`.
    pub fn cost(&self, r: &ServeRequest) -> f64 {
        let per = self
            .per_image_s
            .iter()
            .find(|(m, _)| *m == r.model)
            .map(|&(_, c)| c)
            .unwrap_or(1e-6);
        per * r.images.max(1) as f64
    }
}

/// Compute the epoch's steal schedule by water-filling: donors (backlog
/// above `mean · (1 + slack)`) pour their excess over the mean into
/// recipients (below `mean · (1 − slack)`) up to the mean, donors in
/// descending and recipients in ascending backlog order. The recipient
/// scan starts at a seeded rotation — `Rng::new(seed ^ epoch · GOLDEN)`
/// — so repeated ties do not always favor the same shard, yet the same
/// `(seed, epoch, loads)` always yields the same schedule.
pub fn steal_schedule(seed: u64, epoch: u64, loads: &[f64], slack: f64) -> Vec<StealMove> {
    let n = loads.len();
    if n < 2 {
        return Vec::new();
    }
    // Sum in value order, not index order: float addition is not
    // associative, so this is what makes the schedule commute with
    // shard-id relabeling *bit-exactly* (for distinct loads).
    let mut sorted = loads.to_vec();
    sorted.sort_by(f64::total_cmp);
    let mean = sorted.iter().sum::<f64>() / n as f64;
    if mean <= 0.0 {
        return Vec::new();
    }
    let slack = slack.max(0.0);
    let hi = mean * (1.0 + slack);
    let lo = mean * (1.0 - slack);
    let mut donors: Vec<usize> = (0..n).filter(|&i| loads[i] > hi).collect();
    let mut recips: Vec<usize> = (0..n).filter(|&i| loads[i] < lo).collect();
    if donors.is_empty() || recips.is_empty() {
        return Vec::new();
    }
    // Order by backlog value — ids only break exact ties — so the
    // schedule commutes with shard-id relabeling.
    donors.sort_by(|&a, &b| loads[b].total_cmp(&loads[a]).then(a.cmp(&b)));
    recips.sort_by(|&a, &b| loads[a].total_cmp(&loads[b]).then(a.cmp(&b)));
    let mut rng = Rng::new(seed ^ epoch.wrapping_mul(0x9e3779b97f4a7c15));
    let mut cursor = rng.below(recips.len());
    let mut room: Vec<f64> = recips.iter().map(|&i| mean - loads[i]).collect();
    let mut moves = Vec::new();
    for &d in &donors {
        let mut excess = loads[d] - mean;
        let mut visited = 0;
        while excess > 1e-9 && visited < recips.len() {
            let k = cursor % recips.len();
            if room[k] <= 1e-9 {
                cursor += 1;
                visited += 1;
                continue;
            }
            let take = excess.min(room[k]);
            moves.push(StealMove { from: d, to: recips[k], cost_s: take });
            excess -= take;
            room[k] -= take;
            if room[k] <= 1e-9 {
                cursor += 1;
            }
            visited += 1;
        }
    }
    moves
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_is_deterministic_per_seed_epoch_loads() {
        let loads = [9.0, 1.0, 2.0, 8.0, 0.5];
        let a = steal_schedule(7, 3, &loads, 0.25);
        let b = steal_schedule(7, 3, &loads, 0.25);
        assert_eq!(a, b);
        assert!(!a.is_empty(), "skewed loads must plan moves");
    }

    #[test]
    fn balanced_loads_plan_nothing() {
        assert!(steal_schedule(1, 0, &[4.0, 4.0, 4.0, 4.0], 0.25).is_empty());
        // Within the slack dead-band: still nothing.
        assert!(steal_schedule(1, 0, &[4.0, 4.4, 3.7, 4.1], 0.25).is_empty());
        // Degenerate shapes.
        assert!(steal_schedule(1, 0, &[5.0], 0.25).is_empty());
        assert!(steal_schedule(1, 0, &[0.0, 0.0], 0.25).is_empty());
    }

    #[test]
    fn moves_flow_downhill_and_conserve_excess() {
        let loads = [12.0, 1.0, 3.0, 2.0];
        let mean = loads.iter().sum::<f64>() / loads.len() as f64;
        let moves = steal_schedule(42, 5, &loads, 0.25);
        assert!(!moves.is_empty());
        let mut poured = 0.0;
        for m in &moves {
            assert!(loads[m.from] > mean, "donor {} not above mean", m.from);
            assert!(loads[m.to] < mean, "recipient {} not below mean", m.to);
            assert!(m.cost_s > 0.0);
            poured += m.cost_s;
        }
        // A donor never pours more than its excess over the mean.
        assert!(poured <= loads[0] - mean + 1e-9, "poured {poured}");
        // And no recipient is filled past the mean.
        let mut filled = vec![0.0; loads.len()];
        for m in &moves {
            filled[m.to] += m.cost_s;
            assert!(loads[m.to] + filled[m.to] <= mean + 1e-9);
        }
    }

    #[test]
    fn rotation_depends_only_on_seed_epoch_and_count() {
        // Same count of recipients, wildly different values: the scan
        // offset matches, so only values decide the pairing.
        let a = steal_schedule(9, 2, &[10.0, 1.0, 2.0], 0.1);
        let b = steal_schedule(9, 2, &[20.0, 3.0, 5.0], 0.1);
        assert_eq!(
            a.iter().map(|m| (m.from, m.to)).collect::<Vec<_>>(),
            b.iter().map(|m| (m.from, m.to)).collect::<Vec<_>>(),
        );
    }
}
