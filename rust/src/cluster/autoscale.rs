//! Utilization-threshold autoscaler for the shard fleet.
//!
//! Each epoch the coordinator feeds the autoscaler the offered job rate;
//! it compares utilization (offered / aggregate shard capacity) against
//! hysteresis thresholds and grows or shrinks the *active* shard set by
//! one, with a cooldown between decisions. Draining is graceful: a
//! removed shard leaves the hash ring (no new requests) but its worker
//! keeps stepping, finishing in-flight work, and still participates in
//! the arbiter barrier — so scaling decisions, which depend only on the
//! deterministic offered stream, never break run-to-run reproducibility.

#[derive(Clone, Debug)]
pub struct AutoscaleConfig {
    pub min_shards: usize,
    pub max_shards: usize,
    /// Scale up when utilization exceeds this.
    pub hi_util: f64,
    /// Scale down when utilization falls below this.
    pub lo_util: f64,
    /// Nominal sustained capacity of one shard (jobs/s), the utilization
    /// denominator. The paper-scale package saturates around ~2 jobs/s.
    pub shard_capacity_jobs_s: f64,
    /// Epochs to wait after a scaling decision before the next one.
    pub cooldown_epochs: usize,
}

impl Default for AutoscaleConfig {
    fn default() -> AutoscaleConfig {
        AutoscaleConfig {
            min_shards: 1,
            max_shards: 4,
            hi_util: 0.85,
            lo_util: 0.30,
            shard_capacity_jobs_s: 2.0,
            cooldown_epochs: 5,
        }
    }
}

#[derive(Debug)]
pub struct Autoscaler {
    pub cfg: AutoscaleConfig,
    cooldown: usize,
    pub scale_ups: u64,
    pub scale_downs: u64,
}

impl Autoscaler {
    pub fn new(cfg: AutoscaleConfig) -> Autoscaler {
        Autoscaler { cfg, cooldown: 0, scale_ups: 0, scale_downs: 0 }
    }

    /// Decide the active-shard count for the next epoch given this
    /// epoch's offered rate and the current active count.
    pub fn target(&mut self, offered_jobs_s: f64, active: usize) -> usize {
        if self.cooldown > 0 {
            self.cooldown -= 1;
            return active;
        }
        let capacity = (active as f64 * self.cfg.shard_capacity_jobs_s).max(1e-9);
        let util = offered_jobs_s / capacity;
        if util > self.cfg.hi_util && active < self.cfg.max_shards {
            self.cooldown = self.cfg.cooldown_epochs;
            self.scale_ups += 1;
            active + 1
        } else if util < self.cfg.lo_util && active > self.cfg.min_shards {
            self.cooldown = self.cfg.cooldown_epochs;
            self.scale_downs += 1;
            active - 1
        } else {
            active
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AutoscaleConfig {
        AutoscaleConfig { cooldown_epochs: 2, ..AutoscaleConfig::default() }
    }

    #[test]
    fn scales_up_under_load_with_cooldown() {
        let mut a = Autoscaler::new(cfg());
        // 4 jobs/s on one 2 jobs/s shard → 200% utilization.
        assert_eq!(a.target(4.0, 1), 2);
        // Cooldown holds for the next 2 epochs.
        assert_eq!(a.target(4.0, 2), 2);
        assert_eq!(a.target(4.0, 2), 2);
        // Still over 85% of 2 shards → up again.
        assert_eq!(a.target(4.0, 2), 3);
        assert_eq!(a.scale_ups, 2);
    }

    #[test]
    fn scales_down_when_idle_and_respects_bounds() {
        let mut a = Autoscaler::new(cfg());
        // 0.5 jobs/s on 3 shards → 8% utilization.
        assert_eq!(a.target(0.5, 3), 2);
        a.cooldown = 0;
        assert_eq!(a.target(0.5, 2), 1);
        a.cooldown = 0;
        // Never below min_shards.
        assert_eq!(a.target(0.0, 1), 1);
        // Never above max_shards.
        let mut b = Autoscaler::new(cfg());
        assert_eq!(b.target(100.0, 4), 4);
        assert_eq!(a.scale_downs, 2);
    }

    #[test]
    fn steady_load_holds_steady() {
        let mut a = Autoscaler::new(cfg());
        // 1.2 jobs/s on one shard → 60%, inside [30%, 85%].
        for _ in 0..10 {
            assert_eq!(a.target(1.2, 1), 1);
        }
        assert_eq!((a.scale_ups, a.scale_downs), (0, 0));
    }
}
