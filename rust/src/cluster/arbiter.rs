//! The global power/thermal arbiter: owns the package power budget and
//! redistributes per-shard caps at every telemetry epoch barrier.
//!
//! Each epoch every shard reports its peak chiplet temperature; the
//! arbiter reslices the fixed total budget headroom-weighted — shards far
//! below the reference temperature (coolest PIM `t_max`, 330 K) gain
//! budget, shards at or above it fall to a floor share. The sum of caps
//! over *alive* shards always equals the budget (conservation): a dead
//! shard's slice is reclaimed and redistributed over the survivors until
//! the supervisor restarts it. Caps are enforced by the engine's
//! mapping-time admission gate, and since the coordinator collects the
//! reports at a barrier and sorts them by shard id, the redistribution is
//! deterministic regardless of thread scheduling.

use crate::arch::Arch;

/// Sum of every chiplet's peak power (full-rate MACs + leakage) — the
/// package TDP the default budget is derived from.
pub fn package_tdp_w(arch: &Arch) -> f64 {
    arch.chiplets
        .iter()
        .map(|c| {
            let spec = &arch.specs[c.pim as usize];
            spec.rate_mac_s * spec.energy_per_mac_j + spec.leakage_w
        })
        .sum()
}

#[derive(Clone, Debug)]
pub struct ArbiterConfig {
    /// Total cluster power budget (W), shared across shards.
    pub budget_w: f64,
    /// Reference temperature (K): headroom is measured against this.
    /// Default 330 K — the ReRAM clusters' Eq. 2 limit, the first wall a
    /// heterogeneous package hits.
    pub t_ref_k: f64,
    /// Fraction of the fair share (`budget / n_alive`) every alive shard
    /// keeps even when hot, so a throttled shard can still drain
    /// in-flight work.
    pub floor_frac: f64,
}

impl ArbiterConfig {
    pub fn new(budget_w: f64) -> ArbiterConfig {
        ArbiterConfig { budget_w, t_ref_k: 330.0, floor_frac: 0.25 }
    }
}

pub struct Arbiter {
    cfg: ArbiterConfig,
    n: usize,
    caps_w: Vec<f64>,
    /// Epochs on which the redistribution moved any cap by > 1 mW.
    pub rebalances: u64,
    pub epochs: u64,
}

impl Arbiter {
    pub fn new(cfg: ArbiterConfig, n_shards: usize) -> Arbiter {
        assert!(n_shards >= 1);
        assert!(cfg.budget_w > 0.0, "power budget must be positive");
        let fair = cfg.budget_w / n_shards as f64;
        Arbiter { cfg, n: n_shards, caps_w: vec![fair; n_shards], rebalances: 0, epochs: 0 }
    }

    pub fn caps_w(&self) -> &[f64] {
        &self.caps_w
    }

    /// Redistribute the budget from per-shard peak temperatures:
    /// `cap_i = floor + pool · w_i / Σw` with `w_i = max(t_ref − T_i, ε)`.
    /// Conserves the budget exactly (up to float rounding).
    pub fn rebalance(&mut self, peak_temp_k: &[f64]) -> Vec<f64> {
        let alive = vec![true; self.n];
        self.rebalance_masked(peak_temp_k, &alive)
    }

    /// [`Arbiter::rebalance`] with a liveness mask: dead shards get a 0 W
    /// cap and their slice is reclaimed into the pool shared by the alive
    /// shards (whose caps still sum to the full budget). With every shard
    /// alive this is arithmetically identical — same operations in the
    /// same order — to the unmasked path, so fault-free runs keep their
    /// exact digests.
    pub fn rebalance_masked(&mut self, peak_temp_k: &[f64], alive: &[bool]) -> Vec<f64> {
        assert_eq!(peak_temp_k.len(), self.n);
        assert_eq!(alive.len(), self.n);
        let n_alive = alive.iter().filter(|&&a| a).count();
        let new: Vec<f64> = if n_alive == 0 {
            // Nothing to power: an all-dead epoch parks the budget.
            vec![0.0; self.n]
        } else {
            let fair = self.cfg.budget_w / n_alive as f64;
            let floor = fair * self.cfg.floor_frac.clamp(0.0, 1.0);
            let pool = self.cfg.budget_w - floor * n_alive as f64;
            let weights: Vec<f64> = peak_temp_k
                .iter()
                .zip(alive)
                .map(|(&t, &a)| if a { (self.cfg.t_ref_k - t).max(0.5) } else { 0.0 })
                .collect();
            let wsum: f64 = weights.iter().sum();
            weights
                .iter()
                .zip(alive)
                .map(|(w, &a)| if a { floor + pool * w / wsum } else { 0.0 })
                .collect()
        };
        if new
            .iter()
            .zip(self.caps_w.iter())
            .any(|(a, b)| (a - b).abs() > 1e-3)
        {
            self.rebalances += 1;
        }
        self.epochs += 1;
        self.caps_w = new.clone();
        new
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noi::NoiTopology;

    #[test]
    fn package_tdp_is_plausible() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let tdp = package_tdp_w(&arch);
        // 78 chiplets at 0.06–0.26 W each.
        assert!((5.0..50.0).contains(&tdp), "tdp {tdp}");
    }

    #[test]
    fn rebalance_conserves_budget_and_favors_cool_shards() {
        let mut arb = Arbiter::new(ArbiterConfig::new(12.0), 4);
        let caps = arb.rebalance(&[300.0, 310.0, 320.0, 329.0]);
        let total: f64 = caps.iter().sum();
        assert!((total - 12.0).abs() < 1e-9, "budget not conserved: {total}");
        // Strictly decreasing caps with increasing temperature.
        for w in caps.windows(2) {
            assert!(w[0] > w[1], "hotter shard got more budget: {caps:?}");
        }
        assert_eq!(arb.rebalances, 1);
    }

    #[test]
    fn equal_temps_get_equal_caps_and_hot_shards_hit_the_floor() {
        let mut arb = Arbiter::new(ArbiterConfig::new(8.0), 2);
        let caps = arb.rebalance(&[305.0, 305.0]);
        assert!((caps[0] - caps[1]).abs() < 1e-12);
        assert!((caps[0] - 4.0).abs() < 1e-9);
        // One shard at/above t_ref keeps only ~the floor share.
        let caps = arb.rebalance(&[360.0, 300.0]);
        let floor = 4.0 * 0.25;
        assert!(caps[0] < floor + 0.1, "hot shard cap {} ≫ floor {floor}", caps[0]);
        assert!((caps[0] + caps[1] - 8.0).abs() < 1e-9);
    }

    #[test]
    fn dead_shards_lose_their_slice_to_the_survivors() {
        let mut arb = Arbiter::new(ArbiterConfig::new(12.0), 4);
        let temps = [305.0, 305.0, 305.0, 305.0];
        let caps = arb.rebalance_masked(&temps, &[true, false, true, true]);
        assert_eq!(caps[1], 0.0, "dead shard must hold no budget");
        let alive_total: f64 = caps.iter().sum();
        assert!((alive_total - 12.0).abs() < 1e-9, "reclaimed budget not conserved");
        // Equal temps: survivors split evenly at budget / 3.
        for &c in [caps[0], caps[2], caps[3]].iter() {
            assert!((c - 4.0).abs() < 1e-9, "caps {caps:?}");
        }
        // Masked all-alive path is bit-identical to the legacy path.
        let mut a = Arbiter::new(ArbiterConfig::new(12.0), 4);
        let mut b = Arbiter::new(ArbiterConfig::new(12.0), 4);
        let temps = [301.0, 317.5, 322.25, 328.0];
        let ca = a.rebalance(&temps);
        let cb = b.rebalance_masked(&temps, &[true; 4]);
        assert_eq!(ca, cb);
        // All-dead epoch parks the whole budget.
        let caps = arb.rebalance_masked(&temps, &[false; 4]);
        assert!(caps.iter().all(|&c| c == 0.0));
    }
}
