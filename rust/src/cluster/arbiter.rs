//! The global power/thermal arbiter: one thread owning the package power
//! budget, redistributing per-shard caps every telemetry epoch.
//!
//! Each epoch every shard reports its peak chiplet temperature; the
//! arbiter reslices the fixed total budget headroom-weighted — shards far
//! below the reference temperature (coolest PIM `t_max`, 330 K) gain
//! budget, shards at or above it fall to a floor share. The sum of caps
//! always equals the budget (conservation), caps are enforced by the
//! engine's mapping-time admission gate, and since reports are collected
//! at a barrier and sorted by shard id, the redistribution is
//! deterministic regardless of thread scheduling.

use super::shard::EpochReport;
use crate::arch::Arch;
use std::sync::mpsc::{Receiver, Sender};

/// Sum of every chiplet's peak power (full-rate MACs + leakage) — the
/// package TDP the default budget is derived from.
pub fn package_tdp_w(arch: &Arch) -> f64 {
    arch.chiplets
        .iter()
        .map(|c| {
            let spec = &arch.specs[c.pim as usize];
            spec.rate_mac_s * spec.energy_per_mac_j + spec.leakage_w
        })
        .sum()
}

#[derive(Clone, Debug)]
pub struct ArbiterConfig {
    /// Total cluster power budget (W), shared across shards.
    pub budget_w: f64,
    /// Reference temperature (K): headroom is measured against this.
    /// Default 330 K — the ReRAM clusters' Eq. 2 limit, the first wall a
    /// heterogeneous package hits.
    pub t_ref_k: f64,
    /// Fraction of the fair share (`budget / n`) every shard keeps even
    /// when hot, so a throttled shard can still drain in-flight work.
    pub floor_frac: f64,
}

impl ArbiterConfig {
    pub fn new(budget_w: f64) -> ArbiterConfig {
        ArbiterConfig { budget_w, t_ref_k: 330.0, floor_frac: 0.25 }
    }
}

/// Caps-and-reports message the arbiter sends back each epoch.
pub type EpochOutcome = (Vec<f64>, Vec<EpochReport>);

pub struct Arbiter {
    cfg: ArbiterConfig,
    n: usize,
    caps_w: Vec<f64>,
    /// Epochs on which the redistribution moved any cap by > 1 mW.
    pub rebalances: u64,
    pub epochs: u64,
}

impl Arbiter {
    pub fn new(cfg: ArbiterConfig, n_shards: usize) -> Arbiter {
        assert!(n_shards >= 1);
        assert!(cfg.budget_w > 0.0, "power budget must be positive");
        let fair = cfg.budget_w / n_shards as f64;
        Arbiter { cfg, n: n_shards, caps_w: vec![fair; n_shards], rebalances: 0, epochs: 0 }
    }

    pub fn caps_w(&self) -> &[f64] {
        &self.caps_w
    }

    /// Redistribute the budget from per-shard peak temperatures:
    /// `cap_i = floor + pool · w_i / Σw` with `w_i = max(t_ref − T_i, ε)`.
    /// Conserves the budget exactly (up to float rounding).
    pub fn rebalance(&mut self, peak_temp_k: &[f64]) -> Vec<f64> {
        assert_eq!(peak_temp_k.len(), self.n);
        let fair = self.cfg.budget_w / self.n as f64;
        let floor = fair * self.cfg.floor_frac.clamp(0.0, 1.0);
        let pool = self.cfg.budget_w - floor * self.n as f64;
        let weights: Vec<f64> =
            peak_temp_k.iter().map(|&t| (self.cfg.t_ref_k - t).max(0.5)).collect();
        let wsum: f64 = weights.iter().sum();
        let new: Vec<f64> = weights.iter().map(|w| floor + pool * w / wsum).collect();
        if new
            .iter()
            .zip(self.caps_w.iter())
            .any(|(a, b)| (a - b).abs() > 1e-3)
        {
            self.rebalances += 1;
        }
        self.epochs += 1;
        self.caps_w = new.clone();
        new
    }

    /// Arbiter thread body: each epoch, collect exactly one report per
    /// shard (a barrier), sort by shard id (determinism), rebalance, and
    /// send the new caps plus the sorted reports to the coordinator.
    /// Returns itself so the coordinator can read final caps/counters.
    pub fn run(
        mut self,
        reports_rx: Receiver<EpochReport>,
        outcome_tx: Sender<EpochOutcome>,
        total_epochs: usize,
    ) -> Arbiter {
        for _ in 0..total_epochs {
            let mut reports = Vec::with_capacity(self.n);
            for _ in 0..self.n {
                match reports_rx.recv() {
                    Ok(r) => reports.push(r),
                    Err(_) => return self, // a shard died; stop arbitrating
                }
            }
            reports.sort_by_key(|r| r.shard);
            let peaks: Vec<f64> = reports.iter().map(|r| r.peak_temp_k).collect();
            let caps = self.rebalance(&peaks);
            if outcome_tx.send((caps, reports)).is_err() {
                return self;
            }
        }
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noi::NoiTopology;

    #[test]
    fn package_tdp_is_plausible() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let tdp = package_tdp_w(&arch);
        // 78 chiplets at 0.06–0.26 W each.
        assert!((5.0..50.0).contains(&tdp), "tdp {tdp}");
    }

    #[test]
    fn rebalance_conserves_budget_and_favors_cool_shards() {
        let mut arb = Arbiter::new(ArbiterConfig::new(12.0), 4);
        let caps = arb.rebalance(&[300.0, 310.0, 320.0, 329.0]);
        let total: f64 = caps.iter().sum();
        assert!((total - 12.0).abs() < 1e-9, "budget not conserved: {total}");
        // Strictly decreasing caps with increasing temperature.
        for w in caps.windows(2) {
            assert!(w[0] > w[1], "hotter shard got more budget: {caps:?}");
        }
        assert_eq!(arb.rebalances, 1);
    }

    #[test]
    fn equal_temps_get_equal_caps_and_hot_shards_hit_the_floor() {
        let mut arb = Arbiter::new(ArbiterConfig::new(8.0), 2);
        let caps = arb.rebalance(&[305.0, 305.0]);
        assert!((caps[0] - caps[1]).abs() < 1e-12);
        assert!((caps[0] - 4.0).abs() < 1e-9);
        // One shard at/above t_ref keeps only ~the floor share.
        let caps = arb.rebalance(&[360.0, 300.0]);
        let floor = 4.0 * 0.25;
        assert!(caps[0] < floor + 0.1, "hot shard cap {} ≫ floor {floor}", caps[0]);
        assert!((caps[0] + caps[1] - 8.0).abs() < 1e-9);
    }
}
