//! Consistent-hash request routing with same-model batch coalescing.
//!
//! Requests are keyed by model fingerprint, so every request for a given
//! model lands on the same shard — that shard's chiplets keep the model's
//! weights resident and its [`crate::sim::ProfileCache`] entries hot.
//! The ring uses virtual nodes for balance; adding or removing one shard
//! remaps only ~K/N of the key population (the property test below pins
//! this down).
//!
//! Within one epoch's batch for a shard, requests for the same
//! `(model, tenant)` pair are coalesced into a single engine job (image
//! counts add, bounded by `max_batch_images`; the batch keeps the
//! earliest member's arrival time) — the Sangam-style batching lever for
//! chiplet-PIM serving throughput.

use crate::serve::ServeRequest;
use crate::util::stats::fnv1a64;

/// Consistent-hash ring over shard ids with virtual nodes.
#[derive(Clone, Debug)]
pub struct HashRing {
    /// Sorted `(point, shard)` pairs.
    points: Vec<(u64, usize)>,
    vnodes: usize,
}

impl HashRing {
    pub fn new(shards: &[usize], vnodes: usize) -> HashRing {
        let mut ring = HashRing { points: Vec::new(), vnodes: vnodes.max(1) };
        for &s in shards {
            ring.add(s);
        }
        ring
    }

    fn point(shard: usize, vnode: usize) -> u64 {
        fnv1a64(format!("shard-{shard}-vnode-{vnode}").as_bytes())
    }

    pub fn add(&mut self, shard: usize) {
        if self.contains(shard) {
            return;
        }
        for v in 0..self.vnodes {
            self.points.push((Self::point(shard, v), shard));
        }
        self.points.sort_unstable();
    }

    pub fn remove(&mut self, shard: usize) {
        self.points.retain(|&(_, s)| s != shard);
    }

    pub fn contains(&self, shard: usize) -> bool {
        self.points.iter().any(|&(_, s)| s == shard)
    }

    /// Active shard ids, sorted.
    pub fn shards(&self) -> Vec<usize> {
        let mut ids: Vec<usize> = self.points.iter().map(|&(_, s)| s).collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    pub fn num_shards(&self) -> usize {
        self.shards().len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// The shard owning `key`: the first ring point at or after the key,
    /// wrapping around.
    pub fn shard_for(&self, key: u64) -> usize {
        assert!(!self.points.is_empty(), "routing on an empty ring");
        let idx = self.points.partition_point(|&(p, _)| p < key);
        let idx = if idx == self.points.len() { 0 } else { idx };
        self.points[idx].1
    }

    /// Non-panicking [`HashRing::shard_for`]: `None` when the ring is
    /// empty (every shard dead or drained) so the caller can surface a
    /// [`crate::fault::ClusterError::NoActiveShards`] instead of crashing
    /// the coordinator.
    pub fn try_shard_for(&self, key: u64) -> Option<usize> {
        if self.points.is_empty() {
            None
        } else {
            Some(self.shard_for(key))
        }
    }
}

/// Per-run routing counters.
#[derive(Clone, Debug, Default)]
pub struct RouteStats {
    /// Raw requests offered to the router.
    pub offered: u64,
    /// Requests absorbed into an existing batch.
    pub coalesced: u64,
    /// Batches (engine jobs) actually emitted.
    pub batches: u64,
    /// Raw requests routed to each shard id.
    pub routed: Vec<u64>,
}

/// The cluster-level request router: consistent-hash placement plus
/// per-epoch same-model coalescing.
pub struct ClusterRouter {
    pub ring: HashRing,
    coalesce: bool,
    max_batch_images: u64,
}

impl ClusterRouter {
    pub fn new(
        active_shards: &[usize],
        vnodes: usize,
        coalesce: bool,
        max_batch_images: u64,
    ) -> ClusterRouter {
        ClusterRouter {
            ring: HashRing::new(active_shards, vnodes),
            coalesce,
            max_batch_images: max_batch_images.max(1),
        }
    }

    /// Routing key: the model fingerprint, so same-model requests are
    /// always co-located on one shard.
    pub fn key_of(req: &ServeRequest) -> u64 {
        fnv1a64(req.model.name().as_bytes())
    }

    /// Route one epoch of arrivals into per-shard batches (indexed by
    /// shard id over `0..n_shards`; inactive shards get empty batches).
    pub fn route_epoch(
        &self,
        arrivals: Vec<ServeRequest>,
        n_shards: usize,
        stats: &mut RouteStats,
    ) -> Vec<Vec<ServeRequest>> {
        let mut out: Vec<Vec<ServeRequest>> = vec![Vec::new(); n_shards];
        for req in arrivals {
            stats.offered += 1;
            let shard = self.ring.shard_for(Self::key_of(&req));
            stats.routed[shard] += 1;
            let batch = &mut out[shard];
            if self.coalesce {
                if let Some(b) = batch.iter_mut().find(|b| {
                    b.model == req.model
                        && b.tenant == req.tenant
                        && b.images + req.images <= self.max_batch_images
                }) {
                    // Absorb: images add, the batch keeps the earliest
                    // member's arrival time (arrival order ⇒ b.t_s ≤ t_s).
                    b.images += req.images;
                    stats.coalesced += 1;
                    continue;
                }
            }
            batch.push(req);
        }
        stats.batches += out.iter().map(|b| b.len() as u64).sum::<u64>();
        out
    }

    /// Failover placement for a single request: where it lands on the
    /// *current* ring (the supervisor removes a dead shard before calling
    /// this, so in-flight work re-routes exactly like fresh arrivals —
    /// same key, same ring, deterministic). `None` when no shard is left.
    pub fn reroute(&self, req: &ServeRequest) -> Option<usize> {
        self.ring.try_shard_for(Self::key_of(req))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::TenantClass;
    use crate::workload::DnnModel;

    fn keys(n: usize) -> Vec<u64> {
        (0..n).map(|i| fnv1a64(format!("key-{i}").as_bytes())).collect()
    }

    #[test]
    fn adding_a_shard_remaps_at_most_about_k_over_n() {
        let population = keys(10_000);
        let before = HashRing::new(&[0, 1, 2, 3], 64);
        let mut after = before.clone();
        after.add(4);
        let mut moved = 0usize;
        for &k in &population {
            let (a, b) = (before.shard_for(k), after.shard_for(k));
            if a != b {
                moved += 1;
                // Consistency: a key only ever moves TO the new shard.
                assert_eq!(b, 4, "key moved between surviving shards");
            }
        }
        // Ideal is K/N = 2000; allow 2x for vnode placement variance.
        assert!(moved > 0, "new shard must take some keys");
        assert!(moved <= 2 * population.len() / 5, "moved {moved} of {}", population.len());
    }

    #[test]
    fn removing_a_shard_only_remaps_its_own_keys() {
        let population = keys(10_000);
        let before = HashRing::new(&[0, 1, 2, 3], 64);
        let mut after = before.clone();
        after.remove(2);
        for &k in &population {
            let (a, b) = (before.shard_for(k), after.shard_for(k));
            if a == 2 {
                assert_ne!(b, 2, "removed shard still owns a key");
            } else {
                assert_eq!(a, b, "key on a surviving shard must not move");
            }
        }
    }

    #[test]
    fn failover_moves_about_one_nth_of_the_keys() {
        // The quantitative version of the remap property: killing one of
        // N shards must re-route ≈ K/N keys — the dead shard's share and
        // nothing else. Checked over several ring sizes and victims.
        let population = keys(10_000);
        for &n in &[3usize, 4, 8] {
            let shards: Vec<usize> = (0..n).collect();
            let before = HashRing::new(&shards, 64);
            let victim = n / 2;
            let mut after = before.clone();
            after.remove(victim);
            let moved = population
                .iter()
                .filter(|&&k| before.shard_for(k) != after.shard_for(k))
                .count();
            let ideal = population.len() as f64 / n as f64;
            assert!(
                (moved as f64) < 2.0 * ideal,
                "n={n}: moved {moved}, ideal {ideal}"
            );
            assert!(
                (moved as f64) > 0.3 * ideal,
                "n={n}: moved {moved} suspiciously few (ideal {ideal})"
            );
        }
    }

    #[test]
    fn try_shard_for_handles_an_empty_ring() {
        let mut ring = HashRing::new(&[0], 8);
        assert!(ring.try_shard_for(12345).is_some());
        ring.remove(0);
        assert!(ring.is_empty());
        assert_eq!(ring.try_shard_for(12345), None);
        let router = ClusterRouter::new(&[], 8, false, 100);
        let r = ServeRequest {
            t_s: 0.0,
            tenant: TenantClass::Exec,
            model: DnnModel::ResNet18,
            images: 10,
        };
        assert_eq!(router.reroute(&r), None, "empty ring must not panic");
    }

    #[test]
    fn ring_membership_round_trips() {
        let mut ring = HashRing::new(&[0, 1], 16);
        assert_eq!(ring.shards(), vec![0, 1]);
        ring.add(1); // idempotent
        assert_eq!(ring.num_shards(), 2);
        ring.add(5);
        assert_eq!(ring.shards(), vec![0, 1, 5]);
        ring.remove(0);
        assert_eq!(ring.shards(), vec![1, 5]);
        assert!(!ring.contains(0));
        assert!(!ring.is_empty());
    }

    fn req(model: DnnModel, tenant: TenantClass, t_s: f64, images: u64) -> ServeRequest {
        ServeRequest { t_s, tenant, model, images }
    }

    #[test]
    fn same_model_requests_stay_colocated() {
        let router = ClusterRouter::new(&[0, 1, 2, 3], 64, false, u64::MAX);
        let mut stats = RouteStats { routed: vec![0; 4], ..Default::default() };
        for model in DnnModel::all() {
            let arrivals: Vec<ServeRequest> = (0..20)
                .map(|i| req(model, TenantClass::ALL[i % 3], i as f64 * 0.01, 100))
                .collect();
            let batches = router.route_epoch(arrivals, 4, &mut stats);
            let owners: Vec<usize> =
                (0..4).filter(|&s| !batches[s].is_empty()).collect();
            assert_eq!(owners.len(), 1, "model {model:?} split across {owners:?}");
            assert_eq!(owners[0], router.ring.shard_for(fnv1a64(model.name().as_bytes())));
        }
    }

    #[test]
    fn coalescing_merges_same_model_same_tenant_within_cap() {
        let router = ClusterRouter::new(&[0], 8, true, 250);
        let mut stats = RouteStats { routed: vec![0; 1], ..Default::default() };
        let arrivals = vec![
            req(DnnModel::ResNet18, TenantClass::Exec, 0.1, 100),
            req(DnnModel::ResNet18, TenantClass::Exec, 0.2, 100), // merges
            req(DnnModel::ResNet18, TenantClass::Energy, 0.3, 100), // other tenant
            req(DnnModel::ResNet18, TenantClass::Exec, 0.4, 100), // over cap → new batch
            req(DnnModel::AlexNet, TenantClass::Exec, 0.5, 100), // other model
        ];
        let batches = router.route_epoch(arrivals, 1, &mut stats);
        assert_eq!(stats.offered, 5);
        assert_eq!(stats.coalesced, 1);
        assert_eq!(stats.batches, 4);
        let exec_resnet: Vec<&ServeRequest> = batches[0]
            .iter()
            .filter(|b| b.model == DnnModel::ResNet18 && b.tenant == TenantClass::Exec)
            .collect();
        assert_eq!(exec_resnet.len(), 2);
        assert_eq!(exec_resnet[0].images, 200, "first batch absorbed the second request");
        assert_eq!(exec_resnet[0].t_s, 0.1, "batch keeps earliest arrival time");
        assert_eq!(exec_resnet[1].images, 100, "cap forces a fresh batch");
    }
}
