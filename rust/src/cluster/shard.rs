//! Shard worker: one simulation engine + scheduler per interposer, driven
//! epoch-by-epoch from the coordinator in lockstep.
//!
//! A shard blocks on its mailbox for an [`EpochPacket`], applies the
//! supervisor's directive ([`ShardCmd`]) and the arbiter-assigned power
//! cap, offers the routed batch, advances exactly `epoch_steps` engine
//! steps, and reports its epoch telemetry. After the final packet it
//! drains in-flight work (no new arrivals, no barrier — drain is a
//! deterministic function of shard-local state) and sends its telemetry
//! hub + final report for the epoch-ordered merge.
//!
//! # Fault model
//!
//! The worker thread is the shard's *node agent*: it never dies — only
//! the engine + scheduler it hosts do. On `Crash` the server is dropped
//! (queued and running work is lost; the supervisor fails those ids over
//! to surviving shards); on `Restart` it is rebuilt from the scheduler
//! factory and the lightweight checkpoint that survives the crash — the
//! telemetry hub, the shared replay log, and cluster time (the fresh
//! engine clock fast-forwards to `epoch · epoch_dt` so it rejoins the
//! lockstep instead of lagging it). On `Hang` the worker buffers the
//! packet without making progress and, on resume, books the lost epochs
//! as stall time so completion stamps stay consistent with cluster time.
//! Every packet — dead, hung, or healthy — is answered with exactly one
//! [`EpochReport`] (`alive: false` markers for dead/hung epochs), so the
//! coordinator's barrier always collects `n` reports and never deadlocks,
//! and the fault schedule perturbs telemetry deterministically.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};

use crate::arch::Arch;
use crate::fault::ShardCmd;
use crate::noi::NoiTopology;
use crate::sched::policy::NativeDdt;
use crate::sched::state::{StateEncoder, NUM_CLUSTERS, STATE_DIM};
use crate::sched::thermos::{Preference, ThermosSched};
use crate::sched::{BigLittleSched, SimbaSched};
use crate::serve::ingest::NullSource;
use crate::serve::replay::ReplayWriter;
use crate::serve::server::{ServeConfig, ServeReport, ServeSched, Server, TenantRouter};
use crate::serve::telemetry::{digest64, TelemetryHub};
use crate::serve::ServeRequest;
use crate::sim::ProfileCache;
use crate::thermal::ThermalParams;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::sync::lock_recover;
use crate::workload::ModelZoo;

/// Which scheduler each shard instantiates (every shard gets its own
/// instance — policy state is shard-local, only the power budget and the
/// profile cache are shared).
#[derive(Clone, Debug)]
pub enum ShardSchedSpec {
    /// Preference-conditioned MORL policy behind the tenant router;
    /// `theta: None` initializes from the shard's seed.
    Thermos { theta: Option<Vec<f32>>, fallback: Preference },
    Simba,
    BigLittle,
}

impl ShardSchedSpec {
    pub fn name(&self) -> &'static str {
        match self {
            ShardSchedSpec::Thermos { .. } => "thermos_mt",
            ShardSchedSpec::Simba => "simba",
            ShardSchedSpec::BigLittle => "big_little",
        }
    }
}

/// One epoch of work for a shard. Requests carry the coordinator-assigned
/// global id that identifies them across failovers.
#[derive(Clone, Debug)]
pub struct EpochPacket {
    pub reqs: Vec<(u64, ServeRequest)>,
    /// Arbiter-assigned power cap for this epoch (W).
    pub cap_w: f64,
    /// Final epoch: drain and report after this one.
    pub last: bool,
    /// Supervisor directive for this epoch.
    pub cmd: ShardCmd,
    /// Chiplet trip transitions to apply this epoch: `(chiplet, offline)`.
    pub trips: Vec<(usize, bool)>,
}

impl EpochPacket {
    /// A plain healthy-epoch packet (used by tests and the no-fault path).
    pub fn run(reqs: Vec<(u64, ServeRequest)>, cap_w: f64, last: bool) -> EpochPacket {
        EpochPacket { reqs, cap_w, last, cmd: ShardCmd::Run, trips: Vec::new() }
    }
}

/// Per-epoch shard telemetry, consumed by the supervisor and arbiter.
#[derive(Clone, Debug)]
pub struct EpochReport {
    pub shard: usize,
    pub epoch: usize,
    /// Peak chiplet temperature over the epoch (K).
    pub peak_temp_k: f64,
    /// Package power at the epoch boundary (W).
    pub power_w: f64,
    /// Cumulative completed jobs.
    pub completed: u64,
    pub queue_depth: usize,
    pub fifo_depth: usize,
    pub throttled: bool,
    pub cap_gated: bool,
    /// False for the marker report of a dead or hung epoch.
    pub alive: bool,
    /// Request ids completed this epoch (at-most-once settlement).
    pub done_ids: Vec<u64>,
    /// Request ids resolved negatively this epoch (rejected/shed).
    pub dropped_ids: Vec<u64>,
}

impl EpochReport {
    /// Marker for an epoch the shard sat out (dead or hung): no progress,
    /// no thermal reading, cumulative counters only.
    fn marker(shard: usize, epoch: usize, completed: u64) -> EpochReport {
        EpochReport {
            shard,
            epoch,
            peak_temp_k: 0.0,
            power_w: 0.0,
            completed,
            queue_depth: 0,
            fifo_depth: 0,
            throttled: false,
            cap_gated: false,
            alive: false,
            done_ids: Vec::new(),
            dropped_ids: Vec::new(),
        }
    }
}

/// Final shard output: its telemetry hub (for the fleet-wide merge), its
/// own serve report, and the ids it settled during the post-horizon drain
/// (the supervisor closes its ledger with these).
pub struct ShardResult {
    pub id: usize,
    pub hub: TelemetryHub,
    pub report: ServeReport,
    pub done_ids: Vec<u64>,
    pub dropped_ids: Vec<u64>,
}

/// Everything a shard worker needs; all owned, so the thread closure is
/// a plain `move`.
#[derive(Clone, Debug)]
pub struct ShardParams {
    pub id: usize,
    pub noi: NoiTopology,
    pub serve: ServeConfig,
    pub sched: ShardSchedSpec,
    /// Engine steps per epoch.
    pub epoch_steps: usize,
    /// Post-horizon drain bound (s).
    pub drain_max_s: f64,
    /// Per-shard replay log path (satellite: per-shard writers instead of
    /// one contended handle).
    pub record_path: Option<String>,
}

/// Shard thread entry point: construct the architecture locally (the
/// engine borrows the arch, so it must live on this thread) and hand a
/// scheduler *factory* to the epoch loop — restarts after a crash rebuild
/// the scheduler from the same deterministic inputs.
pub fn run_shard(
    params: ShardParams,
    cache: ProfileCache,
    packet_rx: Receiver<EpochPacket>,
    report_tx: Sender<EpochReport>,
    result_tx: Sender<ShardResult>,
) {
    let arch = Arch::paper_heterogeneous(params.noi);
    let arch_ref = &arch;
    match params.sched.clone() {
        ShardSchedSpec::Simba => {
            let factory = move || SimbaSched::new(arch_ref.clone());
            drive(&params, cache, arch_ref, factory, packet_rx, report_tx, result_tx);
        }
        ShardSchedSpec::BigLittle => {
            let factory = move || BigLittleSched::new(arch_ref.clone());
            drive(&params, cache, arch_ref, factory, packet_rx, report_tx, result_tx);
        }
        ShardSchedSpec::Thermos { theta, fallback } => {
            let zoo = ModelZoo::new();
            let encoder = StateEncoder::new(arch_ref, &zoo, params.serve.sim.max_images);
            let seed = params.serve.sim.seed;
            let factory = move || {
                let ddt = match &theta {
                    Some(t) => NativeDdt::new(STATE_DIM, NUM_CLUSTERS, t.clone()),
                    None => {
                        let mut rng = Rng::new(seed);
                        NativeDdt::init(STATE_DIM, NUM_CLUSTERS, &mut rng)
                    }
                };
                TenantRouter::new(ThermosSched::new(arch_ref.clone(), encoder.clone(), ddt, fallback))
            };
            drive(&params, cache, arch_ref, factory, packet_rx, report_tx, result_tx);
        }
    }
}

fn drive<'a, S: ServeSched, F: Fn() -> S>(
    params: &ShardParams,
    cache: ProfileCache,
    arch: &'a Arch,
    make_sched: F,
    packet_rx: Receiver<EpochPacket>,
    report_tx: Sender<EpochReport>,
    result_tx: Sender<ShardResult>,
) {
    let epoch_dt = params.epoch_steps as f64 * ThermalParams::default().dt_s;
    let hub = Arc::new(Mutex::new(TelemetryHub::new()));
    let replay: Option<Arc<Mutex<ReplayWriter>>> = params.record_path.as_ref().and_then(|path| {
        match ReplayWriter::create(path) {
            Ok(w) => Some(Arc::new(Mutex::new(w))),
            Err(e) => {
                eprintln!("shard {}: replay log {path} failed: {e}", params.id);
                None
            }
        }
    });
    let new_server = || -> Server<'a, S> {
        let mut s = Server::new_with_hub(
            arch,
            make_sched(),
            Box::new(NullSource),
            params.serve.clone(),
            hub.clone(),
        );
        s.set_profile_cache(cache.clone());
        if let Some(w) = &replay {
            s = s.with_replay(w.clone());
        }
        s
    };

    let mut server: Option<Server<'a, S>> = Some(new_server());
    let mut epoch = 0usize;
    // Hang state: batches/trips buffered while frozen, and how many epochs
    // the freeze has lasted (booked as stall time on resume).
    let mut paused_reqs: Vec<(u64, ServeRequest)> = Vec::new();
    let mut paused_trips: Vec<(usize, bool)> = Vec::new();
    let mut paused_epochs = 0usize;
    // Engine clock at the last healthy barrier (the dead-shard report's
    // service duration).
    let mut checkpoint_s = 0.0f64;

    while let Ok(pkt) = packet_rx.recv() {
        let last = pkt.last;
        match pkt.cmd {
            ShardCmd::Crash => {
                // Engine + scheduler die; queued and running work is gone
                // (the supervisor fails those ids over). The hub, replay
                // log, and checkpoint clock survive in the node agent.
                server = None;
                paused_reqs.clear();
                paused_trips.clear();
                paused_epochs = 0;
                let done = lock_recover(&hub).totals().4;
                if report_tx.send(EpochReport::marker(params.id, epoch, done)).is_err() {
                    break;
                }
            }
            ShardCmd::Down => {
                let done = lock_recover(&hub).totals().4;
                if report_tx.send(EpochReport::marker(params.id, epoch, done)).is_err() {
                    break;
                }
            }
            ShardCmd::Hang => {
                paused_reqs.extend(pkt.reqs);
                paused_trips.extend(pkt.trips);
                paused_epochs += 1;
                let done = lock_recover(&hub).totals().4;
                if report_tx.send(EpochReport::marker(params.id, epoch, done)).is_err() {
                    break;
                }
            }
            ShardCmd::Run | ShardCmd::Restart => {
                if pkt.cmd == ShardCmd::Restart || server.is_none() {
                    let mut s = new_server();
                    // Rejoin cluster time: resuming at the checkpoint clock
                    // would lag the lockstep forever.
                    s.set_clock_s(epoch as f64 * epoch_dt);
                    server = Some(s);
                    paused_epochs = 0;
                }
                let Some(s) = server.as_mut() else {
                    // Unreachable (rebuilt above), but the barrier contract
                    // is one report per packet no matter what.
                    let done = lock_recover(&hub).totals().4;
                    if report_tx.send(EpochReport::marker(params.id, epoch, done)).is_err() {
                        break;
                    }
                    epoch += 1;
                    if last {
                        break;
                    }
                    continue;
                };
                if paused_epochs > 0 {
                    s.stall_for(paused_epochs as f64 * epoch_dt);
                    paused_epochs = 0;
                }
                s.set_power_cap_w(Some(pkt.cap_w));
                for (c, off) in paused_trips.drain(..).chain(pkt.trips.iter().copied()) {
                    s.set_chiplet_offline(c % arch.num_chiplets(), off);
                }
                let buffered: Vec<(u64, ServeRequest)> = paused_reqs.drain(..).collect();
                for (id, req) in buffered.into_iter().chain(pkt.reqs.into_iter()) {
                    s.offer_with_id(id, req);
                }
                s.advance(params.epoch_steps);
                let (done_ids, dropped_ids) = s.take_epoch_done();
                let report = EpochReport {
                    shard: params.id,
                    epoch,
                    peak_temp_k: s.take_epoch_peak_temp_k(),
                    power_w: s.power_w(),
                    completed: s.completed_total(),
                    queue_depth: s.queue_depth(),
                    fifo_depth: s.fifo_depth(),
                    throttled: s.any_throttled(),
                    cap_gated: s.cap_gated(),
                    alive: true,
                    done_ids,
                    dropped_ids,
                };
                checkpoint_s = s.now();
                if report_tx.send(report).is_err() {
                    break; // coordinator gone; drain and exit
                }
            }
        }
        epoch += 1;
        if last {
            break;
        }
    }

    // Drain: keep the final cap, no new arrivals, bounded by drain_max_s.
    // A shard that ends its run hung first catches up its frozen epochs.
    let (report, done_ids, dropped_ids) = match server {
        Some(mut s) => {
            if paused_epochs > 0 {
                s.stall_for(paused_epochs as f64 * epoch_dt);
            }
            for (id, req) in paused_reqs.drain(..) {
                s.offer_with_id(id, req);
            }
            let deadline = s.now() + params.drain_max_s;
            while !s.is_drained() && s.now() < deadline - 1e-9 {
                s.advance(params.epoch_steps.max(1));
            }
            let (done, dropped) = s.take_epoch_done();
            (s.finish(), done, dropped)
        }
        None => (
            dead_shard_report(params, &hub, checkpoint_s),
            Vec::new(),
            Vec::new(),
        ),
    };
    let hub_snapshot = lock_recover(&hub).clone();
    let _ = result_tx.send(ShardResult {
        id: params.id,
        hub: hub_snapshot,
        report,
        done_ids,
        dropped_ids,
    });
}

/// Final report for a shard that died and was never restarted: admission
/// counters and latency histograms survive in the hub; engine-owned stats
/// (temperatures, energy, throttle counters) died with the engine and
/// read zero — visible degradation, not fabricated data.
fn dead_shard_report(
    params: &ShardParams,
    hub: &Arc<Mutex<TelemetryHub>>,
    checkpoint_s: f64,
) -> ServeReport {
    let hub = lock_recover(hub);
    let (offered, admitted, rejected, shed, completed) = hub.totals();
    let json = Json::obj(vec![
        ("scheduler", Json::Str(params.sched.name().to_string())),
        ("source", Json::Str("null".to_string())),
        ("seed", Json::Num(params.serve.sim.seed as f64)),
        ("duration_s", Json::Num(checkpoint_s)),
        ("offered", Json::Num(offered as f64)),
        ("admitted", Json::Num(admitted as f64)),
        ("rejected", Json::Num(rejected as f64)),
        ("shed", Json::Num(shed as f64)),
        ("shed_pressure", Json::Num(hub.shed_pressure_total() as f64)),
        ("completed", Json::Num(completed as f64)),
        ("images_done", Json::Num(hub.images_done_total() as f64)),
        ("throughput_jobs_s", Json::Num(completed as f64 / checkpoint_s.max(1e-9))),
        ("latency_e2e_s", hub.e2e_all.to_json()),
        ("latency_exec_s", hub.exec_all.to_json()),
        ("energy_j", hub.energy_all.to_json()),
        ("queue_depth_max", Json::Num(hub.queue_depth_max as f64)),
        ("fifo_depth_max", Json::Num(hub.fifo_depth_max as f64)),
        ("host_stalls", Json::Num(0.0)),
        ("throttle_events", Json::Num(0.0)),
        ("cap_gated_steps", Json::Num(0.0)),
        ("max_temp_k", Json::Num(0.0)),
        ("cluster_max_temp_k", Json::arr_f64(&[])),
        ("system_energy_j", Json::Num(0.0)),
        ("tenants", hub.tenants_json()),
    ]);
    let digest = digest64(&json.to_string_compact());
    ServeReport { json, digest, snapshots: Vec::new() }
}
