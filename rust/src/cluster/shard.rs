//! Shard slot: one simulation engine + scheduler per interposer, stepped
//! epoch-by-epoch from the coordinator in lockstep on the shared
//! [`WorkPool`](crate::util::pool::WorkPool).
//!
//! A slot receives an [`EpochPacket`], applies the supervisor's
//! directive ([`ShardCmd`]) and the arbiter-assigned power cap, offers
//! the routed batch, advances exactly `epoch_steps` engine steps,
//! optionally surrenders queued backlog to the coordinator's steal quota,
//! and returns its epoch telemetry. After the final packet the
//! coordinator calls [`ShardSlot::finish`] to drain in-flight work (no
//! new arrivals — drain is a deterministic function of shard-local
//! state) and collect the telemetry hub + final report for the
//! epoch-ordered merge.
//!
//! # Fault model
//!
//! The slot is the shard's *node agent*: it never dies — only the engine
//! + scheduler it hosts do. On `Crash` the server is dropped (queued and
//! running work is lost; the supervisor fails those ids over to
//! surviving shards); on `Restart` it is rebuilt from the scheduler
//! factory and the lightweight checkpoint that survives the crash — the
//! telemetry hub, the shared replay log, and cluster time (the fresh
//! engine clock fast-forwards to `epoch · epoch_dt` so it rejoins the
//! lockstep instead of lagging it). On `Hang` the slot buffers the
//! packet without making progress and, on resume, books the lost epochs
//! as stall time so completion stamps stay consistent with cluster time.
//! `Standby` keeps a prebuilt warm engine idle (rebuilding it lazily
//! after a demotion); `Adopt` is the warm-failover counterpart of
//! `Restart` — the standby engine takes over a dead shard's position
//! without a cold rebuild. Every packet — dead, hung, idle, or healthy —
//! is answered with exactly one [`EpochReport`] (`alive: false` markers
//! for dead/hung/idle epochs), so the coordinator's barrier always
//! collects one report per slot, and the fault schedule perturbs
//! telemetry deterministically.

use std::sync::{Arc, Mutex};

use crate::arch::Arch;
use crate::fault::ShardCmd;
use crate::noi::NoiTopology;
use crate::sched::thermos::Preference;
use crate::serve::ingest::NullSource;
use crate::serve::replay::ReplayWriter;
use crate::serve::server::{ServeConfig, ServeReport, ServeSched, Server};
use crate::serve::telemetry::{digest64, TelemetryHub};
use crate::serve::ServeRequest;
use crate::sim::ProfileCache;
use crate::thermal::ThermalParams;
use crate::util::json::Json;
use crate::util::sync::lock_recover;

use super::steal::CostModel;

/// Which scheduler each shard instantiates (every shard gets its own
/// instance — policy state is shard-local, only the power budget and the
/// profile cache are shared).
#[derive(Clone, Debug)]
pub enum ShardSchedSpec {
    /// Preference-conditioned MORL policy behind the tenant router;
    /// `theta: None` initializes from the shard's seed.
    Thermos { theta: Option<Vec<f32>>, fallback: Preference },
    Simba,
    BigLittle,
}

impl ShardSchedSpec {
    pub fn name(&self) -> &'static str {
        match self {
            ShardSchedSpec::Thermos { .. } => "thermos_mt",
            ShardSchedSpec::Simba => "simba",
            ShardSchedSpec::BigLittle => "big_little",
        }
    }
}

/// One epoch of work for a shard. Requests carry the coordinator-assigned
/// global id that identifies them across failovers.
#[derive(Clone, Debug)]
pub struct EpochPacket {
    pub reqs: Vec<(u64, ServeRequest)>,
    /// Arbiter-assigned power cap for this epoch (W).
    pub cap_w: f64,
    /// Final epoch: drain and report after this one.
    pub last: bool,
    /// Supervisor directive for this epoch.
    pub cmd: ShardCmd,
    /// Chiplet trip transitions to apply this epoch: `(chiplet, offline)`.
    pub trips: Vec<(usize, bool)>,
    /// Steal quota: surrender queued backlog worth up to this many
    /// estimated seconds at the end of the epoch (0 ⇒ donate nothing).
    pub steal_cost_s: f64,
}

impl EpochPacket {
    /// A plain healthy-epoch packet (used by tests and the no-fault path).
    pub fn run(reqs: Vec<(u64, ServeRequest)>, cap_w: f64, last: bool) -> EpochPacket {
        EpochPacket { reqs, cap_w, last, cmd: ShardCmd::Run, trips: Vec::new(), steal_cost_s: 0.0 }
    }
}

/// Per-epoch shard telemetry, consumed by the supervisor and arbiter.
#[derive(Clone, Debug)]
pub struct EpochReport {
    pub shard: usize,
    pub epoch: usize,
    /// Peak chiplet temperature over the epoch (K).
    pub peak_temp_k: f64,
    /// Package power at the epoch boundary (W).
    pub power_w: f64,
    /// Cumulative completed jobs.
    pub completed: u64,
    pub queue_depth: usize,
    pub fifo_depth: usize,
    pub throttled: bool,
    pub cap_gated: bool,
    /// False for the marker report of a dead or hung epoch.
    pub alive: bool,
    /// Request ids completed this epoch (at-most-once settlement).
    pub done_ids: Vec<u64>,
    /// Request ids resolved negatively this epoch (rejected/shed).
    pub dropped_ids: Vec<u64>,
    /// Queued requests surrendered to the steal quota this epoch; the
    /// coordinator reassigns them at the barrier (keeping their gids).
    pub stolen: Vec<(u64, ServeRequest)>,
}

impl EpochReport {
    /// Marker for an epoch the shard sat out (dead, hung, or standby):
    /// no progress, no thermal reading, cumulative counters only.
    fn marker(shard: usize, epoch: usize, completed: u64) -> EpochReport {
        EpochReport {
            shard,
            epoch,
            peak_temp_k: 0.0,
            power_w: 0.0,
            completed,
            queue_depth: 0,
            fifo_depth: 0,
            throttled: false,
            cap_gated: false,
            alive: false,
            done_ids: Vec::new(),
            dropped_ids: Vec::new(),
            stolen: Vec::new(),
        }
    }
}

/// Final shard output: its telemetry hub (for the fleet-wide merge), its
/// own serve report, and the ids it settled during the post-horizon drain
/// (the supervisor closes its ledger with these).
pub struct ShardResult {
    pub id: usize,
    pub hub: TelemetryHub,
    pub report: ServeReport,
    pub done_ids: Vec<u64>,
    pub dropped_ids: Vec<u64>,
}

/// Everything a shard slot needs; all owned, so slots can be built in a
/// plain loop before the epoch driver starts.
#[derive(Clone, Debug)]
pub struct ShardParams {
    pub id: usize,
    pub noi: NoiTopology,
    pub serve: ServeConfig,
    pub sched: ShardSchedSpec,
    /// Engine steps per epoch.
    pub epoch_steps: usize,
    /// Post-horizon drain bound (s).
    pub drain_max_s: f64,
    /// Per-shard replay log path (satellite: per-shard writers instead of
    /// one contended handle).
    pub record_path: Option<String>,
}

/// One shard's long-lived state between epoch barriers: the (optional,
/// crash-killable) server, the hang/checkpoint bookkeeping, and the
/// factory + handles needed to rebuild the engine deterministically.
///
/// The coordinator owns `Mutex<ShardSlot>`s and steps them on the shared
/// [`WorkPool`](crate::util::pool::WorkPool) — one pooled task per slot
/// per epoch, an exclusive lock per task, so a slot's state is only ever
/// touched by one thread at a time with the barrier as the hand-off.
pub(crate) struct ShardSlot<'a, S: ServeSched> {
    params: ShardParams,
    cache: ProfileCache,
    arch: &'a Arch,
    make: Box<dyn Fn() -> S + Send + 'a>,
    hub: Arc<Mutex<TelemetryHub>>,
    replay: Option<Arc<Mutex<ReplayWriter>>>,
    /// Steal cost oracle, shared with the coordinator (set only when
    /// stealing is on).
    cost: Option<Arc<CostModel>>,
    server: Option<Server<'a, S>>,
    epoch_dt: f64,
    epoch: usize,
    /// Hang state: batches/trips buffered while frozen, and how many
    /// epochs the freeze has lasted (booked as stall time on resume).
    paused_reqs: Vec<(u64, ServeRequest)>,
    paused_trips: Vec<(usize, bool)>,
    paused_epochs: usize,
    /// Engine clock at the last healthy barrier (the dead-shard report's
    /// service duration).
    checkpoint_s: f64,
}

// SAFETY: `Server` is not automatically `Send` only because its optional
// event callbacks (`on_mapped`/`on_completed`/`on_snapshot`) are
// `Box<dyn FnMut .. + 'a>` without a `Send` bound — a deliberate choice
// so single-threaded users (the RL trainers) can capture `&RefCell`
// state. Cluster slots never install such closures: the servers built
// here use `Server::new_with_hub` (no snapshot callback — per-shard
// snapshotting is forced off by the coordinator) and only ever hold
// `Send` handles (`Arc<Mutex<TelemetryHub>>`, `Arc<Mutex<ReplayWriter>>`,
// `Arc<CostModel>`, a `Send + 'a` scheduler factory, and plain data).
// Each slot is additionally wrapped in a `Mutex` by the coordinator, so
// it is only ever accessed by one pool worker at a time.
unsafe impl<S: ServeSched + Send> Send for ShardSlot<'_, S> {}

impl<'a, S: ServeSched> ShardSlot<'a, S> {
    pub(crate) fn new(
        params: ShardParams,
        cache: ProfileCache,
        arch: &'a Arch,
        make: Box<dyn Fn() -> S + Send + 'a>,
        cost: Option<Arc<CostModel>>,
    ) -> ShardSlot<'a, S> {
        let epoch_dt = params.epoch_steps as f64 * ThermalParams::default().dt_s;
        let hub = Arc::new(Mutex::new(TelemetryHub::new()));
        let replay: Option<Arc<Mutex<ReplayWriter>>> =
            params.record_path.as_ref().and_then(|path| match ReplayWriter::create(path) {
                Ok(w) => Some(Arc::new(Mutex::new(w))),
                Err(e) => {
                    eprintln!("shard {}: replay log {path} failed: {e}", params.id);
                    None
                }
            });
        let mut slot = ShardSlot {
            params,
            cache,
            arch,
            make,
            hub,
            replay,
            cost,
            server: None,
            epoch_dt,
            epoch: 0,
            paused_reqs: Vec::new(),
            paused_trips: Vec::new(),
            paused_epochs: 0,
            checkpoint_s: 0.0,
        };
        slot.server = Some(slot.new_server());
        slot
    }

    fn new_server(&self) -> Server<'a, S> {
        let mut s = Server::new_with_hub(
            self.arch,
            (self.make)(),
            Box::new(NullSource),
            self.params.serve.clone(),
            self.hub.clone(),
        );
        s.set_profile_cache(self.cache.clone());
        if let Some(w) = &self.replay {
            s = s.with_replay(w.clone());
        }
        s
    }

    fn marker(&self) -> EpochReport {
        EpochReport::marker(self.params.id, self.epoch, lock_recover(&self.hub).totals().4)
    }

    /// Apply one epoch packet and return exactly one report — the
    /// barrier contract, dead or alive.
    pub(crate) fn epoch(&mut self, pkt: EpochPacket) -> EpochReport {
        let report = match pkt.cmd {
            ShardCmd::Crash => {
                // Engine + scheduler die; queued and running work is gone
                // (the supervisor fails those ids over). The hub, replay
                // log, and checkpoint clock survive in the node agent.
                self.server = None;
                self.paused_reqs.clear();
                self.paused_trips.clear();
                self.paused_epochs = 0;
                self.marker()
            }
            ShardCmd::Down => self.marker(),
            ShardCmd::Standby => {
                // Warm standby: keep a prebuilt engine idle. A slot whose
                // engine was demoted away (or crashed) re-warms here, so
                // it is adoptable again from the next barrier on.
                if self.server.is_none() {
                    self.server = Some(self.new_server());
                }
                self.marker()
            }
            ShardCmd::Hang => {
                self.paused_reqs.extend(pkt.reqs);
                self.paused_trips.extend(pkt.trips);
                self.paused_epochs += 1;
                self.marker()
            }
            ShardCmd::Run | ShardCmd::Restart | ShardCmd::Adopt => self.run_epoch(pkt),
        };
        self.epoch += 1;
        report
    }

    fn run_epoch(&mut self, pkt: EpochPacket) -> EpochReport {
        if pkt.cmd == ShardCmd::Restart || self.server.is_none() {
            let mut s = self.new_server();
            // Rejoin cluster time: resuming at the checkpoint clock
            // would lag the lockstep forever.
            s.set_clock_s(self.epoch as f64 * self.epoch_dt);
            self.server = Some(s);
            self.paused_epochs = 0;
        } else if pkt.cmd == ShardCmd::Adopt {
            // Warm adoption: the engine was prebuilt on standby — only
            // its clock needs to join cluster time. This is the whole
            // point of `--spares`: no cold rebuild on the failover path.
            if let Some(s) = self.server.as_mut() {
                s.set_clock_s(self.epoch as f64 * self.epoch_dt);
            }
            self.paused_epochs = 0;
        }
        let epoch = self.epoch;
        let Some(s) = self.server.as_mut() else {
            // Unreachable (rebuilt above), but the barrier contract is
            // one report per packet no matter what.
            return EpochReport::marker(self.params.id, epoch, lock_recover(&self.hub).totals().4);
        };
        if self.paused_epochs > 0 {
            s.stall_for(self.paused_epochs as f64 * self.epoch_dt);
            self.paused_epochs = 0;
        }
        s.set_power_cap_w(Some(pkt.cap_w));
        for (c, off) in self.paused_trips.drain(..).chain(pkt.trips.iter().copied()) {
            s.set_chiplet_offline(c % self.arch.num_chiplets(), off);
        }
        let buffered: Vec<(u64, ServeRequest)> = self.paused_reqs.drain(..).collect();
        for (id, req) in buffered.into_iter().chain(pkt.reqs.into_iter()) {
            s.offer_with_id(id, req);
        }
        s.advance(self.params.epoch_steps);
        // Donate to the steal quota *after* the advance: what migrates is
        // exactly the backlog this epoch could not serve.
        let stolen = match (&self.cost, pkt.steal_cost_s > 0.0) {
            (Some(cm), true) => {
                let cm = cm.clone();
                s.surrender_queued(pkt.steal_cost_s, |r| cm.cost(r))
            }
            _ => Vec::new(),
        };
        let (done_ids, dropped_ids) = s.take_epoch_done();
        let report = EpochReport {
            shard: self.params.id,
            epoch,
            peak_temp_k: s.take_epoch_peak_temp_k(),
            power_w: s.power_w(),
            completed: s.completed_total(),
            queue_depth: s.queue_depth(),
            fifo_depth: s.fifo_depth(),
            throttled: s.any_throttled(),
            cap_gated: s.cap_gated(),
            alive: true,
            done_ids,
            dropped_ids,
            stolen,
        };
        self.checkpoint_s = s.now();
        report
    }

    /// Drain: keep the final cap, no new arrivals, bounded by
    /// `drain_max_s`. A shard that ends its run hung first catches up
    /// its frozen epochs.
    pub(crate) fn finish(&mut self) -> ShardResult {
        let (report, done_ids, dropped_ids) = match self.server.take() {
            Some(mut s) => {
                if self.paused_epochs > 0 {
                    s.stall_for(self.paused_epochs as f64 * self.epoch_dt);
                }
                for (id, req) in self.paused_reqs.drain(..) {
                    s.offer_with_id(id, req);
                }
                let deadline = s.now() + self.params.drain_max_s;
                while !s.is_drained() && s.now() < deadline - 1e-9 {
                    s.advance(self.params.epoch_steps.max(1));
                }
                let (done, dropped) = s.take_epoch_done();
                (s.finish(), done, dropped)
            }
            None => (
                dead_shard_report(&self.params, &self.hub, self.checkpoint_s),
                Vec::new(),
                Vec::new(),
            ),
        };
        let hub_snapshot = lock_recover(&self.hub).clone();
        ShardResult { id: self.params.id, hub: hub_snapshot, report, done_ids, dropped_ids }
    }
}

/// Final report for a shard that died and was never restarted: admission
/// counters and latency histograms survive in the hub; engine-owned stats
/// (temperatures, energy, throttle counters) died with the engine and
/// read zero — visible degradation, not fabricated data.
fn dead_shard_report(
    params: &ShardParams,
    hub: &Arc<Mutex<TelemetryHub>>,
    checkpoint_s: f64,
) -> ServeReport {
    let hub = lock_recover(hub);
    let (offered, admitted, rejected, shed, completed) = hub.totals();
    let json = Json::obj(vec![
        ("scheduler", Json::Str(params.sched.name().to_string())),
        ("source", Json::Str("null".to_string())),
        ("seed", Json::Num(params.serve.sim.seed as f64)),
        ("duration_s", Json::Num(checkpoint_s)),
        ("offered", Json::Num(offered as f64)),
        ("admitted", Json::Num(admitted as f64)),
        ("rejected", Json::Num(rejected as f64)),
        ("shed", Json::Num(shed as f64)),
        ("shed_pressure", Json::Num(hub.shed_pressure_total() as f64)),
        ("completed", Json::Num(completed as f64)),
        ("images_done", Json::Num(hub.images_done_total() as f64)),
        ("throughput_jobs_s", Json::Num(completed as f64 / checkpoint_s.max(1e-9))),
        ("latency_e2e_s", hub.e2e_all.to_json()),
        ("latency_exec_s", hub.exec_all.to_json()),
        ("energy_j", hub.energy_all.to_json()),
        ("queue_depth_max", Json::Num(hub.queue_depth_max as f64)),
        ("fifo_depth_max", Json::Num(hub.fifo_depth_max as f64)),
        ("host_stalls", Json::Num(0.0)),
        ("throttle_events", Json::Num(0.0)),
        ("cap_gated_steps", Json::Num(0.0)),
        ("max_temp_k", Json::Num(0.0)),
        ("cluster_max_temp_k", Json::arr_f64(&[])),
        ("system_energy_j", Json::Num(0.0)),
        ("tenants", hub.tenants_json()),
    ]);
    let digest = digest64(&json.to_string_compact());
    ServeReport { json, digest, snapshots: Vec::new() }
}
