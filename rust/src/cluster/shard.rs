//! Shard worker: one simulation engine + scheduler per interposer, driven
//! epoch-by-epoch from the coordinator in lockstep.
//!
//! A shard blocks on its mailbox for an [`EpochPacket`], applies the
//! arbiter-assigned power cap, offers the routed batch, advances exactly
//! `epoch_steps` engine steps, and reports its epoch telemetry. After the
//! final packet it drains in-flight work (no new arrivals, no barrier —
//! drain is a deterministic function of shard-local state) and sends its
//! telemetry hub + final report for the epoch-ordered merge.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};

use crate::arch::Arch;
use crate::noi::NoiTopology;
use crate::sched::policy::NativeDdt;
use crate::sched::state::{StateEncoder, NUM_CLUSTERS, STATE_DIM};
use crate::sched::thermos::{Preference, ThermosSched};
use crate::sched::{BigLittleSched, SimbaSched};
use crate::serve::ingest::NullSource;
use crate::serve::replay::ReplayWriter;
use crate::serve::server::{ServeConfig, ServeReport, ServeSched, Server, TenantRouter};
use crate::serve::telemetry::TelemetryHub;
use crate::serve::ServeRequest;
use crate::sim::ProfileCache;
use crate::util::rng::Rng;
use crate::workload::ModelZoo;

/// Which scheduler each shard instantiates (every shard gets its own
/// instance — policy state is shard-local, only the power budget and the
/// profile cache are shared).
#[derive(Clone, Debug)]
pub enum ShardSchedSpec {
    /// Preference-conditioned MORL policy behind the tenant router;
    /// `theta: None` initializes from the shard's seed.
    Thermos { theta: Option<Vec<f32>>, fallback: Preference },
    Simba,
    BigLittle,
}

impl ShardSchedSpec {
    pub fn name(&self) -> &'static str {
        match self {
            ShardSchedSpec::Thermos { .. } => "thermos_mt",
            ShardSchedSpec::Simba => "simba",
            ShardSchedSpec::BigLittle => "big_little",
        }
    }
}

/// One epoch of work for a shard.
#[derive(Clone, Debug)]
pub struct EpochPacket {
    pub reqs: Vec<ServeRequest>,
    /// Arbiter-assigned power cap for this epoch (W).
    pub cap_w: f64,
    /// Final epoch: drain and report after this one.
    pub last: bool,
}

/// Per-epoch shard telemetry, consumed by the arbiter.
#[derive(Clone, Copy, Debug)]
pub struct EpochReport {
    pub shard: usize,
    pub epoch: usize,
    /// Peak chiplet temperature over the epoch (K).
    pub peak_temp_k: f64,
    /// Package power at the epoch boundary (W).
    pub power_w: f64,
    /// Cumulative completed jobs.
    pub completed: u64,
    pub queue_depth: usize,
    pub fifo_depth: usize,
    pub throttled: bool,
    pub cap_gated: bool,
}

/// Final shard output: its telemetry hub (for the fleet-wide merge) and
/// its own serve report.
pub struct ShardResult {
    pub id: usize,
    pub hub: TelemetryHub,
    pub report: ServeReport,
}

/// Everything a shard worker needs; all owned, so the thread closure is
/// a plain `move`.
#[derive(Clone, Debug)]
pub struct ShardParams {
    pub id: usize,
    pub noi: NoiTopology,
    pub serve: ServeConfig,
    pub sched: ShardSchedSpec,
    /// Engine steps per epoch.
    pub epoch_steps: usize,
    /// Post-horizon drain bound (s).
    pub drain_max_s: f64,
    /// Per-shard replay log path (satellite: per-shard writers instead of
    /// one contended handle).
    pub record_path: Option<String>,
}

/// Shard thread entry point: construct the architecture + scheduler
/// locally (the engine borrows the arch, so it must live on this thread)
/// and run the epoch loop.
pub fn run_shard(
    params: ShardParams,
    cache: ProfileCache,
    packet_rx: Receiver<EpochPacket>,
    report_tx: Sender<EpochReport>,
    result_tx: Sender<ShardResult>,
) {
    let arch = Arch::paper_heterogeneous(params.noi);
    match params.sched.clone() {
        ShardSchedSpec::Simba => {
            let sched = SimbaSched::new(arch.clone());
            drive(&params, cache, &arch, sched, packet_rx, report_tx, result_tx);
        }
        ShardSchedSpec::BigLittle => {
            let sched = BigLittleSched::new(arch.clone());
            drive(&params, cache, &arch, sched, packet_rx, report_tx, result_tx);
        }
        ShardSchedSpec::Thermos { theta, fallback } => {
            let zoo = ModelZoo::new();
            let encoder = StateEncoder::new(&arch, &zoo, params.serve.sim.max_images);
            let ddt = match theta {
                Some(t) => NativeDdt::new(STATE_DIM, NUM_CLUSTERS, t),
                None => {
                    let mut rng = Rng::new(params.serve.sim.seed);
                    NativeDdt::init(STATE_DIM, NUM_CLUSTERS, &mut rng)
                }
            };
            let sched = TenantRouter::new(ThermosSched::new(arch.clone(), encoder, ddt, fallback));
            drive(&params, cache, &arch, sched, packet_rx, report_tx, result_tx);
        }
    }
}

fn drive<S: ServeSched>(
    params: &ShardParams,
    cache: ProfileCache,
    arch: &Arch,
    sched: S,
    packet_rx: Receiver<EpochPacket>,
    report_tx: Sender<EpochReport>,
    result_tx: Sender<ShardResult>,
) {
    let mut server = Server::new(arch, sched, Box::new(NullSource), params.serve.clone());
    server.set_profile_cache(cache);
    if let Some(path) = &params.record_path {
        match ReplayWriter::create(path) {
            Ok(w) => server = server.with_replay(Arc::new(Mutex::new(w))),
            Err(e) => eprintln!("shard {}: replay log {path} failed: {e}", params.id),
        }
    }

    let mut epoch = 0usize;
    while let Ok(pkt) = packet_rx.recv() {
        let last = pkt.last;
        server.set_power_cap_w(Some(pkt.cap_w));
        for req in pkt.reqs {
            server.offer(req);
        }
        server.advance(params.epoch_steps);
        let report = EpochReport {
            shard: params.id,
            epoch,
            peak_temp_k: server.take_epoch_peak_temp_k(),
            power_w: server.power_w(),
            completed: server.completed_total(),
            queue_depth: server.queue_depth(),
            fifo_depth: server.fifo_depth(),
            throttled: server.any_throttled(),
            cap_gated: server.cap_gated(),
        };
        epoch += 1;
        if report_tx.send(report).is_err() {
            break; // coordinator gone; drain and exit
        }
        if last {
            break;
        }
    }

    // Drain: keep the final cap, no new arrivals, bounded by drain_max_s.
    let deadline = server.now() + params.drain_max_s;
    while !server.is_drained() && server.now() < deadline - 1e-9 {
        server.advance(params.epoch_steps.max(1));
    }

    let hub = server.hub_handle().lock().unwrap().clone();
    let report = server.finish();
    let _ = result_tx.send(ShardResult { id: params.id, hub, report });
}
