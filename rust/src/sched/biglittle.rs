//! Big-Little baseline scheduler [32], adapted from chiplet-size
//! heterogeneity to the four PIM-type clusters (as the paper does in
//! §5.2): early layers — which have fewer weights — go to "little"
//! clusters (small per-chiplet crossbar capacity), later layers to "big"
//! ones; within a cluster, chiplets are filled by *highest crossbar
//! utilization first* (the Big-Little selection rule), with no proximity
//! awareness.

use super::{fill_chiplets, Scheduler, SysSnapshot};
use crate::arch::{Arch, NUM_PIM_TYPES};
use crate::sim::mapping::{LayerAssignment, Mapping};
use crate::workload::Job;

pub struct BigLittleSched {
    arch: Arch,
    /// Cluster indices ordered little → big by per-chiplet capacity.
    size_order: Vec<usize>,
}

impl BigLittleSched {
    pub fn new(arch: Arch) -> BigLittleSched {
        let mut size_order: Vec<usize> = (0..NUM_PIM_TYPES).collect();
        size_order.sort_by_key(|&cl| arch.specs[cl].mem_bits);
        BigLittleSched { arch, size_order }
    }

    /// Cluster choice: the "littlest" cluster whose *free* memory can
    /// still hold the layer; if none fits entirely, the biggest cluster
    /// with any free memory (tiling continues into the next cluster).
    fn pick_cluster(&self, snap: &SysSnapshot, free: &[u64], need: u64) -> Option<usize> {
        for &cl in &self.size_order {
            let cluster_free: u64 = self.arch.clusters[cl].iter().map(|&c| free[c]).sum();
            let usable = self.arch.clusters[cl]
                .iter()
                .any(|&c| free[c] > 0 && !snap.throttled[c]);
            if usable && cluster_free >= need {
                return Some(cl);
            }
        }
        // Fall back: biggest cluster with any unthrottled free chiplet.
        self.size_order
            .iter()
            .rev()
            .copied()
            .find(|&cl| {
                self.arch.clusters[cl].iter().any(|&c| free[c] > 0 && !snap.throttled[c])
            })
    }
}

impl Scheduler for BigLittleSched {
    fn name(&self) -> &'static str {
        "big_little"
    }

    fn schedule(&mut self, job: &Job, snap: &SysSnapshot) -> Option<Mapping> {
        if job.dcg.total_weight_bits() > snap.total_free() {
            return None;
        }
        let mut free = snap.free_bits.clone();
        let mut layers = Vec::with_capacity(job.dcg.num_layers());
        for layer in &job.dcg.layers {
            let mut need = layer.weight_bits;
            let mut parts: Vec<(usize, u64)> = Vec::new();
            let mut guard = 0;
            while need > 0 {
                guard += 1;
                if guard > 2 * NUM_PIM_TYPES + 2 {
                    return None;
                }
                let cl = self.pick_cluster(snap, &free, need)?;
                // Highest-utilization-first within the cluster.
                let cap = self.arch.specs[cl].mem_bits;
                let mut cands: Vec<usize> = self.arch.clusters[cl]
                    .iter()
                    .copied()
                    .filter(|&c| free[c] > 0 && !snap.throttled[c])
                    .collect();
                cands.sort_by(|&a, &b| {
                    let ua = cap - free[a]; // used bits
                    let ub = cap - free[b];
                    ub.cmp(&ua).then(a.cmp(&b))
                });
                let placed = fill_chiplets(&cands, &mut free, need);
                let got: u64 = placed.iter().map(|&(_, b)| b).sum();
                if got == 0 {
                    return None;
                }
                need -= got;
                parts.extend(placed);
            }
            layers.push(LayerAssignment { parts });
        }
        Some(Mapping { layers })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::PimType;
    use crate::noi::NoiTopology;
    use crate::workload::{DnnModel, ModelZoo};

    fn job(m: DnnModel) -> Job {
        let zoo = ModelZoo::new();
        Job { id: 0, dcg: zoo.dcg(m), images: 100, arrival_s: 0.0 }
    }

    #[test]
    fn early_small_layers_go_little() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let snap = SysSnapshot::fresh(&arch);
        let mut s = BigLittleSched::new(arch.clone());
        let j = job(DnnModel::ResNet18);
        let m = s.schedule(&j, &snap).unwrap();
        // The first layer (9.4k params) fits the ADC-less (littlest)
        // cluster entirely.
        let first_cluster = arch.chiplets[m.layers[0].parts[0].0].pim;
        assert_eq!(first_cluster, PimType::AdcLess);
        // All layers complete.
        for (i, la) in m.layers.iter().enumerate() {
            assert_eq!(la.total_bits(), j.dcg.layers[i].weight_bits, "layer {i}");
        }
    }

    #[test]
    fn packs_by_utilization() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let snap = SysSnapshot::fresh(&arch);
        let mut s = BigLittleSched::new(arch.clone());
        let j = job(DnnModel::MobileNetV3Large);
        let m = s.schedule(&j, &snap).unwrap();
        // Big-Little concentrates weights: the number of distinct chiplets
        // used should be near the theoretical minimum for the model
        // (MobileNet overflows the 15-chiplet ADC-less cluster, so ~16 is
        // the tight packing).
        let used = m.chiplets_used().len();
        assert!(used <= 18, "big-little should pack tightly, used {used}");
    }

    #[test]
    fn big_layers_go_big_clusters() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let snap = SysSnapshot::fresh(&arch);
        let mut s = BigLittleSched::new(arch.clone());
        let j = job(DnnModel::AlexNet);
        let m = s.schedule(&j, &snap).unwrap();
        // AlexNet fc6 (≈300 Mb) cannot fit the little clusters; its parts
        // must land on big clusters (accumulator / shared-ADC / standard).
        let fc6 = j.dcg.layers.iter().position(|l| l.name == "fc6").unwrap();
        for &(c, _) in &m.layers[fc6].parts {
            assert_ne!(arch.chiplets[c].pim, PimType::AdcLess);
        }
    }
}
