//! RELMAS baseline scheduler [8]: flat deep-RL scheduling.
//!
//! RELMAS selects *individual chiplets* with a neural-network policy (no
//! cluster hierarchy, no decision tree). We adapt it to the PIM system as
//! the paper does (§5.2): the MLP policy scores all chiplets, invalid
//! (full/throttled) chiplets are masked, and the chosen chiplet is filled
//! before the policy is queried again for the layer's remainder. Its vast
//! flat action space (78 chiplets vs THERMOS's 4 clusters) is exactly the
//! convergence handicap the paper discusses.

use super::policy::{argmax_action, masked_softmax, sample_action, PolicyEval};
use super::state::StateEncoder;
use super::{Scheduler, SysSnapshot};
use crate::arch::Arch;
use crate::sim::mapping::{LayerAssignment, Mapping};
use crate::util::rng::Rng;
use crate::workload::Job;

/// One flat decision (chiplet-level) for PPO training.
#[derive(Clone, Debug)]
pub struct RelmasDecision {
    pub job_id: u64,
    pub obs: Vec<f32>,
    pub mask: Vec<bool>,
    pub action: usize,
    pub logp: f32,
}

pub struct RelmasSched<P: PolicyEval> {
    arch: Arch,
    encoder: StateEncoder,
    pub policy: P,
    pub sample_rng: Option<Rng>,
    pub record: bool,
    pub decisions: Vec<RelmasDecision>,
}

impl<P: PolicyEval> RelmasSched<P> {
    pub fn new(arch: Arch, encoder: StateEncoder, policy: P) -> Self {
        RelmasSched { arch, encoder, policy, sample_rng: None, record: false, decisions: Vec::new() }
    }

    pub fn sampling(mut self, rng: Rng) -> Self {
        self.sample_rng = Some(rng);
        self
    }

    pub fn take_decisions(&mut self) -> Vec<RelmasDecision> {
        std::mem::take(&mut self.decisions)
    }
}

impl<P: PolicyEval> Scheduler for RelmasSched<P> {
    fn name(&self) -> &'static str {
        "relmas"
    }

    fn schedule(&mut self, job: &Job, snap: &SysSnapshot) -> Option<Mapping> {
        let n = self.arch.num_chiplets();
        let usable: u64 =
            (0..n).filter(|&c| !snap.throttled[c]).map(|c| snap.free_bits[c]).sum();
        if job.dcg.total_weight_bits() > usable {
            return None;
        }
        let mut free = snap.free_bits.clone();
        let mut layers = Vec::with_capacity(job.dcg.num_layers());
        let mut prev: Vec<(usize, u64)> = Vec::new();
        let checkpoint = self.decisions.len();

        for (li, layer) in job.dcg.layers.iter().enumerate() {
            let mut need = layer.weight_bits;
            let mut parts: Vec<(usize, u64)> = Vec::new();
            while need > 0 {
                let mask: Vec<bool> =
                    (0..n).map(|c| free[c] > 0 && !snap.throttled[c]).collect();
                if !mask.iter().any(|&m| m) {
                    self.decisions.truncate(checkpoint);
                    return None;
                }
                let obs = self.encoder.encode_relmas(&self.arch, snap, job, li, need, &prev);
                let logits = self.policy.logits(&obs);
                let probs = masked_softmax(&logits, &mask);
                let (action, logp) = match &mut self.sample_rng {
                    Some(rng) => sample_action(&probs, rng),
                    None => {
                        let a = argmax_action(&probs);
                        (a, probs[a].max(1e-12).ln())
                    }
                };
                if self.record {
                    self.decisions.push(RelmasDecision {
                        job_id: job.id,
                        obs,
                        mask: mask.clone(),
                        action,
                        logp,
                    });
                }
                let take = free[action].min(need);
                if take == 0 {
                    self.decisions.truncate(checkpoint);
                    return None;
                }
                free[action] -= take;
                need -= take;
                parts.push((action, take));
            }
            prev = parts.clone();
            layers.push(LayerAssignment { parts });
        }
        Some(Mapping { layers })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noi::NoiTopology;
    use crate::sched::policy::NativeMlp;
    use crate::sched::state::relmas_obs_dim;
    use crate::workload::{DnnModel, ModelZoo};

    fn setup() -> (Arch, SysSnapshot, RelmasSched<NativeMlp>, Job) {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let snap = SysSnapshot::fresh(&arch);
        let zoo = ModelZoo::new();
        let enc = StateEncoder::new(&arch, &zoo, 20_000);
        let n = arch.num_chiplets();
        let mut rng = Rng::new(5);
        let mlp = NativeMlp::init(vec![relmas_obs_dim(n), 128, 128, n], &mut rng);
        let sched = RelmasSched::new(arch.clone(), enc, mlp);
        let job = Job { id: 0, dcg: zoo.dcg(DnnModel::ResNet18), images: 100, arrival_s: 0.0 };
        (arch, snap, sched, job)
    }

    #[test]
    fn complete_mapping_from_untrained_mlp() {
        let (arch, snap, mut sched, job) = setup();
        let m = sched.schedule(&job, &snap).expect("fits");
        assert_eq!(m.layers.len(), job.dcg.num_layers());
        for (i, la) in m.layers.iter().enumerate() {
            assert_eq!(la.total_bits(), job.dcg.layers[i].weight_bits, "layer {i}");
        }
        let per = m.bits_per_chiplet(arch.num_chiplets());
        for (c, &b) in per.iter().enumerate() {
            assert!(b <= snap.free_bits[c]);
        }
    }

    #[test]
    fn flat_decisions_recorded() {
        let (_, snap, mut sched, job) = setup();
        sched.record = true;
        sched.sample_rng = Some(Rng::new(9));
        let _ = sched.schedule(&job, &snap).unwrap();
        let ds = sched.take_decisions();
        assert!(ds.len() >= job.dcg.num_layers());
        for d in &ds {
            assert!(d.mask[d.action]);
            assert_eq!(d.obs.len(), sched.encoder.encode_relmas(
                &sched.arch, &snap, &job, 0, 1, &[]).len());
        }
    }

    #[test]
    fn respects_throttle_mask() {
        let (arch, mut snap, mut sched, job) = setup();
        // Throttle the first half of the system.
        for t in snap.throttled.iter_mut().take(arch.num_chiplets() / 2) {
            *t = true;
        }
        let m = sched.schedule(&job, &snap).expect("still fits");
        for la in &m.layers {
            for &(c, _) in &la.parts {
                assert!(!snap.throttled[c], "placed on throttled chiplet {c}");
            }
        }
    }
}
