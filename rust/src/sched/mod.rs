//! Scheduling framework: the `Scheduler` trait consumed by the simulator,
//! the system snapshot schedulers see, and the concrete policies — the
//! two-level THERMOS scheduler plus the Simba [54], Big-Little [32], and
//! RELMAS [8] baselines.

pub mod biglittle;
pub mod explain;
pub mod policy;
pub mod proximity;
pub mod relmas;
pub mod simba;
pub mod state;
pub mod thermos;

pub use biglittle::BigLittleSched;
pub use relmas::RelmasSched;
pub use simba::SimbaSched;
pub use thermos::ThermosSched;

use crate::arch::Arch;
use crate::sim::mapping::Mapping;
use crate::workload::Job;

/// What a scheduler can see when a job reaches the head of the queue:
/// the ACG's dynamic fields (`M_i(t)`, `T_i(t)`, throttle state).
#[derive(Clone, Debug)]
pub struct SysSnapshot {
    /// Free crossbar memory per chiplet, bits.
    pub free_bits: Vec<u64>,
    /// Die temperature per chiplet, K.
    pub temps: Vec<f64>,
    /// Throttle latch per chiplet (no new assignments while set, §4.1).
    pub throttled: Vec<bool>,
}

impl SysSnapshot {
    pub fn fresh(arch: &Arch) -> SysSnapshot {
        SysSnapshot {
            free_bits: arch.chiplets.iter().map(|c| arch.specs[c.pim as usize].mem_bits).collect(),
            temps: vec![arch.t_ambient; arch.num_chiplets()],
            throttled: vec![false; arch.num_chiplets()],
        }
    }

    pub fn total_free(&self) -> u64 {
        self.free_bits.iter().sum()
    }

    pub fn cluster_free(&self, arch: &Arch, cluster: usize) -> u64 {
        arch.clusters[cluster].iter().map(|&c| self.free_bits[c]).sum()
    }

    pub fn cluster_max_temp(&self, arch: &Arch, cluster: usize) -> f64 {
        arch.clusters[cluster].iter().map(|&c| self.temps[c]).fold(f64::MIN, f64::max)
    }

    /// A cluster can accept work if some chiplet has memory and is not
    /// throttled.
    pub fn cluster_available(&self, arch: &Arch, cluster: usize) -> bool {
        arch.clusters[cluster]
            .iter()
            .any(|&c| self.free_bits[c] > 0 && !self.throttled[c])
    }
}

/// A scheduler maps a whole job (every layer) or declines (insufficient
/// resources — the job stays queued). Implementations mutate their own
/// copy of the snapshot while assigning; the engine validates and commits.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Produce a complete mapping for `job`, or `None` to leave it queued.
    fn schedule(&mut self, job: &Job, snap: &SysSnapshot) -> Option<Mapping>;

    /// Notification hooks (training uses these; default no-op).
    fn on_job_completed(&mut self, _job_id: u64) {}
}

/// Greedy fill helper shared by every scheduler: walk `candidates` in
/// order, placing as much of `need_bits` as each chiplet's free memory
/// allows. Returns placed parts (may be incomplete if memory ran out).
pub fn fill_chiplets(
    candidates: &[usize],
    free_bits: &mut [u64],
    mut need_bits: u64,
) -> Vec<(usize, u64)> {
    let mut parts = Vec::new();
    for &c in candidates {
        if need_bits == 0 {
            break;
        }
        let take = free_bits[c].min(need_bits);
        if take > 0 {
            parts.push((c, take));
            free_bits[c] -= take;
            need_bits -= take;
        }
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noi::NoiTopology;

    #[test]
    fn snapshot_fresh_has_full_memory() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let s = SysSnapshot::fresh(&arch);
        assert_eq!(s.total_free(), arch.total_memory_bits());
        for cl in 0..4 {
            assert!(s.cluster_available(&arch, cl));
            assert_eq!(s.cluster_max_temp(&arch, cl), arch.t_ambient);
        }
    }

    #[test]
    fn fill_respects_capacity_and_order() {
        let mut free = vec![100u64, 50, 200];
        let parts = fill_chiplets(&[1, 0, 2], &mut free, 180);
        assert_eq!(parts, vec![(1, 50), (0, 100), (2, 30)]);
        assert_eq!(free, vec![0, 0, 170]);
        // Incomplete fill when memory short.
        let mut free2 = vec![10u64];
        let parts2 = fill_chiplets(&[0], &mut free2, 25);
        assert_eq!(parts2, vec![(0, 10)]);
    }
}
