//! RL state construction (§4.2.1): layer features, DL-workload features,
//! and PIM-cluster features, all normalized to [0, 1]-ish ranges, plus the
//! preference vector ω appended — exactly the 22-dim input the DDT policy
//! (and the AOT artifacts) consume. Also the flat per-chiplet observation
//! used by the RELMAS baseline.

use super::SysSnapshot;
use crate::arch::{Arch, NUM_PIM_TYPES};
use crate::workload::{Job, ModelZoo};

/// THERMOS policy input dimension: 20 features + 2 preference entries.
pub const STATE_DIM: usize = 22;
/// Action space: the four PIM clusters.
pub const NUM_CLUSTERS: usize = NUM_PIM_TYPES;

/// Normalization constants (fixed per system + zoo, shared with training).
#[derive(Clone, Debug)]
pub struct StateEncoder {
    max_layer_w: f64,
    max_layer_o: f64,
    max_layer_f: f64,
    max_model_w: f64,
    max_model_o: f64,
    max_model_f: f64,
    max_layers: f64,
    max_images: f64,
    cluster_cap: [f64; NUM_CLUSTERS],
    t_ambient: f64,
    t_max: [f64; NUM_CLUSTERS],
}

impl StateEncoder {
    pub fn new(arch: &Arch, zoo: &ModelZoo, max_images: u64) -> StateEncoder {
        let mut cluster_cap = [0.0; NUM_CLUSTERS];
        let mut t_max = [0.0; NUM_CLUSTERS];
        for cl in 0..NUM_CLUSTERS {
            cluster_cap[cl] = arch.cluster_memory_bits(crate::arch::PimType::from_index(cl)) as f64;
            t_max[cl] = arch.specs[cl].t_max_k;
        }
        StateEncoder {
            max_layer_w: zoo.max_layer_weight_bits() as f64,
            max_layer_o: zoo.max_layer_macs() as f64,
            max_layer_f: zoo.max_layer_act_bits() as f64,
            max_model_w: zoo.max_model_weight_bits() as f64,
            max_model_o: zoo.max_model_macs() as f64,
            max_model_f: zoo.max_model_act_bits() as f64,
            max_layers: zoo.max_layers() as f64,
            max_images: max_images.max(1) as f64,
            cluster_cap,
            t_ambient: arch.t_ambient,
            t_max,
        }
    }

    /// Build the 22-dim state for scheduling layer `layer_idx` of `job`,
    /// with `need_bits` still unassigned (tiling re-decisions shrink it),
    /// previous placement `prev`, and runtime preference `omega`.
    #[allow(clippy::too_many_arguments)]
    pub fn encode(
        &self,
        arch: &Arch,
        snap: &SysSnapshot,
        job: &Job,
        layer_idx: usize,
        need_bits: u64,
        prev: &[(usize, u64)],
        omega: [f32; 2],
    ) -> [f32; STATE_DIM] {
        let dcg = &job.dcg;
        let layer = &dcg.layers[layer_idx];
        let mut s = [0.0f32; STATE_DIM];
        // -- layer features
        s[0] = (need_bits as f64 / self.max_layer_w) as f32;
        s[1] = (layer.macs as f64 / self.max_layer_o) as f32;
        s[2] = (dcg.in_bits(layer_idx) as f64 / self.max_layer_f) as f32;
        // -- workload features (remaining = this layer onwards)
        let remaining = &dcg.layers[layer_idx..];
        s[3] = (remaining.len() as f64 / self.max_layers) as f32;
        s[4] = (remaining.iter().map(|l| l.weight_bits).sum::<u64>() as f64 / self.max_model_w)
            as f32;
        s[5] = (remaining.iter().map(|l| l.macs).sum::<u64>() as f64 / self.max_model_o) as f32;
        s[6] = (remaining.iter().map(|l| l.out_bits).sum::<u64>() as f64 / self.max_model_f) as f32;
        s[7] = (job.images as f64 / self.max_images) as f32;
        // -- PIM cluster features
        for cl in 0..NUM_CLUSTERS {
            let free = snap.cluster_free(arch, cl) as f64;
            s[8 + cl] = (free / self.cluster_cap[cl]) as f32;
            let t = snap.cluster_max_temp(arch, cl);
            let headroom = (self.t_max[cl] - t) / (self.t_max[cl] - self.t_ambient);
            s[12 + cl] = headroom.clamp(-1.0, 1.0) as f32;
        }
        // -- previous placement ψ_{i-1}: share of prev layer per cluster
        let prev_total: u64 = prev.iter().map(|&(_, b)| b).sum();
        if prev_total > 0 {
            for &(c, b) in prev {
                let cl = arch.chiplets[c].pim as usize;
                s[16 + cl] += (b as f64 / prev_total as f64) as f32;
            }
        }
        // -- preference vector
        s[20] = omega[0];
        s[21] = omega[1];
        s
    }

    /// RELMAS flat observation: 8 workload dims + per-chiplet free-memory
    /// fraction + per-chiplet previous-placement share + 4 cluster thermal
    /// headrooms. Length = `2·n_chiplets + 12`.
    pub fn encode_relmas(
        &self,
        arch: &Arch,
        snap: &SysSnapshot,
        job: &Job,
        layer_idx: usize,
        need_bits: u64,
        prev: &[(usize, u64)],
    ) -> Vec<f32> {
        let n = arch.num_chiplets();
        let mut s = vec![0.0f32; relmas_obs_dim(n)];
        let base = self.encode(arch, snap, job, layer_idx, need_bits, prev, [0.5, 0.5]);
        s[..8].copy_from_slice(&base[..8]);
        for c in 0..n {
            let cap = arch.spec(c).mem_bits as f64;
            s[8 + c] = (snap.free_bits[c] as f64 / cap) as f32;
        }
        let prev_total: u64 = prev.iter().map(|&(_, b)| b).sum();
        if prev_total > 0 {
            for &(c, b) in prev {
                s[8 + n + c] = (b as f64 / prev_total as f64) as f32;
            }
        }
        for cl in 0..NUM_CLUSTERS {
            s[8 + 2 * n + cl] = base[12 + cl];
        }
        s
    }
}

/// RELMAS observation length for a system of `n` chiplets.
pub fn relmas_obs_dim(n: usize) -> usize {
    2 * n + 12
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noi::NoiTopology;
    use crate::workload::DnnModel;

    fn setup() -> (Arch, SysSnapshot, StateEncoder, Job) {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let snap = SysSnapshot::fresh(&arch);
        let zoo = ModelZoo::new();
        let enc = StateEncoder::new(&arch, &zoo, 20_000);
        let job = Job { id: 0, dcg: zoo.dcg(DnnModel::ResNet50), images: 10_000, arrival_s: 0.0 };
        (arch, snap, enc, job)
    }

    #[test]
    fn features_bounded() {
        let (arch, snap, enc, job) = setup();
        for li in 0..job.dcg.num_layers() {
            let s = enc.encode(
                &arch,
                &snap,
                &job,
                li,
                job.dcg.layers[li].weight_bits,
                &[],
                [1.0, 0.0],
            );
            for (i, &v) in s.iter().enumerate() {
                assert!((-1.0..=1.5).contains(&v), "feature {i} = {v} at layer {li}");
                assert!(v.is_finite());
            }
        }
    }

    #[test]
    fn fresh_system_features() {
        let (arch, snap, enc, job) = setup();
        let s = enc.encode(&arch, &snap, &job, 0, job.dcg.layers[0].weight_bits, &[], [0.5, 0.5]);
        // All clusters fully free, full thermal headroom.
        for cl in 0..4 {
            assert!((s[8 + cl] - 1.0).abs() < 1e-6);
            assert!((s[12 + cl] - 1.0).abs() < 1e-6);
            assert_eq!(s[16 + cl], 0.0); // no previous placement
        }
        assert_eq!(s[20], 0.5);
        assert_eq!(s[21], 0.5);
    }

    #[test]
    fn remaining_workload_shrinks() {
        let (arch, snap, enc, job) = setup();
        let s0 = enc.encode(&arch, &snap, &job, 0, 1, &[], [1.0, 0.0]);
        let last = job.dcg.num_layers() - 1;
        let s_last = enc.encode(&arch, &snap, &job, last, 1, &[], [1.0, 0.0]);
        assert!(s_last[3] < s0[3]);
        assert!(s_last[4] < s0[4]);
        assert!(s_last[5] < s0[5]);
    }

    #[test]
    fn prev_placement_shares_sum_to_one() {
        let (arch, snap, enc, job) = setup();
        let prev = vec![(0usize, 300u64), (arch.clusters[1][0], 700u64)];
        let s = enc.encode(&arch, &snap, &job, 1, 1, &prev, [0.0, 1.0]);
        let total: f32 = (0..4).map(|cl| s[16 + cl]).sum();
        assert!((total - 1.0).abs() < 1e-6);
        assert!((s[16] - 0.3).abs() < 1e-6);
        assert!((s[17] - 0.7).abs() < 1e-6);
    }

    #[test]
    fn relmas_obs_layout() {
        let (arch, snap, enc, job) = setup();
        let n = arch.num_chiplets();
        let obs = enc.encode_relmas(&arch, &snap, &job, 0, 1, &[]);
        assert_eq!(obs.len(), relmas_obs_dim(n));
        // Free fractions all 1 on a fresh system.
        for c in 0..n {
            assert!((obs[8 + c] - 1.0).abs() < 1e-6);
        }
    }
}
