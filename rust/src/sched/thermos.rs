//! The THERMOS two-level scheduler (§4, Algorithm 1).
//!
//! Level 1: the MORL policy (a [`PolicyEval`] — the AOT-compiled DDT
//! artifact via PJRT at runtime, or the bit-compatible native evaluator in
//! the training inner loop) selects a PIM cluster per layer, conditioned
//! on the runtime preference vector ω. Invalid clusters (no free memory or
//! fully throttled) are masked with −10⁷ before the softmax (§4.2.2).
//!
//! Level 2: the proximity-driven algorithm (§4.4) places the layer's
//! weights on concrete chiplets within the chosen cluster. Layers larger
//! than the cluster's remaining memory loop back to Level 1 for another
//! cluster (Algorithm 1's `while totalRemainingWeights > 0`).

use super::policy::{argmax_action, masked_softmax, sample_action, PolicyEval};
use super::proximity::assign_in_cluster;
use super::state::{StateEncoder, NUM_CLUSTERS};
use super::{Scheduler, SysSnapshot};
use crate::arch::Arch;
use crate::sim::mapping::{LayerAssignment, Mapping};
use crate::util::rng::Rng;
use crate::workload::Job;

/// Runtime preference vector ω (ω_L + ω_E = 1, §4.1).
pub type Preference = [f32; 2];

pub const PREF_EXEC_TIME: Preference = [1.0, 0.0];
pub const PREF_BALANCED: Preference = [0.5, 0.5];
pub const PREF_ENERGY: Preference = [0.0, 1.0];

/// One Level-1 decision, recorded for PPO training.
#[derive(Clone, Debug)]
pub struct Decision {
    pub job_id: u64,
    pub state: Vec<f32>,
    pub mask: [bool; NUM_CLUSTERS],
    pub action: usize,
    pub logp: f32,
}

/// Action selection mode.
pub enum SelectMode {
    /// Runtime: argmax over the masked distribution.
    Greedy,
    /// Training rollouts: stochastic sampling.
    Sample(Rng),
}

pub struct ThermosSched<P: PolicyEval> {
    arch: Arch,
    encoder: StateEncoder,
    pub policy: P,
    pub omega: Preference,
    pub mode: SelectMode,
    /// When set, every Level-1 decision is recorded for the trainer.
    pub record: bool,
    pub decisions: Vec<Decision>,
}

impl<P: PolicyEval> ThermosSched<P> {
    pub fn new(arch: Arch, encoder: StateEncoder, policy: P, omega: Preference) -> Self {
        assert!((omega[0] + omega[1] - 1.0).abs() < 1e-5, "preferences must sum to 1");
        ThermosSched {
            arch,
            encoder,
            policy,
            omega,
            mode: SelectMode::Greedy,
            record: false,
            decisions: Vec::new(),
        }
    }

    pub fn sampling(mut self, rng: Rng) -> Self {
        self.mode = SelectMode::Sample(rng);
        self
    }

    pub fn recording(mut self) -> Self {
        self.record = true;
        self
    }

    pub fn take_decisions(&mut self) -> Vec<Decision> {
        std::mem::take(&mut self.decisions)
    }

    /// Valid-action mask over clusters given the working free-memory view.
    fn mask(&self, snap: &SysSnapshot, free: &[u64]) -> [bool; NUM_CLUSTERS] {
        let mut m = [false; NUM_CLUSTERS];
        for (cl, mm) in m.iter_mut().enumerate() {
            *mm = self.arch.clusters[cl]
                .iter()
                .any(|&c| free[c] > 0 && !snap.throttled[c]);
        }
        m
    }
}

impl<P: PolicyEval> Scheduler for ThermosSched<P> {
    fn name(&self) -> &'static str {
        "thermos"
    }

    fn schedule(&mut self, job: &Job, snap: &SysSnapshot) -> Option<Mapping> {
        // Algorithm 1 line 4: weights must fit available memory.
        let usable: u64 = (0..self.arch.num_chiplets())
            .filter(|&c| !snap.throttled[c])
            .map(|c| snap.free_bits[c])
            .sum();
        if job.dcg.total_weight_bits() > usable {
            return None;
        }
        let mut free = snap.free_bits.clone();
        let mut layers = Vec::with_capacity(job.dcg.num_layers());
        let mut prev: Vec<(usize, u64)> = Vec::new();
        let checkpoint = self.decisions.len();

        for (li, layer) in job.dcg.layers.iter().enumerate() {
            let mut need = layer.weight_bits;
            let mut parts: Vec<(usize, u64)> = Vec::new();
            while need > 0 {
                let mask = self.mask(snap, &free);
                if !mask.iter().any(|&m| m) {
                    self.decisions.truncate(checkpoint);
                    return None;
                }
                // Level 1: MORL cluster selection.
                let state = self.encoder.encode(
                    &self.arch, snap, job, li, need, &prev, self.omega,
                );
                let logits = self.policy.logits(&state);
                let probs = masked_softmax(&logits, &mask);
                let (action, logp) = match &mut self.mode {
                    SelectMode::Greedy => {
                        let a = argmax_action(&probs);
                        (a, probs[a].max(1e-12).ln())
                    }
                    SelectMode::Sample(rng) => sample_action(&probs, rng),
                };
                if self.record {
                    self.decisions.push(Decision {
                        job_id: job.id,
                        state: state.to_vec(),
                        mask,
                        action,
                        logp,
                    });
                }
                // Level 2: proximity-driven placement inside the cluster.
                let placed = assign_in_cluster(&self.arch, snap, &mut free, action, need, &prev);
                let got: u64 = placed.iter().map(|&(_, b)| b).sum();
                if got == 0 {
                    // Masked cluster selection guarantees progress; zero
                    // placement means the mask and memory view diverged.
                    self.decisions.truncate(checkpoint);
                    return None;
                }
                need -= got;
                parts.extend(placed);
            }
            prev = parts.clone();
            layers.push(LayerAssignment { parts });
        }
        Some(Mapping { layers })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noi::NoiTopology;
    use crate::sched::policy::NativeDdt;
    use crate::sched::state::STATE_DIM;
    use crate::workload::{DnnModel, ModelZoo};

    fn setup(omega: Preference) -> (Arch, SysSnapshot, ThermosSched<NativeDdt>, Job) {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let snap = SysSnapshot::fresh(&arch);
        let zoo = ModelZoo::new();
        let enc = StateEncoder::new(&arch, &zoo, 20_000);
        let mut rng = Rng::new(11);
        let ddt = NativeDdt::init(STATE_DIM, NUM_CLUSTERS, &mut rng);
        let sched = ThermosSched::new(arch.clone(), enc, ddt, omega);
        let job = Job { id: 7, dcg: zoo.dcg(DnnModel::ResNet18), images: 1000, arrival_s: 0.0 };
        (arch, snap, sched, job)
    }

    #[test]
    fn untrained_policy_produces_complete_mapping() {
        let (arch, snap, mut sched, job) = setup(PREF_BALANCED);
        let m = sched.schedule(&job, &snap).expect("fits in empty system");
        assert_eq!(m.layers.len(), job.dcg.num_layers());
        for (i, la) in m.layers.iter().enumerate() {
            assert_eq!(la.total_bits(), job.dcg.layers[i].weight_bits, "layer {i}");
        }
        let per = m.bits_per_chiplet(arch.num_chiplets());
        for (c, &b) in per.iter().enumerate() {
            assert!(b <= snap.free_bits[c], "chiplet {c} overcommitted");
        }
    }

    #[test]
    fn records_decisions_when_asked() {
        let (_, snap, mut sched, job) = setup(PREF_EXEC_TIME);
        sched.record = true;
        sched.mode = SelectMode::Sample(Rng::new(3));
        let _ = sched.schedule(&job, &snap).unwrap();
        let ds = sched.take_decisions();
        // At least one decision per layer (more when tiling spills).
        assert!(ds.len() >= job.dcg.num_layers());
        for d in &ds {
            assert_eq!(d.job_id, 7);
            assert_eq!(d.state.len(), STATE_DIM);
            assert!(d.mask[d.action], "sampled action must be valid");
            assert!(d.logp <= 0.0);
            // Preference is embedded in the recorded state.
            assert_eq!(d.state[20], 1.0);
            assert_eq!(d.state[21], 0.0);
        }
        assert!(sched.take_decisions().is_empty(), "take drains");
    }

    #[test]
    fn declines_on_throttled_system_and_rolls_back_decisions() {
        let (_, mut snap, mut sched, job) = setup(PREF_ENERGY);
        sched.record = true;
        snap.throttled.iter_mut().for_each(|t| *t = true);
        assert!(sched.schedule(&job, &snap).is_none());
        assert!(sched.take_decisions().is_empty(), "failed schedule must not leak decisions");
    }

    #[test]
    fn huge_layer_tiles_across_clusters() {
        let (arch, snap, mut sched, _) = setup(PREF_BALANCED);
        let zoo = ModelZoo::new();
        // AlexNet fc6 exceeds every single cluster's capacity → the
        // while-loop must produce parts in ≥ 2 clusters.
        let job = Job { id: 1, dcg: zoo.dcg(DnnModel::AlexNet), images: 10, arrival_s: 0.0 };
        let m = sched.schedule(&job, &snap).expect("alexnet fits the system");
        let fc6 = job.dcg.layers.iter().position(|l| l.name == "fc6").unwrap();
        let clusters_used: std::collections::HashSet<usize> = m.layers[fc6]
            .parts
            .iter()
            .map(|&(c, _)| arch.chiplets[c].pim as usize)
            .collect();
        assert!(clusters_used.len() >= 2, "fc6 should span clusters: {clusters_used:?}");
    }
}
