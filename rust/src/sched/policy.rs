//! Policy function approximators, native Rust implementations.
//!
//! The canonical policy artifacts are the AOT-compiled HLO graphs built by
//! `python/compile/aot.py` and executed through PJRT
//! ([`crate::runtime`]). This module provides *bit-compatible* native
//! evaluators over the same flat parameter layout (the layout is pinned in
//! `artifacts/abi.json` and asserted in integration tests):
//!
//! * [`NativeDdt`] — the soft differentiable decision tree actor (§4.3.1);
//! * [`NativeMlp`] — the critic / RELMAS actor MLP;
//!
//! The native path exists for the training inner loop (millions of tiny
//! forward passes where per-call PJRT dispatch would dominate — see
//! EXPERIMENTS.md §Perf); correctness is anchored to the artifacts by
//! round-trip tests.

use crate::util::rng::Rng;

/// DDT geometry (Table 4: depth 5).
pub const DDT_DEPTH: usize = 5;
pub const DDT_INTERNAL: usize = (1 << DDT_DEPTH) - 1; // 31
pub const DDT_LEAVES: usize = 1 << DDT_DEPTH; // 32

/// Flat parameter length of a DDT with `state_dim` inputs and
/// `num_actions` outputs: per internal node a weight row + bias +
/// steepness, plus per-leaf action logits.
pub const fn ddt_theta_len(state_dim: usize, num_actions: usize) -> usize {
    DDT_INTERNAL * (state_dim + 2) + DDT_LEAVES * num_actions
}

/// Anything that maps a state to action logits (cluster scores).
pub trait PolicyEval {
    fn num_actions(&self) -> usize;
    fn logits(&mut self, x: &[f32]) -> Vec<f32>;
}

/// Soft differentiable decision tree (§4.3.1, Fig. 3a).
///
/// Internal node j computes σ(β_j·(w_j·x + b_j)); the probability of
/// reaching a leaf is the product of branch probabilities along its path
/// (heap indexing: children of j are 2j+1 / 2j+2); the output is the
/// leaf-probability-weighted mixture of per-leaf action logit vectors.
///
/// Parameter layout (must match `python/compile/model.py::ddt_forward`):
/// `[w: internal×state_dim, b: internal, beta: internal,
///   leaves: leaves×actions]`, row-major, f32.
#[derive(Clone, Debug)]
pub struct NativeDdt {
    pub state_dim: usize,
    pub num_actions: usize,
    pub theta: Vec<f32>,
}

impl NativeDdt {
    pub fn new(state_dim: usize, num_actions: usize, theta: Vec<f32>) -> NativeDdt {
        assert_eq!(theta.len(), ddt_theta_len(state_dim, num_actions));
        NativeDdt { state_dim, num_actions, theta }
    }

    /// Xavier-ish random init matching the python initializer.
    pub fn init(state_dim: usize, num_actions: usize, rng: &mut Rng) -> NativeDdt {
        let len = ddt_theta_len(state_dim, num_actions);
        let mut theta = vec![0.0f32; len];
        let wscale = (1.0 / state_dim as f64).sqrt();
        let (wlen, ilen) = (DDT_INTERNAL * state_dim, DDT_INTERNAL);
        for v in theta.iter_mut().take(wlen) {
            *v = (rng.gaussian() * wscale) as f32;
        }
        // b = 0; beta = 1.
        for v in theta.iter_mut().skip(wlen + ilen).take(ilen) {
            *v = 1.0;
        }
        for v in theta.iter_mut().skip(wlen + 2 * ilen) {
            *v = (rng.gaussian() * 0.1) as f32;
        }
        NativeDdt { state_dim, num_actions, theta }
    }

    #[inline]
    fn split(&self) -> (&[f32], &[f32], &[f32], &[f32]) {
        let d = self.state_dim;
        let wlen = DDT_INTERNAL * d;
        let (w, rest) = self.theta.split_at(wlen);
        let (b, rest) = rest.split_at(DDT_INTERNAL);
        let (beta, leaves) = rest.split_at(DDT_INTERNAL);
        (w, b, beta, leaves)
    }

    /// Mixture-of-leaves forward pass.
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.state_dim);
        let (w, b, beta, leaves) = self.split();
        let d = self.state_dim;
        // Node activations σ(β(w·x + b)).
        let mut z = [0.0f32; DDT_INTERNAL];
        for (j, zj) in z.iter_mut().enumerate() {
            let row = &w[j * d..(j + 1) * d];
            let mut acc = b[j];
            for (wi, xi) in row.iter().zip(x) {
                acc += wi * xi;
            }
            *zj = sigmoid(beta[j] * acc);
        }
        // Path probabilities via breadth-first products.
        let mut probs = [0.0f32; 2 * DDT_INTERNAL + 1];
        probs[0] = 1.0;
        for j in 0..DDT_INTERNAL {
            let p = probs[j];
            probs[2 * j + 1] = p * z[j]; // left branch ≡ σ
            probs[2 * j + 2] = p * (1.0 - z[j]);
        }
        // Leaves occupy heap slots [DDT_INTERNAL .. 2·DDT_INTERNAL+1).
        let mut out = vec![0.0f32; self.num_actions];
        for l in 0..DDT_LEAVES {
            let p = probs[DDT_INTERNAL + l];
            let row = &leaves[l * self.num_actions..(l + 1) * self.num_actions];
            for (o, r) in out.iter_mut().zip(row) {
                *o += p * r;
            }
        }
        out
    }
}

impl PolicyEval for NativeDdt {
    fn num_actions(&self) -> usize {
        self.num_actions
    }
    fn logits(&mut self, x: &[f32]) -> Vec<f32> {
        self.forward(x)
    }
}

/// Plain ReLU MLP over a flat parameter vector. Layout per layer:
/// `W (out×in, row-major), b (out)`, concatenated in order. Last layer
/// linear. Used for the critic (22→64→64→64→2) and the RELMAS actor/critic.
#[derive(Clone, Debug)]
pub struct NativeMlp {
    pub dims: Vec<usize>,
    pub params: Vec<f32>,
}

/// Flat parameter length of an MLP with the given layer dims.
pub fn mlp_param_len(dims: &[usize]) -> usize {
    dims.windows(2).map(|w| w[0] * w[1] + w[1]).sum()
}

impl NativeMlp {
    pub fn new(dims: Vec<usize>, params: Vec<f32>) -> NativeMlp {
        assert_eq!(params.len(), mlp_param_len(&dims));
        NativeMlp { dims, params }
    }

    pub fn init(dims: Vec<usize>, rng: &mut Rng) -> NativeMlp {
        let mut params = vec![0.0f32; mlp_param_len(&dims)];
        let mut off = 0;
        for w in dims.windows(2) {
            let (fan_in, fan_out) = (w[0], w[1]);
            let scale = (2.0 / fan_in as f64).sqrt();
            for v in params.iter_mut().skip(off).take(fan_in * fan_out) {
                *v = (rng.gaussian() * scale) as f32;
            }
            off += fan_in * fan_out + fan_out; // biases stay 0
        }
        NativeMlp { dims, params }
    }

    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.dims[0]);
        let mut act = x.to_vec();
        let mut off = 0;
        let last = self.dims.len() - 2;
        for (li, w) in self.dims.windows(2).enumerate() {
            let (fin, fout) = (w[0], w[1]);
            let wmat = &self.params[off..off + fin * fout];
            let bias = &self.params[off + fin * fout..off + fin * fout + fout];
            let mut next = vec![0.0f32; fout];
            for (o, nv) in next.iter_mut().enumerate() {
                let row = &wmat[o * fin..(o + 1) * fin];
                let mut acc = bias[o];
                for (wi, ai) in row.iter().zip(&act) {
                    acc += wi * ai;
                }
                *nv = if li < last { acc.max(0.0) } else { acc };
            }
            act = next;
            off += fin * fout + fout;
        }
        act
    }
}

impl PolicyEval for NativeMlp {
    fn num_actions(&self) -> usize {
        *self.dims.last().unwrap()
    }
    fn logits(&mut self, x: &[f32]) -> Vec<f32> {
        self.forward(x)
    }
}

#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Invalid-action mask value (§4.2.2: −10⁷ before softmax).
pub const MASK_NEG: f32 = -1.0e7;

/// Masked softmax: returns probabilities; invalid actions get ~0.
pub fn masked_softmax(logits: &[f32], valid: &[bool]) -> Vec<f32> {
    debug_assert_eq!(logits.len(), valid.len());
    let masked: Vec<f32> =
        logits.iter().zip(valid).map(|(&l, &v)| if v { l } else { l + MASK_NEG }).collect();
    let max = masked.iter().cloned().fold(f32::MIN, f32::max);
    let exps: Vec<f32> = masked.iter().map(|&l| (l - max).exp()).collect();
    let sum: f32 = exps.iter().sum();
    exps.into_iter().map(|e| e / sum).collect()
}

/// Sample an action from masked probabilities; returns (action, log-prob).
pub fn sample_action(probs: &[f32], rng: &mut Rng) -> (usize, f32) {
    let u = rng.f32();
    let mut acc = 0.0f32;
    let mut pick = probs.len() - 1;
    for (i, &p) in probs.iter().enumerate() {
        acc += p;
        if u < acc {
            pick = i;
            break;
        }
    }
    (pick, probs[pick].max(1e-12).ln())
}

/// Greedy action (runtime: §4.2.2 argmax).
pub fn argmax_action(probs: &[f32]) -> usize {
    probs
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::state::{NUM_CLUSTERS, STATE_DIM};
    use crate::util::testkit::{check, check_close, forall, vec_f32};

    #[test]
    fn theta_len_matches_design() {
        // DESIGN.md §4: 31·24 + 32·4 = 872 for the paper dims.
        assert_eq!(ddt_theta_len(STATE_DIM, NUM_CLUSTERS), 872);
        assert_eq!(mlp_param_len(&[22, 64, 64, 64, 2]), 9922);
    }

    #[test]
    fn ddt_leaf_mixture_is_convex() {
        // Output of the DDT is a convex combination of leaf vectors, so it
        // must lie within the min/max of leaf logits per action.
        forall(50, |rng| {
            let ddt = NativeDdt::init(STATE_DIM, NUM_CLUSTERS, rng);
            let x = vec_f32(rng, STATE_DIM, -1.0, 1.0);
            let out = ddt.forward(&x);
            let (_, _, _, leaves) = ddt.split();
            for a in 0..NUM_CLUSTERS {
                let col: Vec<f32> =
                    (0..DDT_LEAVES).map(|l| leaves[l * NUM_CLUSTERS + a]).collect();
                let lo = col.iter().cloned().fold(f32::MAX, f32::min) - 1e-5;
                let hi = col.iter().cloned().fold(f32::MIN, f32::max) + 1e-5;
                check(out[a] >= lo && out[a] <= hi, format!("action {a}: {} ∉ [{lo},{hi}]", out[a]))?;
            }
            Ok(())
        });
    }

    #[test]
    fn ddt_path_probs_sum_to_one() {
        // Implicit check: with all leaf vectors equal to 1, output = 1.
        forall(30, |rng| {
            let mut ddt = NativeDdt::init(STATE_DIM, NUM_CLUSTERS, rng);
            let wlen = DDT_INTERNAL * STATE_DIM;
            for v in ddt.theta.iter_mut().skip(wlen + 2 * DDT_INTERNAL) {
                *v = 1.0;
            }
            let x = vec_f32(rng, STATE_DIM, -2.0, 2.0);
            let out = ddt.forward(&x);
            for &o in &out {
                check_close(o as f64, 1.0, 1e-5, "mixture weight sum")?;
            }
            Ok(())
        });
    }

    #[test]
    fn ddt_hard_routing_with_huge_beta() {
        let mut rng = Rng::new(42);
        let mut ddt = NativeDdt::init(STATE_DIM, NUM_CLUSTERS, &mut rng);
        // Crank steepness: tree becomes a hard decision tree; output equals
        // exactly one leaf row.
        let wlen = DDT_INTERNAL * STATE_DIM;
        for v in ddt.theta.iter_mut().skip(wlen + DDT_INTERNAL).take(DDT_INTERNAL) {
            *v = 1e4;
        }
        let x = vec![0.3f32; STATE_DIM];
        let out = ddt.forward(&x);
        let (_, _, _, leaves) = ddt.split();
        let matches = (0..DDT_LEAVES).any(|l| {
            let row = &leaves[l * NUM_CLUSTERS..(l + 1) * NUM_CLUSTERS];
            row.iter().zip(&out).all(|(a, b)| (a - b).abs() < 1e-3)
        });
        assert!(matches, "hard-routed output should equal a leaf row: {out:?}");
    }

    #[test]
    fn mlp_relu_forward_known_values() {
        // 2→2→1 with hand-set params.
        // W1 = [[1, -1], [0, 2]], b1 = [0, 1]; W2 = [[1, 1]], b2 = [-0.5]
        let params = vec![1.0, -1.0, 0.0, 2.0, 0.0, 1.0, 1.0, 1.0, -0.5];
        let mlp = NativeMlp::new(vec![2, 2, 1], params);
        let out = mlp.forward(&[1.0, 0.5]);
        // h = relu([1-0.5, 0+1+1]) = [0.5, 2]; y = 0.5+2-0.5 = 2.0
        assert!((out[0] - 2.0).abs() < 1e-6);
        // Negative pre-activation clamps.
        let out2 = mlp.forward(&[-1.0, 0.0]);
        // h = relu([-1, 1]) = [0, 1]; y = 1 - 0.5 = 0.5
        assert!((out2[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn masked_softmax_zeroes_invalid() {
        let p = masked_softmax(&[1.0, 2.0, 3.0, 4.0], &[true, false, true, false]);
        assert!(p[1] < 1e-6 && p[3] < 1e-6);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(p[2] > p[0]);
    }

    #[test]
    fn sampling_respects_distribution() {
        let mut rng = Rng::new(7);
        let probs = masked_softmax(&[0.0, 0.0, 2.0, 0.0], &[true; 4]);
        let mut counts = [0usize; 4];
        for _ in 0..20_000 {
            let (a, lp) = sample_action(&probs, &mut rng);
            counts[a] += 1;
            assert!((lp - probs[a].ln()).abs() < 1e-5);
        }
        assert!(counts[2] > counts[0] * 3);
        assert_eq!(argmax_action(&probs), 2);
    }
}
