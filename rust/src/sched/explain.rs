//! DDT explainability (§4.3.1): the paper motivates the differentiable
//! decision tree over a neural policy because it is *explainable* — each
//! internal node is a linear test over named state features and each leaf
//! is an action distribution. This module renders a trained tree in
//! human-readable form (`thermos explain`).

use super::policy::{NativeDdt, DDT_INTERNAL, DDT_LEAVES};
use super::state::{NUM_CLUSTERS, STATE_DIM};
use std::fmt::Write as _;

/// Names of the 22 state-vector components (§4.2.1 order — must match
/// `StateEncoder::encode`).
pub const FEATURE_NAMES: [&str; STATE_DIM] = [
    "layer.weights",
    "layer.macs",
    "layer.in_activations",
    "workload.layers_left",
    "workload.weights_left",
    "workload.macs_left",
    "workload.act_left",
    "workload.images",
    "free_mem.standard",
    "free_mem.shared_adc",
    "free_mem.accumulator",
    "free_mem.adc_less",
    "thermal_headroom.standard",
    "thermal_headroom.shared_adc",
    "thermal_headroom.accumulator",
    "thermal_headroom.adc_less",
    "prev_placement.standard",
    "prev_placement.shared_adc",
    "prev_placement.accumulator",
    "prev_placement.adc_less",
    "omega.exec_time",
    "omega.energy",
];

pub const CLUSTER_NAMES: [&str; NUM_CLUSTERS] =
    ["standard", "shared_adc", "accumulator", "adc_less"];

/// Per-node summary: the k most influential features and the routing
/// steepness.
#[derive(Clone, Debug)]
pub struct NodeSummary {
    pub index: usize,
    pub depth: usize,
    pub bias: f32,
    pub beta: f32,
    /// (feature name, weight), ordered by |weight| descending.
    pub top_features: Vec<(&'static str, f32)>,
}

/// Summarize every internal node of a DDT.
pub fn summarize_nodes(ddt: &NativeDdt, top_k: usize) -> Vec<NodeSummary> {
    assert_eq!(ddt.state_dim, STATE_DIM);
    let d = ddt.state_dim;
    let w = &ddt.theta[..DDT_INTERNAL * d];
    let b = &ddt.theta[DDT_INTERNAL * d..DDT_INTERNAL * (d + 1)];
    let beta = &ddt.theta[DDT_INTERNAL * (d + 1)..DDT_INTERNAL * (d + 2)];
    (0..DDT_INTERNAL)
        .map(|j| {
            let row = &w[j * d..(j + 1) * d];
            let mut feats: Vec<(&'static str, f32)> =
                FEATURE_NAMES.iter().zip(row).map(|(&n, &v)| (n, v)).collect();
            feats.sort_by(|a, b| b.1.abs().partial_cmp(&a.1.abs()).unwrap());
            feats.truncate(top_k);
            NodeSummary {
                index: j,
                depth: (j + 1).ilog2() as usize,
                bias: b[j],
                beta: beta[j],
                top_features: feats,
            }
        })
        .collect()
}

/// Leaf action distributions (softmax of leaf logits, unmasked).
pub fn leaf_distributions(ddt: &NativeDdt) -> Vec<[f32; NUM_CLUSTERS]> {
    let d = ddt.state_dim;
    let leaves = &ddt.theta[DDT_INTERNAL * (d + 2)..];
    (0..DDT_LEAVES)
        .map(|l| {
            let row = &leaves[l * NUM_CLUSTERS..(l + 1) * NUM_CLUSTERS];
            let max = row.iter().cloned().fold(f32::MIN, f32::max);
            let exps: Vec<f32> = row.iter().map(|&x| (x - max).exp()).collect();
            let sum: f32 = exps.iter().sum();
            let mut out = [0.0f32; NUM_CLUSTERS];
            for (o, e) in out.iter_mut().zip(exps) {
                *o = e / sum;
            }
            out
        })
        .collect()
}

/// Render the full explanation report.
pub fn render(ddt: &NativeDdt, top_k: usize) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "DDT policy: depth {}, {} internal nodes, {} leaves, {} parameters",
        (DDT_INTERNAL + 1).ilog2(),
        DDT_INTERNAL,
        DDT_LEAVES,
        ddt.theta.len()
    );
    let _ = writeln!(s, "\nInternal nodes (σ(β·(w·s + b)); left branch taken when the test fires):");
    for n in summarize_nodes(ddt, top_k) {
        let feats: Vec<String> = n
            .top_features
            .iter()
            .map(|(name, v)| format!("{v:+.3}·{name}"))
            .collect();
        let _ = writeln!(
            s,
            "  {:indent$}node {:>2} (β={:+.2}, b={:+.2}): {}",
            "",
            n.index,
            n.beta,
            n.bias,
            feats.join("  "),
            indent = 2 * n.depth
        );
    }
    let _ = writeln!(s, "\nLeaf action distributions (softmax over cluster logits):");
    for (l, dist) in leaf_distributions(ddt).iter().enumerate() {
        let best = dist
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        let cells: Vec<String> = CLUSTER_NAMES
            .iter()
            .zip(dist)
            .map(|(n, p)| format!("{n} {:>4.1}%", p * 100.0))
            .collect();
        let _ = writeln!(s, "  leaf {:>2}: {}  → {}", l, cells.join("  "), CLUSTER_NAMES[best.0]);
    }
    // Aggregate feature importance: Σ_nodes |w_f| (a standard linear-tree
    // saliency measure).
    let d = ddt.state_dim;
    let w = &ddt.theta[..DDT_INTERNAL * d];
    let mut importance: Vec<(&'static str, f32)> = FEATURE_NAMES
        .iter()
        .enumerate()
        .map(|(f, &name)| {
            let total: f32 = (0..DDT_INTERNAL).map(|j| w[j * d + f].abs()).sum();
            (name, total)
        })
        .collect();
    importance.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let max = importance[0].1.max(1e-9);
    let _ = writeln!(s, "\nAggregate feature importance (Σ|w| across nodes):");
    for (name, v) in importance {
        let bar = "#".repeat(((v / max) * 40.0).round() as usize);
        let _ = writeln!(s, "  {name:<28} {v:>7.3} |{bar}");
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn ddt() -> NativeDdt {
        NativeDdt::init(STATE_DIM, NUM_CLUSTERS, &mut Rng::new(3))
    }

    #[test]
    fn node_summaries_cover_tree() {
        let ns = summarize_nodes(&ddt(), 3);
        assert_eq!(ns.len(), DDT_INTERNAL);
        assert_eq!(ns[0].depth, 0);
        assert_eq!(ns[1].depth, 1);
        assert_eq!(ns[30].depth, 4);
        for n in &ns {
            assert_eq!(n.top_features.len(), 3);
            // Sorted by |weight|.
            assert!(n.top_features[0].1.abs() >= n.top_features[1].1.abs());
        }
    }

    #[test]
    fn leaf_distributions_are_probabilities() {
        for dist in leaf_distributions(&ddt()) {
            let sum: f32 = dist.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            assert!(dist.iter().all(|&p| p >= 0.0));
        }
    }

    #[test]
    fn render_contains_all_sections() {
        let s = render(&ddt(), 3);
        assert!(s.contains("Internal nodes"));
        assert!(s.contains("Leaf action distributions"));
        assert!(s.contains("feature importance"));
        assert!(s.contains("omega.exec_time"));
        assert!(s.contains("free_mem.accumulator"));
    }

    #[test]
    fn feature_names_match_state_dim() {
        assert_eq!(FEATURE_NAMES.len(), STATE_DIM);
        assert_eq!(CLUSTER_NAMES.len(), NUM_CLUSTERS);
    }
}
