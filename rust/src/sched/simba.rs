//! Simba baseline scheduler [54]: nearest-neighbour placement.
//!
//! Simba's strategy maps consecutive layers to spatially nearby chiplets
//! to minimize inter-layer communication; it is type-blind (Simba is a
//! homogeneous MCM), so on the heterogeneous system it simply ranks *all*
//! available chiplets by weighted hop distance to the previous layer and
//! fills greedily.

use super::proximity::weighted_distance;
use super::{fill_chiplets, Scheduler, SysSnapshot};
use crate::arch::Arch;
use crate::sim::mapping::{LayerAssignment, Mapping};
use crate::workload::Job;

pub struct SimbaSched {
    arch: Arch,
}

impl SimbaSched {
    pub fn new(arch: Arch) -> SimbaSched {
        SimbaSched { arch }
    }
}

impl Scheduler for SimbaSched {
    fn name(&self) -> &'static str {
        "simba"
    }

    fn schedule(&mut self, job: &Job, snap: &SysSnapshot) -> Option<Mapping> {
        // Algorithm 1 guard: total weights must fit the free memory.
        if job.dcg.total_weight_bits() > snap.total_free() {
            return None;
        }
        let mut free = snap.free_bits.clone();
        let mut layers = Vec::with_capacity(job.dcg.num_layers());
        let mut prev: Vec<(usize, u64)> = Vec::new();
        for layer in &job.dcg.layers {
            // Rank every available chiplet by weighted distance to ψ_{i-1}.
            let mut cands: Vec<usize> = (0..self.arch.num_chiplets())
                .filter(|&c| free[c] > 0 && !snap.throttled[c])
                .collect();
            cands.sort_by(|&a, &b| {
                let da = weighted_distance(&self.arch, &prev, a);
                let db = weighted_distance(&self.arch, &prev, b);
                da.partial_cmp(&db).unwrap().then(a.cmp(&b))
            });
            let parts = fill_chiplets(&cands, &mut free, layer.weight_bits);
            let placed: u64 = parts.iter().map(|&(_, b)| b).sum();
            if placed < layer.weight_bits {
                return None; // not enough unthrottled memory right now
            }
            prev = parts.clone();
            layers.push(LayerAssignment { parts });
        }
        Some(Mapping { layers })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noi::NoiTopology;
    use crate::workload::{DnnModel, ModelZoo};

    fn job(m: DnnModel) -> Job {
        let zoo = ModelZoo::new();
        Job { id: 0, dcg: zoo.dcg(m), images: 100, arrival_s: 0.0 }
    }

    #[test]
    fn maps_all_layers_completely() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let snap = SysSnapshot::fresh(&arch);
        let mut s = SimbaSched::new(arch.clone());
        let j = job(DnnModel::ResNet50);
        let m = s.schedule(&j, &snap).expect("must fit in empty system");
        assert_eq!(m.layers.len(), j.dcg.num_layers());
        for (i, la) in m.layers.iter().enumerate() {
            assert_eq!(la.total_bits(), j.dcg.layers[i].weight_bits, "layer {i}");
        }
        // Memory conservation.
        let per = m.bits_per_chiplet(arch.num_chiplets());
        for (c, &b) in per.iter().enumerate() {
            assert!(b <= snap.free_bits[c], "chiplet {c} overcommitted");
        }
    }

    #[test]
    fn declines_when_memory_insufficient() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let mut snap = SysSnapshot::fresh(&arch);
        for b in snap.free_bits.iter_mut() {
            *b /= 64; // nearly full system
        }
        let mut s = SimbaSched::new(arch);
        assert!(s.schedule(&job(DnnModel::AlexNet), &snap).is_none());
    }

    #[test]
    fn consecutive_layers_stay_close() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let snap = SysSnapshot::fresh(&arch);
        let mut s = SimbaSched::new(arch.clone());
        let j = job(DnnModel::MobileNetV3Large);
        let m = s.schedule(&j, &snap).unwrap();
        // Mean hop distance between consecutive layer centroids must be
        // small (nearest-neighbour behaviour).
        let mut total_hops = 0.0;
        let mut count = 0.0;
        for w in m.layers.windows(2) {
            let d = w[1]
                .parts
                .iter()
                .map(|&(c, b)| {
                    b as f64 * weighted_distance(&arch, &w[0].parts, c)
                })
                .sum::<f64>()
                / w[1].total_bits() as f64;
            total_hops += d;
            count += 1.0;
        }
        assert!(total_hops / count < 3.0, "mean inter-layer hops {}", total_hops / count);
    }
}
