//! Level-2 proximity-driven chiplet allocation (§4.4).
//!
//! Once the MORL policy picks a PIM cluster for a layer, this algorithm
//! places the layer's weights on concrete chiplets of that cluster:
//! chiplets with free memory are sorted by the *weighted hop distance*
//! to the chiplets holding the previous layer (weights = the previous
//! layer's placement shares), then filled to capacity in order —
//! minimizing inter-layer NoI traffic while packing memory densely.

use super::{fill_chiplets, SysSnapshot};
use crate::arch::Arch;

/// Previous-layer placement (ψ_{i-1}): `(chiplet, bits)` parts. Empty for
/// the first layer — distance then falls back to the I/O boundary
/// (chiplet 0's corner of the interposer).
pub type PrevPlacement = [(usize, u64)];

/// Weighted hop distance from the previous layer's placement to chiplet
/// `c` (Σ share_s · hops(s, c)).
pub fn weighted_distance(arch: &Arch, prev: &PrevPlacement, c: usize) -> f64 {
    if prev.is_empty() {
        return arch.hops(0, c) as f64;
    }
    let total: u64 = prev.iter().map(|&(_, b)| b).sum();
    let total = total.max(1) as f64;
    prev.iter().map(|&(s, b)| (b as f64 / total) * arch.hops(s, c) as f64).sum()
}

/// Candidate order for a cluster: available chiplets sorted by weighted
/// distance (ties broken by physical distance, then id for determinism).
pub fn order_cluster_by_proximity(
    arch: &Arch,
    snap: &SysSnapshot,
    free_bits: &[u64],
    cluster: usize,
    prev: &PrevPlacement,
) -> Vec<usize> {
    let mut cands: Vec<usize> = arch.clusters[cluster]
        .iter()
        .copied()
        .filter(|&c| free_bits[c] > 0 && !snap.throttled[c])
        .collect();
    let keyed: Vec<(f64, f64, usize)> = cands
        .iter()
        .map(|&c| {
            let d = weighted_distance(arch, prev, c);
            let phys = if prev.is_empty() {
                0.0
            } else {
                prev.iter().map(|&(s, _)| arch.topology.dist_mm(s, c)).sum::<f64>()
            };
            (d, phys, c)
        })
        .collect();
    let mut order: Vec<usize> = (0..cands.len()).collect();
    order.sort_by(|&a, &b| {
        keyed[a]
            .0
            .partial_cmp(&keyed[b].0)
            .unwrap()
            .then(keyed[a].1.partial_cmp(&keyed[b].1).unwrap())
            .then(keyed[a].2.cmp(&keyed[b].2))
    });
    cands = order.into_iter().map(|i| keyed[i].2).collect();
    cands
}

/// Assign up to `need_bits` of a layer onto `cluster`, preferring chiplets
/// near the previous layer. Mutates `free_bits`. Returns the placed parts
/// (possibly incomplete — Algorithm 1's while-loop then asks the MORL
/// policy for another cluster).
pub fn assign_in_cluster(
    arch: &Arch,
    snap: &SysSnapshot,
    free_bits: &mut [u64],
    cluster: usize,
    need_bits: u64,
    prev: &PrevPlacement,
) -> Vec<(usize, u64)> {
    let order = order_cluster_by_proximity(arch, snap, free_bits, cluster, prev);
    fill_chiplets(&order, free_bits, need_bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{Arch, PimType};
    use crate::noi::NoiTopology;

    fn setup() -> (Arch, SysSnapshot) {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let snap = SysSnapshot::fresh(&arch);
        (arch, snap)
    }

    #[test]
    fn nearest_chiplet_first() {
        let (arch, snap) = setup();
        let mut free = snap.free_bits.clone();
        // Previous layer entirely on chiplet 0 (standard cluster).
        let prev = [(0usize, 1000u64)];
        let order =
            order_cluster_by_proximity(&arch, &snap, &free, PimType::Standard as usize, &prev);
        assert_eq!(order[0], 0, "chiplet 0 itself is distance 0");
        // Weighted distances must be non-decreasing along the order.
        let mut last = -1.0;
        for &c in &order {
            let d = weighted_distance(&arch, &prev, c);
            assert!(d >= last);
            last = d;
        }
        // Fill consumes nearest first.
        let parts = assign_in_cluster(
            &arch,
            &snap,
            &mut free,
            PimType::Standard as usize,
            arch.specs[0].mem_bits + 5,
            &prev,
        );
        assert_eq!(parts[0].0, 0);
        assert_eq!(parts[0].1, arch.specs[0].mem_bits);
        assert_eq!(parts[1].1, 5);
    }

    #[test]
    fn skips_throttled_and_full_chiplets() {
        let (arch, mut snap) = setup();
        let cl = PimType::Standard as usize;
        let first = arch.clusters[cl][0];
        let second = arch.clusters[cl][1];
        snap.throttled[first] = true;
        let mut free = snap.free_bits.clone();
        free[second] = 0;
        let prev = [(first, 100u64)];
        let order = order_cluster_by_proximity(&arch, &snap, &free, cl, &prev);
        assert!(!order.contains(&first), "throttled chiplet must be skipped");
        assert!(!order.contains(&second), "full chiplet must be skipped");
    }

    #[test]
    fn incomplete_fill_reports_partial() {
        let (arch, snap) = setup();
        let cl = PimType::Accumulator as usize;
        let mut free = snap.free_bits.clone();
        // Zero out all but one accumulator chiplet.
        for &c in &arch.clusters[cl][1..] {
            free[c] = 0;
        }
        let only = arch.clusters[cl][0];
        let need = arch.specs[cl].mem_bits * 3;
        let parts = assign_in_cluster(&arch, &snap, &mut free, cl, need, &[]);
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0], (only, arch.specs[cl].mem_bits));
    }

    #[test]
    fn weighted_distance_mixes_sources() {
        let (arch, _) = setup();
        // Half the previous layer on chiplet 0, half on a far chiplet.
        let far = arch.num_chiplets() - 1;
        let prev = [(0usize, 500u64), (far, 500u64)];
        let d0 = weighted_distance(&arch, &prev, 0);
        let expected = 0.5 * arch.hops(far, 0) as f64;
        assert!((d0 - expected).abs() < 1e-12);
    }
}
