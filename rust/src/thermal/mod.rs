//! Compact thermal model of the 2.5D package — our substitute for the
//! MFIT [45] discrete-state-space (DSS) mode the paper uses.
//!
//! RC network built from the floorplan: one node per chiplet die, one
//! interposer node under each die, and a shared lid/heat-spreader node;
//! ambient is the boundary. The continuous system
//! `C·dT/dt = -G·T + P + g_amb·T_amb` is discretized once at construction
//! with a matrix exponential (`x[k+1] = A_d x[k] + B_d P[k]`,
//! `x = T - T_amb`) at the paper's 100 ms sampling interval, so each
//! simulation step is a pair of mat-vecs — the same "very fast
//! matrix-vector formulation" the paper credits MFIT's DSS model for.

use crate::arch::Arch;
use crate::util::linalg::{LuFactor, Mat};

/// Package physical constants (DESIGN.md §6). Tuned so that sustained
/// full-rate activity on the ReRAM-heavy regions approaches the 330 K
/// Eq. 2 threshold with 300 K ambient — the regime the paper's thermal
/// management operates in.
#[derive(Clone, Debug)]
pub struct ThermalParams {
    /// Die heat capacity per mm² of die area (J/K/mm²): 0.3 mm silicon
    /// plus metallization.
    pub die_c_per_mm2: f64,
    /// Interposer node heat capacity per mm² (J/K/mm²).
    pub interposer_c_per_mm2: f64,
    /// Lid / heat-spreader heat capacity (J/K).
    pub lid_c: f64,
    /// Die → interposer vertical conductance per mm² (W/K/mm²), microbumps.
    pub die_interposer_g_per_mm2: f64,
    /// Die → lid conductance per mm² (W/K/mm²), TIM.
    pub die_lid_g_per_mm2: f64,
    /// Lateral interposer conductance between adjacent nodes (W/K).
    pub lateral_g: f64,
    /// Interposer → board/ambient conductance per node (W/K).
    pub interposer_amb_g: f64,
    /// Lid → ambient (heatsink) conductance (W/K).
    pub lid_amb_g: f64,
    /// Sampling interval (s); paper: 100 ms.
    pub dt_s: f64,
}

impl Default for ThermalParams {
    fn default() -> Self {
        ThermalParams {
            die_c_per_mm2: 5.0e-4,
            interposer_c_per_mm2: 2.5e-4,
            lid_c: 18.0,
            die_interposer_g_per_mm2: 0.125, // 2 K/W for a 4 mm² die
            die_lid_g_per_mm2: 0.017,        // ≈15 K/W for a 4 mm² die
            lateral_g: 0.015,
            interposer_amb_g: 0.003,
            lid_amb_g: 0.22,
            dt_s: 0.1,
        }
    }
}

/// Discrete-state-space thermal model.
#[derive(Clone, Debug)]
pub struct DssModel {
    n_chiplets: usize,
    n_nodes: usize,
    /// x[k+1] = ad·x[k] + bd·p[k], x = T - T_amb, p = per-chiplet power.
    ad: Mat,
    bd: Mat,
    /// Fused [A_d | B_d] (row-major, n_nodes × (n_nodes + n_chiplets)) so
    /// the per-step update is ONE contiguous matvec over z = [x; p]
    /// (EXPERIMENTS.md §Perf: ~1.5× faster than two separate passes).
    abd: Mat,
    /// LU of `I − A_d`, factored once at construction so every
    /// [`DssModel::steady_state`] query (called per candidate in
    /// thermal-effectiveness sweeps) is a pair of O(n²) substitutions
    /// instead of a fresh O(n³) factorization.
    ss_factor: LuFactor,
    /// Current state (K above ambient), length n_nodes.
    x: Vec<f64>,
    /// Fused input vector z = [x; p] staging buffer.
    z: Vec<f64>,
    scratch: Vec<f64>,
    pub t_ambient: f64,
    pub params: ThermalParams,
}

impl DssModel {
    pub fn new(arch: &Arch, params: ThermalParams) -> DssModel {
        let n = arch.num_chiplets();
        let n_nodes = 2 * n + 1; // dies, interposer nodes, lid
        let die = |i: usize| i;
        let ipo = |i: usize| n + i;
        let lid = 2 * n;

        // Heat capacities.
        let mut c = vec![0.0; n_nodes];
        for (i, ch) in arch.chiplets.iter().enumerate() {
            let area = arch.specs[ch.pim as usize].area_mm2;
            c[die(i)] = params.die_c_per_mm2 * area;
            c[ipo(i)] = params.interposer_c_per_mm2 * area;
        }
        c[lid] = params.lid_c;

        // Conductance (Laplacian) assembly: g[(a,b)] adds -g off-diagonal,
        // +g to both diagonals; ambient couplings add to diagonal only.
        let mut gmat = Mat::zeros(n_nodes, n_nodes);
        let couple = |g: &mut Mat, a: usize, b: usize, v: f64| {
            g[(a, b)] -= v;
            g[(b, a)] -= v;
            g[(a, a)] += v;
            g[(b, b)] += v;
        };
        for (i, ch) in arch.chiplets.iter().enumerate() {
            let area = arch.specs[ch.pim as usize].area_mm2;
            couple(&mut gmat, die(i), ipo(i), params.die_interposer_g_per_mm2 * area);
            couple(&mut gmat, die(i), lid, params.die_lid_g_per_mm2 * area);
            gmat[(ipo(i), ipo(i))] += params.interposer_amb_g;
        }
        gmat[(lid, lid)] += params.lid_amb_g;

        // Lateral interposer coupling between physically adjacent dies
        // (orthogonal + staggered neighbours: centre distance ≤ 1.25×pitch).
        let pitch = crate::noi::topologies::PITCH_MM;
        for i in 0..n {
            for j in (i + 1)..n {
                if arch.topology.dist_mm(i, j) <= 1.25 * pitch {
                    couple(&mut gmat, ipo(i), ipo(j), params.lateral_g);
                }
            }
        }

        // A = -C⁻¹·G ; B = C⁻¹·E (E maps chiplet power onto die nodes).
        let mut a = Mat::zeros(n_nodes, n_nodes);
        for r in 0..n_nodes {
            for cix in 0..n_nodes {
                a[(r, cix)] = -gmat[(r, cix)] / c[r];
            }
        }
        let mut b = Mat::zeros(n_nodes, n);
        for i in 0..n {
            b[(die(i), i)] = 1.0 / c[die(i)];
        }

        // Discretize: A_d = expm(A·dt); B_d = A⁻¹(A_d − I)·B.
        let ad = a.scale(params.dt_s).expm();
        let ad_minus_i = ad.sub(&Mat::eye(n_nodes));
        let bd = a.solve(&ad_minus_i.matmul(&b));
        let ss_factor = LuFactor::of(&Mat::eye(n_nodes).sub(&ad))
            .expect("I − A_d is nonsingular for a dissipative RC system");

        // Fuse [A_d | B_d] for the single-pass step.
        let mut abd = Mat::zeros(n_nodes, n_nodes + n);
        for r in 0..n_nodes {
            abd.data[r * (n_nodes + n)..r * (n_nodes + n) + n_nodes]
                .copy_from_slice(ad.row(r));
            abd.data[r * (n_nodes + n) + n_nodes..(r + 1) * (n_nodes + n)]
                .copy_from_slice(bd.row(r));
        }

        DssModel {
            n_chiplets: n,
            n_nodes,
            ad,
            bd,
            abd,
            ss_factor,
            x: vec![0.0; n_nodes],
            z: vec![0.0; n_nodes + n],
            scratch: vec![0.0; n_nodes],
            t_ambient: arch.t_ambient,
            params,
        }
    }

    pub fn from_arch(arch: &Arch) -> DssModel {
        DssModel::new(arch, ThermalParams::default())
    }

    /// Advance one Δt with the given per-chiplet power vector (W).
    /// x' = A_d·x + B_d·p, computed as one fused pass [A_d|B_d]·[x;p].
    pub fn step(&mut self, powers: &[f64]) {
        assert_eq!(powers.len(), self.n_chiplets);
        self.z[..self.n_nodes].copy_from_slice(&self.x);
        self.z[self.n_nodes..].copy_from_slice(powers);
        self.abd.matvec(&self.z, &mut self.scratch);
        std::mem::swap(&mut self.x, &mut self.scratch);
    }

    /// Die temperature of chiplet `i`, Kelvin (T_i(t) in the ACG).
    #[inline]
    pub fn temp(&self, i: usize) -> f64 {
        self.t_ambient + self.x[i]
    }

    /// Write all die temperatures into `out` (length = chiplet count).
    /// The engine's per-step path uses this to refresh its persistent
    /// temperature buffer without allocating.
    pub fn write_die_temps(&self, out: &mut [f64]) {
        assert_eq!(out.len(), self.n_chiplets);
        for (i, t) in out.iter_mut().enumerate() {
            *t = self.t_ambient + self.x[i];
        }
    }

    /// All die temperatures (allocating convenience; hot paths use
    /// [`DssModel::write_die_temps`]).
    pub fn die_temps(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.n_chiplets];
        self.write_die_temps(&mut out);
        out
    }

    pub fn lid_temp(&self) -> f64 {
        self.t_ambient + self.x[self.n_nodes - 1]
    }

    /// Steady-state die temperatures for a constant power vector
    /// (x_ss = −A⁻¹·B·p solved via the discretized system:
    /// x_ss = (I − A_d)⁻¹ B_d p). Uses the factorization of `I − A_d`
    /// precomputed at construction — each call is two O(n²) substitutions.
    pub fn steady_state(&self, powers: &[f64]) -> Vec<f64> {
        assert_eq!(powers.len(), self.n_chiplets);
        let n = self.n_nodes;
        let mut bp = vec![0.0; n];
        for (r, v) in bp.iter_mut().enumerate() {
            let row = self.bd.row(r);
            *v = powers.iter().enumerate().map(|(j, &p)| row[j] * p).sum();
        }
        let mut xss = vec![0.0; n];
        self.ss_factor.solve_vec(&bp, &mut xss);
        (0..self.n_chiplets).map(|i| self.t_ambient + xss[i]).collect()
    }

    /// Reset all nodes to ambient.
    pub fn reset(&mut self) {
        self.x.iter_mut().for_each(|v| *v = 0.0);
    }

    pub fn num_nodes(&self) -> usize {
        self.n_nodes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Arch;
    use crate::noi::NoiTopology;

    fn small_arch() -> Arch {
        Arch::heterogeneous(NoiTopology::Mesh, [4, 4, 2, 2])
    }

    #[test]
    fn zero_power_stays_ambient() {
        let arch = small_arch();
        let mut m = DssModel::from_arch(&arch);
        let p = vec![0.0; arch.num_chiplets()];
        for _ in 0..100 {
            m.step(&p);
        }
        for i in 0..arch.num_chiplets() {
            assert!((m.temp(i) - 300.0).abs() < 1e-9);
        }
    }

    #[test]
    fn heating_raises_and_converges_to_steady_state() {
        let arch = small_arch();
        let mut m = DssModel::from_arch(&arch);
        let mut p = vec![0.0; arch.num_chiplets()];
        p[0] = 0.5;
        let ss = m.steady_state(&p);
        // Long run converges to the steady state.
        for _ in 0..20_000 {
            m.step(&p);
        }
        assert!((m.temp(0) - ss[0]).abs() < 0.05, "{} vs {}", m.temp(0), ss[0]);
        assert!(ss[0] > 300.5, "hot die should rise: {}", ss[0]);
        // Monotone rise from ambient for the heated die.
        let mut m2 = DssModel::from_arch(&arch);
        let mut last = 300.0;
        for _ in 0..50 {
            m2.step(&p);
            assert!(m2.temp(0) >= last - 1e-9);
            last = m2.temp(0);
        }
    }

    #[test]
    fn neighbour_coupling_spreads_heat() {
        let arch = small_arch();
        let mut m = DssModel::from_arch(&arch);
        let mut p = vec![0.0; arch.num_chiplets()];
        p[0] = 0.5;
        for _ in 0..5000 {
            m.step(&p);
        }
        // Chiplet 1 is adjacent to 0 in the mesh floorplan; it must warm
        // above ambient but stay cooler than the heated die.
        assert!(m.temp(1) > 300.01);
        assert!(m.temp(1) < m.temp(0));
        // Heat decays with distance.
        let far = arch.num_chiplets() - 1;
        assert!(m.temp(far) < m.temp(1));
    }

    #[test]
    fn superposition_of_linear_system() {
        let arch = small_arch();
        let n = arch.num_chiplets();
        let m = DssModel::from_arch(&arch);
        let mut p1 = vec![0.0; n];
        p1[0] = 0.3;
        let mut p2 = vec![0.0; n];
        p2[3] = 0.7;
        let p12: Vec<f64> = p1.iter().zip(&p2).map(|(a, b)| a + b).collect();
        let s1 = m.steady_state(&p1);
        let s2 = m.steady_state(&p2);
        let s12 = m.steady_state(&p12);
        for i in 0..n {
            let lhs = s12[i] - 300.0;
            let rhs = (s1[i] - 300.0) + (s2[i] - 300.0);
            assert!((lhs - rhs).abs() < 1e-8);
        }
    }

    #[test]
    fn steady_state_matches_fresh_factorization() {
        // The precomputed LU path must agree with a from-scratch solve of
        // (I − A_d) x = B_d p for every query.
        let arch = small_arch();
        let m = DssModel::from_arch(&arch);
        let n = m.n_nodes;
        let mut p = vec![0.0; arch.num_chiplets()];
        p[2] = 0.4;
        p[5] = 0.9;
        let got = m.steady_state(&p);
        let i_minus_ad = Mat::eye(n).sub(&m.ad);
        let mut bp = Mat::zeros(n, 1);
        for r in 0..n {
            let row = m.bd.row(r);
            bp[(r, 0)] = p.iter().enumerate().map(|(j, &pw)| row[j] * pw).sum();
        }
        let xss = i_minus_ad.solve(&bp);
        for i in 0..arch.num_chiplets() {
            let want = m.t_ambient + xss[(i, 0)];
            assert!((got[i] - want).abs() < 1e-9, "{} vs {}", got[i], want);
        }
    }

    #[test]
    fn write_die_temps_matches_temp() {
        let arch = small_arch();
        let mut m = DssModel::from_arch(&arch);
        let p = vec![0.3; arch.num_chiplets()];
        for _ in 0..50 {
            m.step(&p);
        }
        let mut buf = vec![0.0; arch.num_chiplets()];
        m.write_die_temps(&mut buf);
        for i in 0..arch.num_chiplets() {
            assert_eq!(buf[i], m.temp(i));
        }
        assert_eq!(buf, m.die_temps());
    }

    #[test]
    fn full_system_load_can_cross_reram_threshold() {
        // The regime the paper studies: sustained full activity must be
        // able to violate the 330 K ReRAM limit (otherwise thermal
        // management would be vacuous), while idle systems must not.
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let m = DssModel::from_arch(&arch);
        let cm = crate::pim::ComputeModel::default();
        let powers: Vec<f64> = arch
            .chiplets
            .iter()
            .map(|c| {
                let spec = &arch.specs[c.pim as usize];
                // Full-rate continuous compute.
                spec.rate_mac_s * spec.energy_per_mac_j + cm.idle_power_w(spec)
            })
            .collect();
        let ss = m.steady_state(&powers);
        let max_t = ss.iter().cloned().fold(f64::MIN, f64::max);
        assert!(max_t > 330.0, "full load should exceed ReRAM limit: {max_t:.1} K");
        assert!(max_t < 420.0, "sanity: not absurdly hot: {max_t:.1} K");
    }

    #[test]
    fn reset_returns_to_ambient() {
        let arch = small_arch();
        let mut m = DssModel::from_arch(&arch);
        let p = vec![0.2; arch.num_chiplets()];
        for _ in 0..100 {
            m.step(&p);
        }
        assert!(m.temp(0) > 300.0);
        m.reset();
        assert_eq!(m.temp(0), 300.0);
    }
}
