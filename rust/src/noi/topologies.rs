//! Generators for the four NoI topologies.
//!
//! All generators produce physical die positions on a uniform-pitch
//! interposer floorplan (pitch = largest die edge + 1 mm spacing) plus an
//! adjacency list. Chiplet ids are assigned row-major, so the contiguous
//! cluster id ranges chosen in [`crate::arch`] become contiguous spatial
//! regions — matching the paper's Fig. 1a four-region layout.

use super::{NoiTopology, Topology};

/// Interposer placement pitch in mm: 3 mm die (largest, shared-ADC 9 mm²)
/// plus 1 mm inter-die spacing.
pub const PITCH_MM: f64 = 4.0;

/// Build a topology over `n` chiplets. For Floret, the paper's cluster
/// split is used when `n` matches the 78-chiplet evaluation system;
/// otherwise the chiplets are split into four equal petals.
pub fn build(kind: NoiTopology, n: usize) -> Topology {
    match kind {
        NoiTopology::Mesh => mesh(n),
        NoiTopology::Kite => kite(n),
        NoiTopology::HexaMesh => hexamesh(n),
        NoiTopology::Floret => {
            let clusters: Vec<usize> = if n == 78 {
                vec![25, 28, 10, 15]
            } else {
                // Four near-equal petals.
                let base = n / 4;
                let mut c = vec![base; 4];
                for item in c.iter_mut().take(n % 4) {
                    *item += 1;
                }
                c.retain(|&x| x > 0);
                c
            };
            floret(&clusters)
        }
    }
}

fn grid_dims(n: usize) -> (usize, usize) {
    let w = (n as f64).sqrt().ceil() as usize;
    let h = n.div_ceil(w);
    (w, h)
}

fn grid_positions(n: usize, stagger: bool) -> Vec<(f64, f64)> {
    let (w, _) = grid_dims(n);
    (0..n)
        .map(|i| {
            let r = i / w;
            let c = i % w;
            let dx = if stagger && r % 2 == 1 { PITCH_MM / 2.0 } else { 0.0 };
            (c as f64 * PITCH_MM + dx, r as f64 * PITCH_MM)
        })
        .collect()
}

fn push_edge(adj: &mut [Vec<usize>], a: usize, b: usize) {
    if !adj[a].contains(&b) {
        adj[a].push(b);
        adj[b].push(a);
    }
}

/// 2D mesh: 4-neighbour grid (the baseline NoI, as in SIAM [31]).
fn mesh(n: usize) -> Topology {
    let (w, _) = grid_dims(n);
    let mut adj = vec![Vec::new(); n];
    for i in 0..n {
        let (r, c) = (i / w, i % w);
        if c + 1 < w && i + 1 < n {
            push_edge(&mut adj, i, i + 1);
        }
        let below = (r + 1) * w + c;
        if below < n {
            push_edge(&mut adj, i, below);
        }
    }
    Topology::from_adjacency(NoiTopology::Mesh, grid_positions(n, false), adj)
}

/// Kite-small [6]: the mesh augmented with short diagonal skip links
/// (both diagonals to the next row), complying with the passive-interposer
/// reach limit by only linking immediately adjacent diagonals.
fn kite(n: usize) -> Topology {
    let (w, _) = grid_dims(n);
    let mut adj = vec![Vec::new(); n];
    for i in 0..n {
        let (r, c) = (i / w, i % w);
        if c + 1 < w && i + 1 < n {
            push_edge(&mut adj, i, i + 1);
        }
        let below = (r + 1) * w + c;
        if below < n {
            push_edge(&mut adj, i, below);
        }
        // Diagonal skips.
        if c + 1 < w {
            let dr = (r + 1) * w + c + 1;
            if dr < n {
                push_edge(&mut adj, i, dr);
            }
        }
        if c > 0 {
            let dl = (r + 1) * w + c - 1;
            if dl < n {
                push_edge(&mut adj, i, dl);
            }
        }
    }
    Topology::from_adjacency(NoiTopology::Kite, grid_positions(n, false), adj)
}

/// HexaMesh [19]: staggered rows where each chiplet links to six
/// neighbours (left, right, and four diagonal row-neighbours).
fn hexamesh(n: usize) -> Topology {
    let (w, _) = grid_dims(n);
    let mut adj = vec![Vec::new(); n];
    let idx = |r: usize, c: usize| r * w + c;
    for i in 0..n {
        let (r, c) = (i / w, i % w);
        if c + 1 < w && i + 1 < n {
            push_edge(&mut adj, i, i + 1);
        }
        // Row below: staggered rows touch (r+1, c) and one horizontal
        // neighbour that depends on the row parity.
        let below_candidates: [(usize, isize); 2] =
            if r % 2 == 0 { [(r + 1, 0), (r + 1, -1)] } else { [(r + 1, 0), (r + 1, 1)] };
        for (rr, dc) in below_candidates {
            let cc = c as isize + dc;
            if cc >= 0 && (cc as usize) < w {
                let j = idx(rr, cc as usize);
                if j < n {
                    push_edge(&mut adj, i, j);
                }
            }
        }
    }
    Topology::from_adjacency(NoiTopology::HexaMesh, grid_positions(n, true), adj)
}

/// Floret [57]: data-flow-aware space-filling-curve (SFC) petals, one per
/// cluster. Each petal is a serpentine chain through its own quadrant so
/// consecutive DNN layers mapped along the chain communicate over one hop.
/// Petal heads sit near the interposer centre and are chained head-to-head
/// (the "flower core") to connect the florets.
fn floret(clusters: &[usize]) -> Topology {
    let n: usize = clusters.iter().sum();
    let mut positions = vec![(0.0, 0.0); n];
    let mut adj = vec![Vec::new(); n];
    // Quadrant unit vectors: petals grow outward from the centre.
    let quadrant = [(1.0, 1.0), (-1.0, 1.0), (-1.0, -1.0), (1.0, -1.0)];
    let mut base = 0usize;
    let mut heads = Vec::new();
    for (q, &size) in clusters.iter().enumerate() {
        let (sx, sy) = quadrant[q % 4];
        // Extra quadrant ring for >4 clusters (not used by the paper system).
        let ring = (q / 4) as f64;
        let w = (size as f64).sqrt().ceil() as usize;
        for k in 0..size {
            let id = base + k;
            // Serpentine within the quadrant sub-grid.
            let r = k / w;
            let c = if r % 2 == 0 { k % w } else { w - 1 - k % w };
            let off = 0.75 + ring * (w as f64 + 1.0);
            positions[id] = (
                sx * (off + c as f64) * PITCH_MM,
                sy * (off + r as f64) * PITCH_MM,
            );
            if k > 0 {
                push_edge(&mut adj, id - 1, id);
            }
        }
        heads.push(base);
        base += size;
    }
    // Flower core: chain the petal heads (id 0 of each cluster sits at the
    // quadrant corner nearest the centre).
    for win in heads.windows(2) {
        push_edge(&mut adj, win[0], win[1]);
    }
    if heads.len() > 2 {
        push_edge(&mut adj, heads[0], *heads.last().unwrap());
    }
    // Cross links between petal mid-points and the core improve bisection
    // slightly, mirroring Floret's overlapping-SFC structure.
    for (q, &size) in clusters.iter().enumerate() {
        if size >= 4 {
            let head = heads[q];
            let mid = head + size / 2;
            push_edge(&mut adj, head, mid);
        }
    }
    Topology::from_adjacency(NoiTopology::Floret, positions, adj)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mesh_grid_neighbor_counts() {
        let t = mesh(9); // 3x3
        let deg: Vec<usize> = t.adj.iter().map(|a| a.len()).collect();
        assert_eq!(deg[4], 4); // centre
        assert_eq!(deg[0], 2); // corner
        assert_eq!(t.num_links, 12);
    }

    #[test]
    fn kite_has_diagonals() {
        let t = kite(9);
        // Centre node: 4 mesh + 4 diagonal = 8 links in a 3x3.
        assert_eq!(t.adj[4].len(), 8);
    }

    #[test]
    fn hexamesh_interior_degree_is_six() {
        let t = hexamesh(49); // 7x7
        // Interior node away from edges.
        let i = 3 * 7 + 3;
        assert_eq!(t.adj[i].len(), 6, "adj: {:?}", t.adj[i]);
    }

    #[test]
    fn floret_chains_within_clusters() {
        let t = build(NoiTopology::Floret, 78);
        // Consecutive ids in the standard cluster (0..25) chained.
        for i in 0..24 {
            assert!(t.adj[i].contains(&(i + 1)), "chain broken at {i}");
        }
        // Petal heads connected (0 and 25).
        assert!(t.adj[0].contains(&25));
    }

    #[test]
    fn floret_works_for_non_paper_sizes() {
        for n in [4, 7, 16, 40] {
            let t = build(NoiTopology::Floret, n);
            assert_eq!(t.n(), n);
        }
    }

    #[test]
    fn mesh_positions_row_major() {
        let t = mesh(9);
        assert_eq!(t.positions[0], (0.0, 0.0));
        assert_eq!(t.positions[1], (PITCH_MM, 0.0));
        assert_eq!(t.positions[3], (0.0, PITCH_MM));
    }
}
