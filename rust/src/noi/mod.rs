//! Network-on-Interposer (NoI) topologies and the UCIe link model.
//!
//! The paper evaluates THERMOS on four interposer networks: Mesh,
//! Kite(-small) [6], Floret [57], and HexaMesh [19]. We generate each as a
//! chiplet-level graph with physical die positions (consumed by the
//! thermal floorplan and the proximity algorithm), precompute all-pairs
//! hop counts, and expose a latency/energy link model with the paper's
//! UCIe parameters (64-bit links, 0.5 pJ/bit/hop — Table 4).

pub mod topologies;

pub use topologies::build;

/// The four NoI architectures of §5.3–5.4.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum NoiTopology {
    Mesh,
    Kite,
    Floret,
    HexaMesh,
}

impl NoiTopology {
    pub fn all() -> [NoiTopology; 4] {
        [NoiTopology::Mesh, NoiTopology::Kite, NoiTopology::Floret, NoiTopology::HexaMesh]
    }
    pub fn name(self) -> &'static str {
        match self {
            NoiTopology::Mesh => "mesh",
            NoiTopology::Kite => "kite",
            NoiTopology::Floret => "floret",
            NoiTopology::HexaMesh => "hexamesh",
        }
    }
    pub fn from_name(s: &str) -> Option<NoiTopology> {
        match s.to_ascii_lowercase().as_str() {
            "mesh" => Some(NoiTopology::Mesh),
            "kite" | "kite-small" => Some(NoiTopology::Kite),
            "floret" => Some(NoiTopology::Floret),
            "hexamesh" | "hexa" => Some(NoiTopology::HexaMesh),
            _ => None,
        }
    }
}

/// UCIe-derived link parameters (Table 4 + [55]).
#[derive(Clone, Debug)]
pub struct LinkModel {
    /// Link width in bits (Table 4: 64).
    pub width_bits: u32,
    /// Link clock (Hz). 2 GHz advanced-package UCIe lane rate.
    pub clock_hz: f64,
    /// Per-hop router+link traversal latency (s).
    pub hop_latency_s: f64,
    /// Energy per bit per hop (Table 4: 0.5 pJ/b).
    pub energy_per_bit_hop_j: f64,
}

impl Default for LinkModel {
    fn default() -> Self {
        LinkModel {
            width_bits: 64,
            clock_hz: 2.0e9,
            hop_latency_s: 4.0e-9,
            energy_per_bit_hop_j: 0.5e-12,
        }
    }
}

impl LinkModel {
    /// Serialized bandwidth of one link, bits/s.
    pub fn bandwidth_bits_s(&self) -> f64 {
        self.width_bits as f64 * self.clock_hz
    }

    /// Time to move `bits` across `hops` hops (store-and-forward head
    /// latency + serialization).
    pub fn transfer_time_s(&self, bits: f64, hops: u32) -> f64 {
        if hops == 0 || bits <= 0.0 {
            return 0.0;
        }
        hops as f64 * self.hop_latency_s + bits / self.bandwidth_bits_s()
    }

    /// NoI energy to move `bits` across `hops` hops.
    pub fn transfer_energy_j(&self, bits: f64, hops: u32) -> f64 {
        bits * hops as f64 * self.energy_per_bit_hop_j
    }
}

/// A generated topology: node positions, adjacency, and all-pairs hops.
#[derive(Clone, Debug)]
pub struct Topology {
    pub kind: NoiTopology,
    /// Die-centre coordinates in mm.
    pub positions: Vec<(f64, f64)>,
    /// Adjacency list (undirected; both directions present).
    pub adj: Vec<Vec<usize>>,
    /// All-pairs hop counts (BFS distances), row-major n×n.
    hops: Vec<u32>,
    pub link: LinkModel,
    /// Total link count (undirected edges).
    pub num_links: usize,
}

impl Topology {
    pub(crate) fn from_adjacency(
        kind: NoiTopology,
        positions: Vec<(f64, f64)>,
        adj: Vec<Vec<usize>>,
    ) -> Topology {
        let n = positions.len();
        assert_eq!(adj.len(), n);
        let num_links = adj.iter().map(|a| a.len()).sum::<usize>() / 2;
        let mut hops = vec![u32::MAX; n * n];
        // BFS from every node — n ≈ 80, trivial.
        let mut queue = std::collections::VecDeque::new();
        for src in 0..n {
            hops[src * n + src] = 0;
            queue.clear();
            queue.push_back(src);
            while let Some(u) = queue.pop_front() {
                let du = hops[src * n + u];
                for &v in &adj[u] {
                    if hops[src * n + v] == u32::MAX {
                        hops[src * n + v] = du + 1;
                        queue.push_back(v);
                    }
                }
            }
        }
        assert!(
            hops.iter().all(|&h| h != u32::MAX),
            "{kind:?} topology is disconnected"
        );
        Topology { kind, positions, adj, hops, link: LinkModel::default(), num_links }
    }

    pub fn n(&self) -> usize {
        self.positions.len()
    }

    #[inline]
    pub fn hops(&self, a: usize, b: usize) -> u32 {
        self.hops[a * self.n() + b]
    }

    /// Mean hop count over all distinct pairs — the headline NoI quality
    /// metric used in the Kite/HexaMesh papers.
    pub fn mean_hops(&self) -> f64 {
        let n = self.n();
        let mut total = 0u64;
        for a in 0..n {
            for b in 0..n {
                if a != b {
                    total += self.hops(a, b) as u64;
                }
            }
        }
        total as f64 / (n * (n - 1)) as f64
    }

    /// Maximum hop count (network diameter).
    pub fn diameter(&self) -> u32 {
        *self.hops.iter().max().unwrap()
    }

    /// Euclidean die-centre distance in mm (UCIe passive-interposer reach
    /// checks; proximity tie-breaking).
    pub fn dist_mm(&self, a: usize, b: usize) -> f64 {
        let (ax, ay) = self.positions[a];
        let (bx, by) = self.positions[b];
        ((ax - bx).powi(2) + (ay - by).powi(2)).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_model_transfer_math() {
        let lm = LinkModel::default();
        // 1 Mb over 3 hops: 3*4ns + 1e6/128e9 s
        let t = lm.transfer_time_s(1.0e6, 3);
        assert!((t - (12.0e-9 + 1.0e6 / 128.0e9)).abs() < 1e-15);
        let e = lm.transfer_energy_j(1.0e6, 3);
        assert!((e - 1.0e6 * 3.0 * 0.5e-12).abs() < 1e-20);
        assert_eq!(lm.transfer_time_s(0.0, 5), 0.0);
        assert_eq!(lm.transfer_time_s(100.0, 0), 0.0);
    }

    #[test]
    fn all_topologies_connected_78() {
        for kind in NoiTopology::all() {
            let t = build(kind, 78);
            assert_eq!(t.n(), 78);
            assert!(t.diameter() < 80, "{kind:?} diameter {}", t.diameter());
            assert!(t.num_links >= 77, "{kind:?} must span");
        }
    }

    #[test]
    fn hexamesh_beats_mesh_on_mean_hops() {
        let mesh = build(NoiTopology::Mesh, 78);
        let hexa = build(NoiTopology::HexaMesh, 78);
        let kite = build(NoiTopology::Kite, 78);
        assert!(
            hexa.mean_hops() < mesh.mean_hops(),
            "hexa {} vs mesh {}",
            hexa.mean_hops(),
            mesh.mean_hops()
        );
        assert!(kite.mean_hops() < mesh.mean_hops());
    }

    #[test]
    fn hops_symmetric_and_triangle() {
        for kind in NoiTopology::all() {
            let t = build(kind, 40);
            for a in 0..t.n() {
                for b in 0..t.n() {
                    assert_eq!(t.hops(a, b), t.hops(b, a));
                    for c in 0..t.n() {
                        assert!(t.hops(a, c) <= t.hops(a, b) + t.hops(b, c));
                    }
                }
            }
        }
    }
}
