//! Reader for `artifacts/abi.json` — the dimension/layout contract the
//! python AOT exporter pins so the rust coordinator and the HLO artifacts
//! can never drift. Every integration test that touches the artifacts
//! asserts these against the rust-side constants.

use crate::util::json::{Json, JsonError};
use std::path::Path;

#[derive(Clone, Debug)]
pub struct Abi {
    pub state_dim: usize,
    pub num_clusters: usize,
    pub ddt_depth: usize,
    pub theta_len: usize,
    pub phi_len: usize,
    pub critic_dims: Vec<usize>,
    pub update_batch: usize,
    pub num_chiplets: usize,
    pub relmas_obs: usize,
    pub relmas_actor_dims: Vec<usize>,
    pub relmas_critic_dims: Vec<usize>,
    pub relmas_theta_len: usize,
    pub relmas_phi_len: usize,
    pub lr: f64,
    pub clip_eps: f64,
    /// Artifact name → file name.
    pub artifacts: Vec<(String, String)>,
}

impl Abi {
    pub fn params_len(&self) -> usize {
        self.theta_len + self.phi_len
    }
    pub fn relmas_params_len(&self) -> usize {
        self.relmas_theta_len + self.relmas_phi_len
    }

    pub fn load(dir: &Path) -> Result<Abi, JsonError> {
        let path = dir.join("abi.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| JsonError(format!("read {}: {e}", path.display())))?;
        let root = Json::parse(&text)?;
        Self::from_json(&root)
    }

    pub fn from_json(root: &Json) -> Result<Abi, JsonError> {
        let abi = root.get("abi");
        let dims = |key: &str| -> Result<Vec<usize>, JsonError> {
            abi.get(key)
                .as_arr()
                .ok_or_else(|| JsonError(format!("missing array `{key}`")))?
                .iter()
                .map(|v| v.as_usize().ok_or_else(|| JsonError(format!("bad dim in `{key}`"))))
                .collect()
        };
        let mut artifacts = Vec::new();
        if let Some(arts) = root.get("artifacts").as_obj() {
            for (name, desc) in arts {
                artifacts.push((name.clone(), desc.req_str("file")?.to_string()));
            }
        }
        Ok(Abi {
            state_dim: abi.req_usize("state_dim")?,
            num_clusters: abi.req_usize("num_clusters")?,
            ddt_depth: abi.req_usize("ddt_depth")?,
            theta_len: abi.req_usize("theta_len")?,
            phi_len: abi.req_usize("phi_len")?,
            critic_dims: dims("critic_dims")?,
            update_batch: abi.req_usize("update_batch")?,
            num_chiplets: abi.req_usize("num_chiplets")?,
            relmas_obs: abi.req_usize("relmas_obs")?,
            relmas_actor_dims: dims("relmas_actor_dims")?,
            relmas_critic_dims: dims("relmas_critic_dims")?,
            relmas_theta_len: abi.req_usize("relmas_theta_len")?,
            relmas_phi_len: abi.req_usize("relmas_phi_len")?,
            lr: abi.req_f64("lr")?,
            clip_eps: abi.req_f64("clip_eps")?,
            artifacts,
        })
    }

    /// Assert the ABI matches the rust-side compile-time constants.
    pub fn validate(&self) -> Result<(), String> {
        use crate::sched::policy::{ddt_theta_len, mlp_param_len};
        use crate::sched::state::{NUM_CLUSTERS, STATE_DIM};
        if self.state_dim != STATE_DIM {
            return Err(format!("state_dim {} != rust {}", self.state_dim, STATE_DIM));
        }
        if self.num_clusters != NUM_CLUSTERS {
            return Err(format!("num_clusters {} != rust {}", self.num_clusters, NUM_CLUSTERS));
        }
        let want_theta = ddt_theta_len(self.state_dim, self.num_clusters);
        if self.theta_len != want_theta {
            return Err(format!("theta_len {} != rust {}", self.theta_len, want_theta));
        }
        let want_phi = mlp_param_len(&self.critic_dims);
        if self.phi_len != want_phi {
            return Err(format!("phi_len {} != rust {}", self.phi_len, want_phi));
        }
        let want_rt = mlp_param_len(&self.relmas_actor_dims);
        if self.relmas_theta_len != want_rt {
            return Err(format!("relmas_theta_len {} != {}", self.relmas_theta_len, want_rt));
        }
        let want_rp = mlp_param_len(&self.relmas_critic_dims);
        if self.relmas_phi_len != want_rp {
            return Err(format!("relmas_phi_len {} != {}", self.relmas_phi_len, want_rp));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "abi": {
        "state_dim": 22, "num_clusters": 4, "ddt_depth": 5,
        "theta_len": 872, "phi_len": 9922,
        "critic_dims": [22, 64, 64, 64, 2], "update_batch": 256,
        "num_chiplets": 78, "relmas_obs": 168,
        "relmas_actor_dims": [168, 128, 128, 78],
        "relmas_critic_dims": [168, 128, 128, 1],
        "relmas_theta_len": 48206, "relmas_phi_len": 38273,
        "lr": 0.0005, "clip_eps": 0.1
      },
      "artifacts": {"ddt_policy": {"file": "ddt_policy.hlo.txt"}}
    }"#;

    #[test]
    fn parses_and_validates() {
        let abi = Abi::from_json(&Json::parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(abi.theta_len, 872);
        assert_eq!(abi.params_len(), 872 + 9922);
        abi.validate().expect("abi should match rust constants");
        assert_eq!(abi.artifacts.len(), 1);
    }

    #[test]
    fn validation_catches_drift() {
        let mut j = Json::parse(SAMPLE).unwrap();
        if let Json::Obj(m) = &mut j {
            if let Some(Json::Obj(abi)) = m.get_mut("abi") {
                abi.insert("theta_len".into(), Json::Num(900.0));
            }
        }
        let abi = Abi::from_json(&j).unwrap();
        assert!(abi.validate().is_err());
    }
}
