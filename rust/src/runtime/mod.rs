//! PJRT runtime: loads the AOT-compiled HLO-text artifacts and executes
//! them on the CPU PJRT client via the `xla` crate.
//!
//! This is the only place the coordinator touches XLA, and the whole
//! XLA-facing surface is gated behind the `pjrt` cargo feature: default
//! builds use the bit-compatible native evaluators
//! ([`crate::sched::policy::NativeDdt`] / `NativeMlp`) and need neither
//! the `xla` crate nor any HLO artifacts. The feature-independent pieces
//! — the artifact ABI ([`abi`]) and the params file format
//! ([`params_io`]) — stay available everywhere.
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute`, with
//! `return_tuple=True` on the python side so every artifact yields one
//! tuple literal we decompose.

pub mod abi;

pub use abi::Abi;

#[cfg(feature = "pjrt")]
use anyhow::{Context, Result};
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::path::{Path, PathBuf};

/// A compiled artifact ready to execute.
#[cfg(feature = "pjrt")]
pub struct Artifact {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
impl Artifact {
    /// Execute with f32 tensor inputs; returns the flattened f32 outputs
    /// (one Vec per tuple element).
    pub fn run_f32(&self, inputs: &[F32Tensor]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("execute {}", self.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetch result of {}", self.name))?;
        let elems = tuple.to_tuple().context("decompose result tuple")?;
        elems
            .into_iter()
            .map(|l| l.to_vec::<f32>().context("read f32 output"))
            .collect()
    }
}

/// Shape-carrying f32 buffer for artifact I/O.
#[derive(Clone, Debug)]
pub struct F32Tensor {
    pub data: Vec<f32>,
    pub dims: Vec<i64>,
}

impl F32Tensor {
    pub fn vec(data: Vec<f32>) -> F32Tensor {
        let dims = vec![data.len() as i64];
        F32Tensor { data, dims }
    }
    pub fn mat(data: Vec<f32>, rows: usize, cols: usize) -> F32Tensor {
        assert_eq!(data.len(), rows * cols);
        F32Tensor { data, dims: vec![rows as i64, cols as i64] }
    }
    pub fn scalar1(v: f32) -> F32Tensor {
        F32Tensor { data: vec![v], dims: vec![1] }
    }
    #[cfg(feature = "pjrt")]
    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        Ok(lit.reshape(&self.dims)?)
    }
}

/// The runtime: a PJRT CPU client plus lazily compiled artifacts.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub abi: Abi,
    cache: HashMap<String, Artifact>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Open `artifacts/` (validating abi.json against the rust constants)
    /// and create the PJRT CPU client.
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let abi = Abi::load(&dir)
            .map_err(|e| anyhow::anyhow!("{e}"))
            .context("load abi.json — run `make artifacts` first")?;
        abi.validate().map_err(|e| anyhow::anyhow!("abi drift: {e}"))?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Runtime { client, dir, abi, cache: HashMap::new() })
    }

    /// Default artifacts directory: $THERMOS_ARTIFACTS or ./artifacts.
    pub fn open_default() -> Result<Runtime> {
        let dir = std::env::var("THERMOS_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::open(dir)
    }

    /// Load + compile an artifact by name (cached).
    pub fn artifact(&mut self, name: &str) -> Result<&Artifact> {
        if !self.cache.contains_key(name) {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compile artifact {name}"))?;
            self.cache.insert(name.to_string(), Artifact { name: name.to_string(), exe });
        }
        Ok(&self.cache[name])
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

/// Save/load flat f32 parameter vectors (trained policies) as little-endian
/// binary with a tiny header. Used by `thermos train` / `thermos sim`.
pub mod params_io {
    use anyhow::{bail, Context, Result};
    use std::io::{Read, Write};
    use std::path::Path;

    const MAGIC: &[u8; 8] = b"THERMOS1";

    pub fn save(path: impl AsRef<Path>, params: &[f32]) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("create {}", path.as_ref().display()))?;
        f.write_all(MAGIC)?;
        f.write_all(&(params.len() as u64).to_le_bytes())?;
        let bytes: Vec<u8> = params.iter().flat_map(|v| v.to_le_bytes()).collect();
        f.write_all(&bytes)?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Vec<f32>> {
        let mut f = std::fs::File::open(path.as_ref())
            .with_context(|| format!("open {}", path.as_ref().display()))?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{}: not a THERMOS params file", path.as_ref().display());
        }
        let mut lenb = [0u8; 8];
        f.read_exact(&mut lenb)?;
        let len = u64::from_le_bytes(lenb) as usize;
        let mut bytes = vec![0u8; len * 4];
        f.read_exact(&mut bytes)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn roundtrip() {
            let dir = std::env::temp_dir().join("thermos_params_test");
            let path = dir.join("p.bin");
            let params: Vec<f32> = (0..1000).map(|i| (i as f32).sin()).collect();
            save(&path, &params).unwrap();
            let back = load(&path).unwrap();
            assert_eq!(params, back);
            std::fs::remove_dir_all(&dir).ok();
        }

        #[test]
        fn rejects_garbage() {
            let dir = std::env::temp_dir().join("thermos_params_test2");
            std::fs::create_dir_all(&dir).unwrap();
            let path = dir.join("bad.bin");
            std::fs::write(&path, b"not a params file").unwrap();
            assert!(load(&path).is_err());
            std::fs::remove_dir_all(&dir).ok();
        }
    }
}

/// A [`crate::sched::policy::PolicyEval`] backed by a PJRT artifact —
/// the canonical runtime integration for the B=1 scheduling hot path.
/// Owns its own `Runtime` to keep lifetimes simple at call sites.
#[cfg(feature = "pjrt")]
pub struct PjrtPolicy {
    runtime: Runtime,
    name: String,
    in_dim: usize,
    out_dim: usize,
    pub theta: Vec<f32>,
}

#[cfg(feature = "pjrt")]
impl PjrtPolicy {
    pub fn new(
        mut runtime: Runtime,
        name: &str,
        in_dim: usize,
        out_dim: usize,
        theta: Vec<f32>,
    ) -> Result<PjrtPolicy> {
        runtime.artifact(name)?; // pre-compile
        Ok(PjrtPolicy { runtime, name: name.to_string(), in_dim, out_dim, theta })
    }

    /// THERMOS DDT policy from the default artifacts + a params file
    /// (theta is the first `theta_len` entries of the flat param vector).
    pub fn thermos_from_params(runtime: Runtime, params: &[f32]) -> Result<PjrtPolicy> {
        let abi = runtime.abi.clone();
        anyhow::ensure!(
            params.len() == abi.params_len() || params.len() == abi.theta_len,
            "params length {} matches neither theta ({}) nor theta+phi ({})",
            params.len(),
            abi.theta_len,
            abi.params_len()
        );
        let theta = params[..abi.theta_len].to_vec();
        Self::new(runtime, "ddt_policy", abi.state_dim, abi.num_clusters, theta)
    }
}

#[cfg(feature = "pjrt")]
impl crate::sched::policy::PolicyEval for PjrtPolicy {
    fn num_actions(&self) -> usize {
        self.out_dim
    }
    fn logits(&mut self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.in_dim);
        let theta = std::mem::take(&mut self.theta);
        let art = self.runtime.artifact(&self.name).expect("artifact vanished");
        let out = art
            .run_f32(&[
                F32Tensor::vec(theta.clone()),
                F32Tensor::mat(x.to_vec(), 1, self.in_dim),
            ])
            .expect("policy artifact execution failed");
        self.theta = theta;
        assert_eq!(out[0].len(), self.out_dim);
        out[0].clone()
    }
}
