//! The four PIM chiplet implementations considered by the paper (§3.2)
//! and their Table 3 + §4.1 parameters, extended with the analytic compute
//! model constants documented in DESIGN.md §5 (our CiMLoop substitute).

use super::KB;

pub const NUM_PIM_TYPES: usize = 4;

/// PIM implementation variant. Order matches the paper's Table 3 and is
/// the cluster index everywhere (action space, state features, abi).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PimType {
    /// ReRAM macros, 1-bit streamed input, one 8-bit ADC per column [10].
    Standard = 0,
    /// SRAM macros with ADCs shared across crossbar columns [22].
    SharedAdc = 1,
    /// ReRAM with analog accumulators that defer ADC conversions [66].
    Accumulator = 2,
    /// Fully digital SRAM near-memory compute, no ADCs [28, 49].
    AdcLess = 3,
}

impl PimType {
    pub fn all() -> [PimType; NUM_PIM_TYPES] {
        [PimType::Standard, PimType::SharedAdc, PimType::Accumulator, PimType::AdcLess]
    }

    pub fn from_index(i: usize) -> PimType {
        match i {
            0 => PimType::Standard,
            1 => PimType::SharedAdc,
            2 => PimType::Accumulator,
            3 => PimType::AdcLess,
            _ => panic!("invalid PIM type index {i}"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PimType::Standard => "standard",
            PimType::SharedAdc => "shared_adc",
            PimType::Accumulator => "accumulator",
            PimType::AdcLess => "adc_less",
        }
    }

    /// ReRAM-based types are thermally fragile (conductance drift); SRAM
    /// tolerates standard 85 °C.
    pub fn is_reram(self) -> bool {
        matches!(self, PimType::Standard | PimType::Accumulator)
    }
}

/// Static per-type chiplet parameters.
///
/// Columns 1–7 come from the paper's Table 3. The last four columns are
/// the analytic compute model (DESIGN.md §5): peak MAC rate, energy/MAC,
/// leakage power, and the §4.1 Eq. 2 thermal limit.
#[derive(Clone, Debug)]
pub struct PimSpec {
    pub pim: PimType,
    pub fabrication: &'static str,
    pub crossbar: usize,
    pub bits_per_cell: u32,
    /// ADC precision in bits; `None` for the ADC-less digital design.
    pub adc_bits: Option<u32>,
    /// Weight-storage capacity per chiplet (bits).
    pub mem_bits: u64,
    pub area_mm2: f64,
    /// Effective peak rate in MAC/s per chiplet (analytic CiMLoop-substitute).
    pub rate_mac_s: f64,
    /// Dynamic energy per MAC (J).
    pub energy_per_mac_j: f64,
    /// Leakage / retention power per chiplet (W). Paid whenever weights
    /// are resident, including while throttled (§4.1).
    pub leakage_w: f64,
    /// Thermal throttling threshold, Kelvin (Eq. 2).
    pub t_max_k: f64,
}

impl PimSpec {
    /// The paper's Table 3 catalogue with DESIGN.md §5 model constants.
    ///
    /// Rate rationale (all at nominal 1 GHz macro clock, INT8 weights on
    /// 2-bit ReRAM cells / 1-bit SRAM cells):
    /// * Standard: 128×128 crossbar, per-column ADCs keep full column
    ///   parallelism → highest rate per area, but every column conversion
    ///   burns ADC energy → highest energy and heat density.
    /// * Shared-ADC: 768×768 macro with column-shared ADCs — conversions
    ///   are serialized across column groups (lower rate per area), and
    ///   energy amortized (lower J/MAC) [22].
    /// * Accumulator: analog accumulation across input cycles defers ADC
    ///   activity → mid rate, markedly lower J/MAC [66]; densest weight
    ///   memory (256×256, 2 b/cell).
    /// * ADC-less: digital bit-serial MACs — lowest J/MAC and leakage, but
    ///   serialized bitwise arithmetic → lowest rate; smallest capacity.
    pub fn table3() -> [PimSpec; NUM_PIM_TYPES] {
        [
            PimSpec {
                pim: PimType::Standard,
                fabrication: "ReRAM",
                crossbar: 128,
                bits_per_cell: 2,
                adc_bits: Some(8),
                mem_bits: 9568 * KB,
                area_mm2: 4.0,
                rate_mac_s: 204.8e9,
                energy_per_mac_j: 1.10e-12,
                leakage_w: 0.035,
                t_max_k: 330.0,
            },
            PimSpec {
                pim: PimType::SharedAdc,
                fabrication: "SRAM",
                crossbar: 768,
                bits_per_cell: 1,
                adc_bits: Some(8),
                mem_bits: 9792 * KB,
                area_mm2: 9.0,
                rate_mac_s: 147.5e9,
                energy_per_mac_j: 0.65e-12,
                leakage_w: 0.110,
                t_max_k: 358.0,
            },
            PimSpec {
                pim: PimType::Accumulator,
                fabrication: "ReRAM",
                crossbar: 256,
                bits_per_cell: 2,
                adc_bits: Some(8),
                mem_bits: 19200 * KB,
                area_mm2: 4.0,
                rate_mac_s: 163.8e9,
                energy_per_mac_j: 0.48e-12,
                leakage_w: 0.040,
                t_max_k: 330.0,
            },
            PimSpec {
                pim: PimType::AdcLess,
                fabrication: "SRAM",
                crossbar: 128,
                bits_per_cell: 1,
                adc_bits: None,
                mem_bits: 2416 * KB,
                area_mm2: 4.0,
                rate_mac_s: 102.4e9,
                energy_per_mac_j: 0.28e-12,
                leakage_w: 0.028,
                t_max_k: 358.0,
            },
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq2_thermal_thresholds() {
        for spec in PimSpec::table3() {
            if spec.pim.is_reram() {
                assert_eq!(spec.t_max_k, 330.0, "{:?}", spec.pim);
            } else {
                assert_eq!(spec.t_max_k, 358.0, "{:?}", spec.pim);
            }
        }
    }

    #[test]
    fn relative_orderings_match_fig1b() {
        let s = PimSpec::table3();
        // Standard is the fastest; ADC-less the slowest but most efficient.
        assert!(s[0].rate_mac_s > s[1].rate_mac_s);
        assert!(s[0].rate_mac_s > s[2].rate_mac_s);
        assert!(s[3].rate_mac_s < s[2].rate_mac_s);
        assert!(s[0].energy_per_mac_j > s[1].energy_per_mac_j);
        assert!(s[1].energy_per_mac_j > s[2].energy_per_mac_j);
        assert!(s[2].energy_per_mac_j > s[3].energy_per_mac_j);
        // Accumulator has the densest weight memory per area.
        let density = |p: &PimSpec| p.mem_bits as f64 / p.area_mm2;
        assert!(density(&s[2]) > density(&s[0]));
        assert!(density(&s[2]) > density(&s[1]));
        assert!(density(&s[2]) > density(&s[3]));
    }

    #[test]
    fn round_trip_index() {
        for t in PimType::all() {
            assert_eq!(PimType::from_index(t as usize), t);
        }
    }
}
