//! Heterogeneous multi-chiplet PIM architecture model (paper §3.2,
//! Definition 2: the Architecture Characterization Graph) and the Table 3
//! chiplet catalogue.
//!
//! The ACG vertices are chiplets `(v_i, M_i^cap, M_i(t), T_i(t), T_i^max)`;
//! the arcs are NoI links (built in [`crate::noi`]). Chiplets are logically
//! grouped into clusters by PIM type; the level-1 MORL policy picks a
//! cluster, the level-2 proximity algorithm picks chiplets inside it.

pub mod pimtype;

pub use pimtype::{PimSpec, PimType, NUM_PIM_TYPES};

use crate::noi::{NoiTopology, Topology};

/// Kilobit → bit helper (Table 3 lists memory per chiplet in Kb).
pub const KB: u64 = 1024;

/// A single chiplet die on the interposer.
#[derive(Clone, Debug)]
pub struct Chiplet {
    pub id: usize,
    pub pim: PimType,
    /// Die centre on the interposer, millimetres (used by the floorplan,
    /// the thermal model, and proximity distances).
    pub pos: (f64, f64),
    /// Die edge length in mm (dies are square: Table 3 areas are 4/9 mm²).
    pub size_mm: f64,
}

/// Full system description: chiplets + clusters + interconnect.
#[derive(Clone, Debug)]
pub struct Arch {
    pub chiplets: Vec<Chiplet>,
    pub specs: [PimSpec; NUM_PIM_TYPES],
    /// Chiplet ids per PIM cluster, indexed by `PimType as usize`.
    pub clusters: [Vec<usize>; NUM_PIM_TYPES],
    pub topology: Topology,
    /// Which NoI generated `topology` (for reports).
    pub noi: NoiTopology,
    /// Ambient temperature (K) — thermal boundary condition.
    pub t_ambient: f64,
}

impl Arch {
    /// Build the paper's evaluation system: 25 Standard + 28 Shared-ADC +
    /// 10 Accumulator + 15 ADC-less chiplets (Table 3) interconnected by
    /// the given NoI.
    pub fn paper_heterogeneous(noi: NoiTopology) -> Arch {
        Self::heterogeneous(noi, [25, 28, 10, 15])
    }

    /// Build a heterogeneous system with the given per-type chiplet counts.
    pub fn heterogeneous(noi: NoiTopology, counts: [usize; NUM_PIM_TYPES]) -> Arch {
        let specs = PimSpec::table3();
        // Chiplet type sequence: clusters are contiguous so the floorplan
        // groups each PIM type into a region (paper Fig. 1a shows four
        // cluster regions).
        let mut types = Vec::new();
        for (ti, &n) in counts.iter().enumerate() {
            types.extend(std::iter::repeat(PimType::from_index(ti)).take(n));
        }
        Self::from_types(noi, &types, specs)
    }

    /// Build a homogeneous system of a single PIM type with a total
    /// processing area equal to the paper's heterogeneous system
    /// (used by the Fig. 1b radar experiment).
    pub fn homogeneous_equal_area(noi: NoiTopology, pim: PimType) -> Arch {
        let specs = PimSpec::table3();
        let hetero_area: f64 = [25.0 * 4.0, 28.0 * 9.0, 10.0 * 4.0, 15.0 * 4.0].iter().sum();
        let n = (hetero_area / specs[pim as usize].area_mm2).round() as usize;
        let types = vec![pim; n];
        Self::from_types(noi, &types, specs)
    }

    fn from_types(noi: NoiTopology, types: &[PimType], specs: [PimSpec; NUM_PIM_TYPES]) -> Arch {
        let n = types.len();
        let topology = crate::noi::build(noi, n);
        let mut chiplets = Vec::with_capacity(n);
        let mut clusters: [Vec<usize>; NUM_PIM_TYPES] = Default::default();
        for (id, &pim) in types.iter().enumerate() {
            let pos = topology.positions[id];
            chiplets.push(Chiplet {
                id,
                pim,
                pos,
                size_mm: specs[pim as usize].area_mm2.sqrt(),
            });
            clusters[pim as usize].push(id);
        }
        Arch { chiplets, specs, clusters, topology, noi, t_ambient: 300.0 }
    }

    pub fn num_chiplets(&self) -> usize {
        self.chiplets.len()
    }

    pub fn spec(&self, id: usize) -> &PimSpec {
        &self.specs[self.chiplets[id].pim as usize]
    }

    /// Total crossbar weight memory of the whole system, in bits.
    pub fn total_memory_bits(&self) -> u64 {
        self.chiplets.iter().map(|c| self.specs[c.pim as usize].mem_bits).sum()
    }

    /// Total crossbar memory of one cluster, in bits.
    pub fn cluster_memory_bits(&self, pim: PimType) -> u64 {
        self.clusters[pim as usize].len() as u64 * self.specs[pim as usize].mem_bits
    }

    /// Total processing area in mm².
    pub fn total_area_mm2(&self) -> f64 {
        self.chiplets.iter().map(|c| self.specs[c.pim as usize].area_mm2).sum()
    }

    /// Hop count between two chiplets over the NoI.
    pub fn hops(&self, a: usize, b: usize) -> u32 {
        self.topology.hops(a, b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_system_has_78_chiplets() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        assert_eq!(arch.num_chiplets(), 78);
        assert_eq!(arch.clusters[PimType::Standard as usize].len(), 25);
        assert_eq!(arch.clusters[PimType::SharedAdc as usize].len(), 28);
        assert_eq!(arch.clusters[PimType::Accumulator as usize].len(), 10);
        assert_eq!(arch.clusters[PimType::AdcLess as usize].len(), 15);
    }

    #[test]
    fn table3_memory_capacities() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        assert_eq!(arch.cluster_memory_bits(PimType::Standard), 25 * 9568 * KB);
        assert_eq!(arch.cluster_memory_bits(PimType::SharedAdc), 28 * 9792 * KB);
        assert_eq!(arch.cluster_memory_bits(PimType::Accumulator), 10 * 19200 * KB);
        assert_eq!(arch.cluster_memory_bits(PimType::AdcLess), 15 * 2416 * KB);
    }

    #[test]
    fn clusters_are_contiguous_and_partition() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let mut seen = vec![false; arch.num_chiplets()];
        for cl in &arch.clusters {
            for &id in cl {
                assert!(!seen[id]);
                seen[id] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn homogeneous_equal_area_matches_area() {
        let hetero = Arch::paper_heterogeneous(NoiTopology::Mesh);
        for t in PimType::all() {
            let homo = Arch::homogeneous_equal_area(NoiTopology::Mesh, t);
            let ratio = homo.total_area_mm2() / hetero.total_area_mm2();
            assert!((0.9..1.1).contains(&ratio), "area ratio {ratio} for {t:?}");
            assert!(homo.clusters[t as usize].len() == homo.num_chiplets());
        }
    }

    #[test]
    fn positions_are_distinct() {
        for noi in NoiTopology::all() {
            let arch = Arch::paper_heterogeneous(noi);
            for i in 0..arch.num_chiplets() {
                for j in (i + 1)..arch.num_chiplets() {
                    let (a, b) = (arch.chiplets[i].pos, arch.chiplets[j].pos);
                    assert!(
                        (a.0 - b.0).abs() > 1e-9 || (a.1 - b.1).abs() > 1e-9,
                        "{noi:?}: chiplets {i} and {j} overlap"
                    );
                }
            }
        }
    }
}
