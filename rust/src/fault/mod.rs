//! Deterministic fault injection for the cluster serving path.
//!
//! A [`FaultPlan`] is a seeded *schedule* of faults — chiplet thermal
//! trips, shard crashes/hangs, mailbox drops/delays, arbiter-report loss —
//! either parsed from a JSON file (`serve --faults plan.json`) or generated
//! from a chaos seed (`serve --chaos N`). Faults are keyed by (epoch,
//! shard) and the chaos generator draws each epoch's faults from an RNG
//! seeded by `(seed, epoch)` alone, so the same seed always produces the
//! same fault sequence regardless of thread interleaving. Injection itself
//! happens only at epoch barriers inside the cluster supervisor
//! (`cluster::run_cluster`), which keeps the merged telemetry digest
//! byte-identical across same-seed runs.
//!
//! Nothing here touches threads or clocks: this module is pure data —
//! the plan, the degradation counters ([`FaultStats`]), the supervisor →
//! shard command verbs ([`ShardCmd`]), and the cluster error type
//! ([`ClusterError`]) that replaces panics on the serving hot path.
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

use crate::util::json::Json;
use crate::util::rng::Rng;

/// How many consecutive hung epochs the supervisor tolerates before it
/// escalates a hang to a crash + restart.
pub const SUPERVISOR_PATIENCE_EPOCHS: usize = 2;

/// One injectable fault. Durations are in epochs (the cluster barrier
/// period), not seconds — faults land exactly on barrier boundaries.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Force a chiplet offline in the shard's engine for `epochs` epochs:
    /// its capacity is masked out of scheduling and jobs mapped onto it
    /// stall (thermal-trip semantics).
    ChipletTrip { chiplet: usize, epochs: usize },
    /// Kill the shard's engine + scheduler. The supervisor marks it
    /// drained in the ring, fails its in-flight work over, and restarts it
    /// from a checkpoint after `down_epochs` epochs.
    ShardCrash { down_epochs: usize },
    /// The shard stops making progress for `epochs` epochs but keeps its
    /// state; hangs longer than [`SUPERVISOR_PATIENCE_EPOCHS`] are
    /// escalated to a crash.
    ShardHang { epochs: usize },
    /// This epoch's request batch to the shard is lost in transit.
    MailboxDrop,
    /// This epoch's request batch arrives `epochs` epochs late.
    MailboxDelay { epochs: usize },
    /// The shard's epoch report never reaches the arbiter; the supervisor
    /// substitutes the last known reading on the power/telemetry plane.
    ReportLoss,
}

impl FaultKind {
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::ChipletTrip { .. } => "chiplet_trip",
            FaultKind::ShardCrash { .. } => "shard_crash",
            FaultKind::ShardHang { .. } => "shard_hang",
            FaultKind::MailboxDrop => "mailbox_drop",
            FaultKind::MailboxDelay { .. } => "mailbox_delay",
            FaultKind::ReportLoss => "report_loss",
        }
    }
}

/// A fault scheduled against one shard at one epoch barrier.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    pub epoch: usize,
    pub shard: usize,
    pub kind: FaultKind,
}

/// A deterministic schedule of faults, sorted by (epoch, shard).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    pub fn new(mut events: Vec<FaultEvent>) -> Self {
        events.sort_by_key(|e| (e.epoch, e.shard));
        FaultPlan { events }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Parse the JSON plan schema:
    ///
    /// ```json
    /// {"faults": [
    ///   {"kind": "shard_crash",   "shard": 1, "epoch": 5, "down_epochs": 3},
    ///   {"kind": "shard_hang",    "shard": 0, "epoch": 2, "epochs": 2},
    ///   {"kind": "chiplet_trip",  "shard": 2, "epoch": 4, "chiplet": 12, "epochs": 6},
    ///   {"kind": "mailbox_drop",  "shard": 1, "epoch": 7},
    ///   {"kind": "mailbox_delay", "shard": 0, "epoch": 9, "epochs": 1},
    ///   {"kind": "report_loss",   "shard": 3, "epoch": 11}
    /// ]}
    /// ```
    ///
    /// `down_epochs` defaults to 2 and `epochs` to 1 when omitted;
    /// `chiplet` is required for `chiplet_trip` (taken modulo the shard's
    /// chiplet count at injection time).
    pub fn from_json(text: &str) -> Result<FaultPlan, ClusterError> {
        let bad = |msg: String| ClusterError::BadFaultPlan(msg);
        let root = Json::parse(text).map_err(|e| bad(format!("unparseable plan: {e}")))?;
        let list = root
            .get("faults")
            .as_arr()
            .ok_or_else(|| bad("plan must be an object with a `faults` array".into()))?;
        let mut events = Vec::with_capacity(list.len());
        for (i, ev) in list.iter().enumerate() {
            let kind_name = ev
                .get("kind")
                .as_str()
                .ok_or_else(|| bad(format!("fault #{i}: missing `kind`")))?;
            let shard = ev
                .get("shard")
                .as_usize()
                .ok_or_else(|| bad(format!("fault #{i}: missing `shard`")))?;
            let epoch = ev
                .get("epoch")
                .as_usize()
                .ok_or_else(|| bad(format!("fault #{i}: missing `epoch`")))?;
            let epochs = ev.get("epochs").as_usize().unwrap_or(1).max(1);
            let kind = match kind_name {
                "chiplet_trip" => FaultKind::ChipletTrip {
                    chiplet: ev
                        .get("chiplet")
                        .as_usize()
                        .ok_or_else(|| bad(format!("fault #{i}: chiplet_trip needs `chiplet`")))?,
                    epochs,
                },
                "shard_crash" => FaultKind::ShardCrash {
                    down_epochs: ev.get("down_epochs").as_usize().unwrap_or(2).max(1),
                },
                "shard_hang" => FaultKind::ShardHang { epochs },
                "mailbox_drop" => FaultKind::MailboxDrop,
                "mailbox_delay" => FaultKind::MailboxDelay { epochs },
                "report_loss" => FaultKind::ReportLoss,
                other => return Err(bad(format!("fault #{i}: unknown kind `{other}`"))),
            };
            events.push(FaultEvent { epoch, shard, kind });
        }
        Ok(FaultPlan::new(events))
    }

    /// Serialize back to the `from_json` schema (round-trips exactly).
    pub fn to_json(&self) -> Json {
        let faults = self
            .events
            .iter()
            .map(|e| {
                let mut pairs = vec![
                    ("kind", Json::Str(e.kind.name().to_string())),
                    ("shard", Json::Num(e.shard as f64)),
                    ("epoch", Json::Num(e.epoch as f64)),
                ];
                match &e.kind {
                    FaultKind::ChipletTrip { chiplet, epochs } => {
                        pairs.push(("chiplet", Json::Num(*chiplet as f64)));
                        pairs.push(("epochs", Json::Num(*epochs as f64)));
                    }
                    FaultKind::ShardCrash { down_epochs } => {
                        pairs.push(("down_epochs", Json::Num(*down_epochs as f64)));
                    }
                    FaultKind::ShardHang { epochs } | FaultKind::MailboxDelay { epochs } => {
                        pairs.push(("epochs", Json::Num(*epochs as f64)));
                    }
                    FaultKind::MailboxDrop | FaultKind::ReportLoss => {}
                }
                Json::obj(pairs)
            })
            .collect();
        Json::obj(vec![("faults", Json::Arr(faults))])
    }

    /// Generate a chaos schedule. Deterministic per `(seed, epoch)`: every
    /// epoch's faults are drawn from `Rng::new(seed ^ epoch * GOLDEN)`,
    /// independent of all other epochs, so extending the run does not
    /// reshuffle earlier faults. For runs long enough to recover
    /// (`epochs >= 4`, `shards >= 2`) one early shard crash is guaranteed,
    /// which in turn guarantees `faults_injected > 0` and `failovers > 0`
    /// in the merged report.
    pub fn chaos(seed: u64, shards: usize, epochs: usize) -> FaultPlan {
        let mut events = Vec::new();
        if shards >= 2 && epochs >= 4 {
            let mut r = Rng::new(seed ^ 0xc4a5);
            let epoch = 2 + r.below((epochs / 3).max(1));
            let shard = r.below(shards);
            let max_down = epochs.saturating_sub(epoch + 1).clamp(1, 3);
            let down_epochs = 1 + r.below(max_down);
            events.push(FaultEvent { epoch, shard, kind: FaultKind::ShardCrash { down_epochs } });
        }
        for epoch in 0..epochs {
            let mut r = Rng::new(seed ^ (epoch as u64).wrapping_mul(0x9e3779b97f4a7c15));
            let u = r.f64();
            if shards == 0 || u >= 0.24 {
                continue;
            }
            let shard = r.below(shards);
            let kind = if u < 0.03 {
                FaultKind::ShardCrash { down_epochs: 1 + r.below(3) }
            } else if u < 0.06 {
                FaultKind::ShardHang { epochs: 1 + r.below(4) }
            } else if u < 0.12 {
                FaultKind::ChipletTrip { chiplet: r.below(4096), epochs: 1 + r.below(6) }
            } else if u < 0.16 {
                FaultKind::MailboxDrop
            } else if u < 0.20 {
                FaultKind::MailboxDelay { epochs: 1 }
            } else {
                FaultKind::ReportLoss
            };
            events.push(FaultEvent { epoch, shard, kind });
        }
        FaultPlan::new(events)
    }
}

/// Degradation counters accumulated by the supervisor; merged into the
/// cluster report (and therefore the digest) whenever a plan is active.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Fault events actually applied (scheduled events that were skipped —
    /// e.g. a crash that would empty the ring — are not counted).
    pub faults_injected: u64,
    /// Shard-failover events: one per crash/escalation that moved a
    /// shard's in-flight and future work off the dead shard.
    pub failovers: u64,
    /// In-flight requests re-routed to a surviving shard (same global id:
    /// at-most-once accounting, no duplicate completions).
    pub retries: u64,
    /// Shard restarts from checkpoint.
    pub restarts: u64,
    /// Sum over epochs of shards not alive at the barrier.
    pub downtime_epochs: u64,
    /// Requests lost for good (mailbox drop, or no surviving shard).
    pub dropped_requests: u64,
    /// Epoch reports lost before reaching the arbiter.
    pub reports_lost: u64,
    /// Chiplet thermal-trip injections.
    pub chiplet_trips: u64,
    /// Crashes absorbed by a warm standby: a prebuilt spare engine
    /// adopted the dead shard's ring position at the barrier, so the
    /// shard never left the ring and `downtime_epochs` did not grow.
    pub standby_promotions: u64,
}

impl FaultStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("faults_injected", Json::Num(self.faults_injected as f64)),
            ("failovers", Json::Num(self.failovers as f64)),
            ("retries", Json::Num(self.retries as f64)),
            ("restarts", Json::Num(self.restarts as f64)),
            ("downtime_epochs", Json::Num(self.downtime_epochs as f64)),
            ("dropped_requests", Json::Num(self.dropped_requests as f64)),
            ("reports_lost", Json::Num(self.reports_lost as f64)),
            ("chiplet_trips", Json::Num(self.chiplet_trips as f64)),
            ("standby_promotions", Json::Num(self.standby_promotions as f64)),
        ])
    }
}

/// Supervisor → shard-worker directive carried in each epoch packet. The
/// worker thread is the "node agent": it never dies, only its engine +
/// scheduler do, so the epoch barrier always collects exactly one report
/// per shard and stays deadlock-free under faults.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardCmd {
    /// Process this epoch normally.
    Run,
    /// Drop the engine + scheduler now; reply with a dead-shard marker.
    Crash,
    /// Stay dead this epoch; reply with a dead-shard marker.
    Down,
    /// Rebuild engine + scheduler from the factory, fast-forward the clock
    /// to cluster time, then process this epoch normally.
    Restart,
    /// Buffer this epoch's batch without making progress (hung).
    Hang,
    /// Idle as a warm standby: keep (or lazily rebuild) a prebuilt
    /// engine, ready to adopt a crashed shard at a later barrier. Only
    /// ever sent to physical spare slots, never to logical shards.
    Standby,
    /// Adopt a dead shard: the prebuilt standby engine takes over the
    /// shard's ring position — fast-forward the clock to cluster time,
    /// then process this epoch normally (no cold rebuild).
    Adopt,
}

/// Error type for the cluster serving path — replaces the panics that a
/// poisoned lock, an empty ring, or a failed worker used to cause.
#[derive(Clone, Debug)]
pub enum ClusterError {
    /// The autoscaler or failover logic would leave zero active shards.
    NoActiveShards,
    /// A `--faults` plan failed to parse or validate.
    BadFaultPlan(String),
    /// Replay/record file I/O failed.
    Io(String),
    /// A shard worker disappeared without delivering its final result.
    ShardFailed(String),
}

impl std::fmt::Display for ClusterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClusterError::NoActiveShards => {
                write!(f, "cluster would have zero active shards")
            }
            ClusterError::BadFaultPlan(msg) => write!(f, "bad fault plan: {msg}"),
            ClusterError::Io(msg) => write!(f, "cluster i/o error: {msg}"),
            ClusterError::ShardFailed(msg) => write!(f, "shard failed: {msg}"),
        }
    }
}

impl std::error::Error for ClusterError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_json_round_trips() {
        let src = r#"{"faults": [
            {"kind": "shard_crash",   "shard": 1, "epoch": 5, "down_epochs": 3},
            {"kind": "shard_hang",    "shard": 0, "epoch": 2, "epochs": 2},
            {"kind": "chiplet_trip",  "shard": 2, "epoch": 4, "chiplet": 12, "epochs": 6},
            {"kind": "mailbox_drop",  "shard": 1, "epoch": 7},
            {"kind": "mailbox_delay", "shard": 0, "epoch": 9, "epochs": 1},
            {"kind": "report_loss",   "shard": 3, "epoch": 11}
        ]}"#;
        let plan = FaultPlan::from_json(src).unwrap();
        assert_eq!(plan.events.len(), 6);
        // Sorted by (epoch, shard).
        assert!(plan.events.windows(2).all(|w| (w[0].epoch, w[0].shard) <= (w[1].epoch, w[1].shard)));
        let back = FaultPlan::from_json(&plan.to_json().to_string_compact()).unwrap();
        assert_eq!(plan, back);
    }

    #[test]
    fn plan_defaults_apply() {
        let plan = FaultPlan::from_json(
            r#"{"faults": [{"kind": "shard_crash", "shard": 0, "epoch": 1},
                           {"kind": "shard_hang", "shard": 1, "epoch": 2}]}"#,
        )
        .unwrap();
        assert_eq!(plan.events[0].kind, FaultKind::ShardCrash { down_epochs: 2 });
        assert_eq!(plan.events[1].kind, FaultKind::ShardHang { epochs: 1 });
    }

    #[test]
    fn bad_plans_are_rejected() {
        assert!(FaultPlan::from_json("not json").is_err());
        assert!(FaultPlan::from_json(r#"{"no_faults": []}"#).is_err());
        assert!(FaultPlan::from_json(r#"{"faults": [{"kind": "meteor", "shard": 0, "epoch": 0}]}"#)
            .is_err());
        assert!(FaultPlan::from_json(r#"{"faults": [{"kind": "shard_crash", "epoch": 0}]}"#)
            .is_err());
        assert!(FaultPlan::from_json(
            r#"{"faults": [{"kind": "chiplet_trip", "shard": 0, "epoch": 0}]}"#
        )
        .is_err());
    }

    #[test]
    fn chaos_is_deterministic_per_seed() {
        let a = FaultPlan::chaos(7, 4, 30);
        let b = FaultPlan::chaos(7, 4, 30);
        assert_eq!(a, b);
        let c = FaultPlan::chaos(8, 4, 30);
        assert_ne!(a, c, "different chaos seeds should give different plans");
    }

    #[test]
    fn chaos_prefix_is_stable_when_run_extends() {
        // Per-(seed, epoch) draws: the first 30 epochs of a 60-epoch plan
        // match the 30-epoch plan (minus the guaranteed crash whose window
        // scales with the horizon).
        let short = FaultPlan::chaos(11, 4, 30);
        let long = FaultPlan::chaos(11, 4, 60);
        // Compare only non-crash events: the guaranteed crash is drawn from
        // a window that scales with the horizon, everything else is a pure
        // per-epoch draw.
        let non_crash = |p: &FaultPlan, cutoff: usize| -> Vec<FaultEvent> {
            p.events
                .iter()
                .filter(|e| e.epoch < cutoff && !matches!(e.kind, FaultKind::ShardCrash { .. }))
                .cloned()
                .collect()
        };
        assert_eq!(non_crash(&short, 30), non_crash(&long, 30));
    }

    #[test]
    fn chaos_guarantees_an_early_crash() {
        for seed in 0..20u64 {
            let plan = FaultPlan::chaos(seed, 4, 20);
            assert!(
                plan.events
                    .iter()
                    .any(|e| matches!(e.kind, FaultKind::ShardCrash { .. }) && e.epoch >= 2),
                "seed {seed}: no crash scheduled"
            );
        }
        // Degenerate shapes stay quiet rather than panicking.
        assert!(FaultPlan::chaos(3, 0, 10).is_empty());
        let single = FaultPlan::chaos(3, 1, 3);
        assert!(!single.events.iter().any(|e| e.shard > 0));
    }
}
