//! Serving telemetry: counters, gauges, and streaming histograms.
//!
//! Everything here is deterministic given the same event stream — reports
//! are built from fixed-order arrays (never from hash-map iteration), and
//! the final JSON is digested with FNV-1a so two identical runs can be
//! compared byte-for-byte.

use super::TenantClass;
use crate::sim::JobStats;
use crate::util::json::Json;
use std::collections::HashMap;

/// Streaming log-bucketed histogram for positive values (latency seconds,
/// energy joules). Constant memory, O(1) insert, ~7.5% quantile
/// resolution over 8 decades — plenty for p50/p95/p99 reporting.
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    min: f64,
    max: f64,
}

/// Lowest bucket edge; values below land in the underflow bucket.
const LO: f64 = 1e-4;
/// Highest bucket edge; values above land in the overflow bucket.
const HI: f64 = 1e4;
/// Log-spaced buckets between LO and HI.
const NB: usize = 256;

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            // [underflow, NB log buckets, overflow]
            counts: vec![0; NB + 2],
            total: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn bucket(x: f64) -> usize {
        if x.is_nan() || x < LO {
            return 0; // underflow (NaN lands here defensively)
        }
        if x >= HI {
            return NB + 1;
        }
        let step = (HI / LO).ln() / NB as f64;
        let i = ((x / LO).ln() / step).floor() as usize;
        (i + 1).min(NB)
    }

    /// Upper edge of bucket `i` (1-based log buckets).
    fn upper_edge(i: usize) -> f64 {
        let step = (HI / LO).ln() / NB as f64;
        LO * (step * i as f64).exp()
    }

    pub fn record(&mut self, x: f64) {
        self.counts[Self::bucket(x)] += 1;
        self.total += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    /// Approximate quantile (q in [0, 1]): the upper edge of the bucket
    /// containing the target rank, clamped to the observed min/max.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                let edge = if i == 0 {
                    LO
                } else if i == NB + 1 {
                    self.max
                } else {
                    Self::upper_edge(i)
                };
                return edge.clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Fold another histogram into this one. Bucket counts add
    /// elementwise (both sides share the fixed LO/HI/NB layout), so a
    /// merge of per-shard histograms yields exactly the bucket contents
    /// of a single-stream histogram over the union of the samples —
    /// quantiles agree exactly, the mean up to float summation order.
    pub fn merge(&mut self, other: &Histogram) {
        for (c, &o) in self.counts.iter_mut().zip(other.counts.iter()) {
            *c += o;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.total as f64)),
            ("mean", Json::Num(self.mean())),
            ("min", Json::Num(if self.total == 0 { 0.0 } else { self.min })),
            ("max", Json::Num(if self.total == 0 { 0.0 } else { self.max })),
            ("p50", Json::Num(self.quantile(0.50))),
            ("p95", Json::Num(self.quantile(0.95))),
            ("p99", Json::Num(self.quantile(0.99))),
        ])
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// Per-tenant serving counters and distributions.
#[derive(Clone, Debug, Default)]
pub struct TenantStats {
    /// Requests the source offered for this tenant.
    pub offered: u64,
    /// Admitted into the tenant queue.
    pub admitted: u64,
    /// Rejected at admission (tenant queue full — backpressure).
    pub rejected: u64,
    /// Admitted but dropped before dispatch (waited past the deadline).
    pub shed: u64,
    /// Dropped by SLO-ordered load shedding under thermal/power pressure
    /// (energy class first, then balanced, then exec).
    pub shed_pressure: u64,
    pub completed: u64,
    pub images_done: u64,
    pub e2e_s: Histogram,
    pub exec_s: Histogram,
    pub energy_j: Histogram,
}

impl TenantStats {
    /// Fold another tenant's stats into this one (cross-shard merge).
    pub fn merge(&mut self, other: &TenantStats) {
        self.offered += other.offered;
        self.admitted += other.admitted;
        self.rejected += other.rejected;
        self.shed += other.shed;
        self.shed_pressure += other.shed_pressure;
        self.completed += other.completed;
        self.images_done += other.images_done;
        self.e2e_s.merge(&other.e2e_s);
        self.exec_s.merge(&other.exec_s);
        self.energy_j.merge(&other.energy_j);
    }
}

/// The telemetry hub: one per server run. Shared with the engine's
/// completion callback via `Arc<Mutex<…>>` so shard workers can report
/// from their own threads; the cluster merges per-shard hubs at the end
/// of the run in shard-id order.
#[derive(Clone, Debug, Default)]
pub struct TelemetryHub {
    pub tenants: [TenantStats; TenantClass::COUNT],
    pub e2e_all: Histogram,
    pub exec_all: Histogram,
    pub energy_all: Histogram,
    /// Peak service-side queue depth (sum over tenant queues).
    pub queue_depth_max: usize,
    /// Peak engine FIFO depth.
    pub fifo_depth_max: usize,
    /// Lookup only — never iterated, so determinism is preserved.
    tenant_of: HashMap<u64, usize>,
}

impl TelemetryHub {
    pub fn new() -> TelemetryHub {
        TelemetryHub::default()
    }

    pub fn on_offered(&mut self, tenant: TenantClass) {
        self.tenants[tenant.index()].offered += 1;
    }

    pub fn on_admit(&mut self, tenant: TenantClass, job_id: u64) {
        self.tenants[tenant.index()].admitted += 1;
        self.tenant_of.insert(job_id, tenant.index());
    }

    pub fn on_reject(&mut self, tenant: TenantClass) {
        self.tenants[tenant.index()].rejected += 1;
    }

    pub fn on_shed(&mut self, tenant: TenantClass, job_id: u64) {
        self.tenants[tenant.index()].shed += 1;
        self.tenant_of.remove(&job_id);
    }

    pub fn on_shed_pressure(&mut self, tenant: TenantClass, job_id: u64) {
        self.tenants[tenant.index()].shed_pressure += 1;
        self.tenant_of.remove(&job_id);
    }

    pub fn on_completed(&mut self, stats: &JobStats) {
        self.e2e_all.record(stats.e2e_s);
        self.exec_all.record(stats.exec_s);
        self.energy_all.record(stats.energy_j);
        if let Some(ti) = self.tenant_of.remove(&stats.id) {
            let t = &mut self.tenants[ti];
            t.completed += 1;
            t.images_done += stats.images;
            t.e2e_s.record(stats.e2e_s);
            t.exec_s.record(stats.exec_s);
            t.energy_j.record(stats.energy_j);
        }
    }

    pub fn sample_depths(&mut self, service_depth: usize, fifo_depth: usize) {
        self.queue_depth_max = self.queue_depth_max.max(service_depth);
        self.fifo_depth_max = self.fifo_depth_max.max(fifo_depth);
    }

    pub fn totals(&self) -> (u64, u64, u64, u64, u64) {
        let mut o = 0;
        let mut a = 0;
        let mut r = 0;
        let mut s = 0;
        let mut c = 0;
        for t in &self.tenants {
            o += t.offered;
            a += t.admitted;
            r += t.rejected;
            s += t.shed;
            c += t.completed;
        }
        (o, a, r, s, c)
    }

    pub fn shed_pressure_total(&self) -> u64 {
        self.tenants.iter().map(|t| t.shed_pressure).sum()
    }

    pub fn images_done_total(&self) -> u64 {
        self.tenants.iter().map(|t| t.images_done).sum()
    }

    /// Fold another hub into this one. Tenant arrays are fixed-order, so
    /// merging per-shard hubs in shard-id order is deterministic; the
    /// `tenant_of` lookup map is runtime state and is not merged.
    pub fn merge(&mut self, other: &TelemetryHub) {
        for (t, o) in self.tenants.iter_mut().zip(other.tenants.iter()) {
            t.merge(o);
        }
        self.e2e_all.merge(&other.e2e_all);
        self.exec_all.merge(&other.exec_all);
        self.energy_all.merge(&other.energy_all);
        self.queue_depth_max = self.queue_depth_max.max(other.queue_depth_max);
        self.fifo_depth_max = self.fifo_depth_max.max(other.fifo_depth_max);
    }

    /// Per-tenant JSON, in fixed `TenantClass::ALL` order.
    pub fn tenants_json(&self) -> Json {
        Json::obj(
            TenantClass::ALL
                .iter()
                .map(|&tc| {
                    let t = &self.tenants[tc.index()];
                    (
                        tc.name(),
                        Json::obj(vec![
                            ("offered", Json::Num(t.offered as f64)),
                            ("admitted", Json::Num(t.admitted as f64)),
                            ("rejected", Json::Num(t.rejected as f64)),
                            ("shed", Json::Num(t.shed as f64)),
                            ("shed_pressure", Json::Num(t.shed_pressure as f64)),
                            ("completed", Json::Num(t.completed as f64)),
                            ("images_done", Json::Num(t.images_done as f64)),
                            ("latency_e2e_s", t.e2e_s.to_json()),
                            ("latency_exec_s", t.exec_s.to_json()),
                            ("energy_j", t.energy_j.to_json()),
                        ]),
                    )
                })
                .collect(),
        )
    }
}

/// FNV-1a 64-bit digest of a string, rendered as 16 hex chars. Used to
/// compare two runs' final telemetry byte-for-byte.
pub fn digest64(s: &str) -> String {
    format!("{:016x}", crate::util::stats::fnv1a64(s.as_bytes()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_bracket_the_data() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64 / 1000.0); // 1 ms … 1 s uniform
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p95 = h.quantile(0.95);
        let p99 = h.quantile(0.99);
        assert!((0.45..0.60).contains(&p50), "p50 {p50}");
        assert!((0.88..1.05).contains(&p95), "p95 {p95}");
        assert!((0.93..1.05).contains(&p99), "p99 {p99}");
        assert!(p50 <= p95 && p95 <= p99);
        assert!((h.mean() - 0.5005).abs() < 1e-9);
    }

    #[test]
    fn histogram_handles_extremes_and_empty() {
        let mut h = Histogram::new();
        assert_eq!(h.quantile(0.5), 0.0);
        h.record(1e-9); // underflow
        h.record(1e9); // overflow
        assert_eq!(h.count(), 2);
        assert!(h.quantile(0.0) <= h.quantile(1.0));
        assert_eq!(h.quantile(1.0), 1e9);
    }

    #[test]
    fn hub_attributes_completions_to_tenants() {
        let mut hub = TelemetryHub::new();
        hub.on_offered(TenantClass::Exec);
        hub.on_admit(TenantClass::Exec, 1);
        hub.on_offered(TenantClass::Energy);
        hub.on_admit(TenantClass::Energy, 2);
        hub.on_offered(TenantClass::Balanced);
        hub.on_reject(TenantClass::Balanced);
        let stats = JobStats {
            id: 1,
            model: crate::workload::DnnModel::ResNet18,
            images: 100,
            arrival_s: 0.0,
            mapped_s: 0.1,
            completed_s: 0.6,
            exec_s: 0.5,
            e2e_s: 0.6,
            energy_j: 2.0,
            ideal_exec_s: 0.5,
            ideal_energy_j: 1.9,
            stall_s: 0.0,
            stall_leak_j: 0.0,
        };
        hub.on_completed(&stats);
        assert_eq!(hub.tenants[0].completed, 1);
        assert_eq!(hub.tenants[2].completed, 0);
        assert_eq!(hub.tenants[1].rejected, 1);
        let (offered, admitted, rejected, shed, completed) = hub.totals();
        assert_eq!((offered, admitted, rejected, shed, completed), (3, 2, 1, 0, 1));
        assert_eq!(hub.e2e_all.count(), 1);
    }

    #[test]
    fn merged_histograms_match_single_stream() {
        // Deterministic pseudo-samples spanning several decades.
        let samples: Vec<f64> = (0..4000u64)
            .map(|i| ((i.wrapping_mul(2_654_435_761) % 100_000) + 1) as f64 / 1000.0)
            .collect();
        let mut single = Histogram::new();
        let mut shards = [
            Histogram::new(),
            Histogram::new(),
            Histogram::new(),
            Histogram::new(),
        ];
        for (i, &x) in samples.iter().enumerate() {
            single.record(x);
            shards[i % 4].record(x);
        }
        let mut merged = Histogram::new();
        for s in &shards {
            merged.merge(s);
        }
        // Bucket counts are identical, so quantiles agree exactly.
        assert_eq!(merged.count(), single.count());
        for q in [0.01, 0.25, 0.50, 0.90, 0.95, 0.99, 1.0] {
            assert_eq!(merged.quantile(q), single.quantile(q), "q={q}");
        }
        assert_eq!(merged.min, single.min);
        assert_eq!(merged.max, single.max);
        // Mean agrees up to float summation order.
        let rel = (merged.mean() - single.mean()).abs() / single.mean();
        assert!(rel < 1e-9, "mean rel err {rel}");
    }

    #[test]
    fn hub_merge_sums_counters_and_pressure_sheds() {
        let mut a = TelemetryHub::new();
        let mut b = TelemetryHub::new();
        a.on_offered(TenantClass::Energy);
        a.on_admit(TenantClass::Energy, 1);
        a.on_shed_pressure(TenantClass::Energy, 1);
        b.on_offered(TenantClass::Energy);
        b.on_admit(TenantClass::Energy, 7);
        b.on_shed_pressure(TenantClass::Energy, 7);
        b.on_offered(TenantClass::Exec);
        b.on_reject(TenantClass::Exec);
        b.sample_depths(5, 9);
        a.merge(&b);
        let e = &a.tenants[TenantClass::Energy.index()];
        assert_eq!((e.offered, e.admitted, e.shed_pressure), (2, 2, 2));
        assert_eq!(a.tenants[TenantClass::Exec.index()].rejected, 1);
        assert_eq!(a.shed_pressure_total(), 2);
        assert_eq!((a.queue_depth_max, a.fifo_depth_max), (5, 9));
    }

    #[test]
    fn digest_is_stable_and_input_sensitive() {
        let a = digest64("hello");
        assert_eq!(a, digest64("hello"));
        assert_ne!(a, digest64("hellp"));
        assert_eq!(a.len(), 16);
    }
}
