//! Request/decision logging and deterministic replay.
//!
//! The log is JSONL: one compact JSON object per line. Three event kinds:
//!
//! ```text
//! {"ev":"req","t_s":1.234,"tenant":"exec","model":"resnet18","images":500}
//! {"ev":"map","id":12,"model":"resnet18","images":500,"ideal_exec_s":0.42,"load_s":0.01}
//! {"ev":"done","id":12,"t_s":3.456}
//! ```
//!
//! * `req` — every request the source offered (admitted or not), in
//!   arrival order. Re-feeding these through
//!   [`super::ingest::TraceSource`] reproduces the exact offered stream.
//! * `map` — every mapping decision the scheduler committed, with its
//!   deterministic execution profile; a fingerprint for diffing scheduler
//!   behavior between runs.
//! * `done` — every job completion with its (server-local) job id. The
//!   fault-injection tests grep these across shard logs to prove
//!   at-most-once completion under failover.
//!
//! Lines starting with `#` and blank lines are ignored on parse, and
//! non-`req` events are skipped, so a recorded log replays as-is.

use super::{ServeRequest, TenantClass};
use crate::sim::ExecProfile;
use crate::util::json::Json;
use crate::workload::{DnnModel, Job};
use std::io::Write;

enum Sink {
    File(std::io::BufWriter<std::fs::File>),
    Mem(Vec<u8>),
}

/// Writes the JSONL replay log, either to a file or to memory (tests).
pub struct ReplayWriter {
    sink: Sink,
}

impl ReplayWriter {
    pub fn create(path: &str) -> std::io::Result<ReplayWriter> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let f = std::fs::File::create(path)?;
        Ok(ReplayWriter { sink: Sink::File(std::io::BufWriter::new(f)) })
    }

    pub fn in_memory() -> ReplayWriter {
        ReplayWriter { sink: Sink::Mem(Vec::new()) }
    }

    fn write_line(&mut self, j: &Json) -> std::io::Result<()> {
        let line = j.to_string_compact();
        match &mut self.sink {
            Sink::File(w) => {
                w.write_all(line.as_bytes())?;
                w.write_all(b"\n")
            }
            Sink::Mem(buf) => {
                buf.extend_from_slice(line.as_bytes());
                buf.push(b'\n');
                Ok(())
            }
        }
    }

    /// Log one offered request.
    pub fn request(&mut self, req: &ServeRequest) -> std::io::Result<()> {
        self.write_line(&Json::obj(vec![
            ("ev", Json::Str("req".to_string())),
            ("t_s", Json::Num(req.t_s)),
            ("tenant", Json::Str(req.tenant.name().to_string())),
            ("model", Json::Str(req.model.name().to_string())),
            ("images", Json::Num(req.images as f64)),
        ]))
    }

    /// Log one committed mapping decision.
    pub fn decision(&mut self, job: &Job, profile: &ExecProfile) -> std::io::Result<()> {
        self.write_line(&Json::obj(vec![
            ("ev", Json::Str("map".to_string())),
            ("id", Json::Num(job.id as f64)),
            ("model", Json::Str(job.dcg.model.name().to_string())),
            ("images", Json::Num(job.images as f64)),
            ("ideal_exec_s", Json::Num(profile.ideal_exec_s(job.images))),
            ("load_s", Json::Num(profile.load_time_s)),
        ]))
    }

    /// Log one job completion.
    pub fn done(&mut self, job_id: u64, t_s: f64) -> std::io::Result<()> {
        self.write_line(&Json::obj(vec![
            ("ev", Json::Str("done".to_string())),
            ("id", Json::Num(job_id as f64)),
            ("t_s", Json::Num(t_s)),
        ]))
    }

    pub fn flush(&mut self) -> std::io::Result<()> {
        match &mut self.sink {
            Sink::File(w) => w.flush(),
            Sink::Mem(_) => Ok(()),
        }
    }

    /// The recorded log, for in-memory writers (`None` for file sinks).
    pub fn into_string(self) -> Option<String> {
        match self.sink {
            Sink::Mem(buf) => Some(String::from_utf8(buf).expect("json is utf-8")),
            Sink::File(_) => None,
        }
    }
}

/// Parse a JSONL request log into a time-ordered request stream. Skips
/// blank lines, `#` comments, and non-`req` events.
pub fn parse_trace(text: &str) -> Result<Vec<ServeRequest>, String> {
    let mut reqs = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let j = Json::parse(line).map_err(|e| format!("trace line {}: {e:?}", ln + 1))?;
        if j.get("ev").as_str() != Some("req") {
            continue;
        }
        let t_s = j
            .get("t_s")
            .as_f64()
            .ok_or_else(|| format!("trace line {}: missing t_s", ln + 1))?;
        let tenant_name = j
            .get("tenant")
            .as_str()
            .ok_or_else(|| format!("trace line {}: missing tenant", ln + 1))?;
        let tenant = TenantClass::from_name(tenant_name)
            .ok_or_else(|| format!("trace line {}: unknown tenant `{tenant_name}`", ln + 1))?;
        let model_name = j
            .get("model")
            .as_str()
            .ok_or_else(|| format!("trace line {}: missing model", ln + 1))?;
        let model = DnnModel::from_name(model_name)
            .ok_or_else(|| format!("trace line {}: unknown model `{model_name}`", ln + 1))?;
        let images = j
            .get("images")
            .as_f64()
            .ok_or_else(|| format!("trace line {}: missing images", ln + 1))? as u64;
        if let Some(prev) = reqs.last() {
            if t_s < prev.t_s {
                return Err(format!("trace line {}: requests not time-ordered", ln + 1));
            }
        }
        reqs.push(ServeRequest { t_s, tenant, model, images });
    }
    Ok(reqs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_through_writer_and_parser() {
        let reqs = vec![
            ServeRequest {
                t_s: 0.25,
                tenant: TenantClass::Exec,
                model: DnnModel::ResNet18,
                images: 150,
            },
            ServeRequest {
                t_s: 1.75,
                tenant: TenantClass::Balanced,
                model: DnnModel::InceptionV3,
                images: 4000,
            },
        ];
        let mut w = ReplayWriter::in_memory();
        for r in &reqs {
            w.request(r).unwrap();
        }
        let text = w.into_string().unwrap();
        assert_eq!(text.lines().count(), 2);
        let back = parse_trace(&text).unwrap();
        assert_eq!(back.len(), 2);
        for (a, b) in reqs.iter().zip(&back) {
            assert_eq!(a.t_s, b.t_s);
            assert_eq!(a.tenant, b.tenant);
            assert_eq!(a.model, b.model);
            assert_eq!(a.images, b.images);
        }
    }

    #[test]
    fn parser_skips_comments_and_map_events() {
        let text = "\
# recorded by thermos serve
{\"ev\":\"req\",\"t_s\":1,\"tenant\":\"energy\",\"model\":\"alexnet\",\"images\":100}

{\"ev\":\"map\",\"id\":0,\"model\":\"alexnet\",\"images\":100,\"ideal_exec_s\":0.1,\"load_s\":0.01}
{\"ev\":\"done\",\"id\":0,\"t_s\":1.5}
{\"ev\":\"req\",\"t_s\":2,\"tenant\":\"exec\",\"model\":\"resnet50\",\"images\":300}
";
        let reqs = parse_trace(text).unwrap();
        assert_eq!(reqs.len(), 2);
        assert_eq!(reqs[0].tenant, TenantClass::Energy);
        assert_eq!(reqs[1].model, DnnModel::ResNet50);
    }

    #[test]
    fn parser_rejects_bad_input() {
        assert!(parse_trace("{\"ev\":\"req\"}").is_err(), "missing fields");
        assert!(parse_trace("not json").is_err());
        let unordered = "\
{\"ev\":\"req\",\"t_s\":2,\"tenant\":\"exec\",\"model\":\"alexnet\",\"images\":100}
{\"ev\":\"req\",\"t_s\":1,\"tenant\":\"exec\",\"model\":\"alexnet\",\"images\":100}
";
        assert!(parse_trace(unordered).is_err(), "unordered trace must fail");
    }
}
