//! The serving loop: multi-tenant admission control in front of the
//! open-loop simulation engine.
//!
//! Requests flow `source → per-tenant bounded queues → weighted-fair
//! dispatch → engine FIFO → scheduler → PIM execution`. Backpressure is
//! explicit at every stage: a full tenant queue *rejects* new requests, a
//! request that waits past its dispatch deadline is *shed*, and the
//! engine FIFO is only ever filled up to its free room — the batch
//! engine's silent host-stall backlog never grows in serve mode.

use super::ingest::TrafficSource;
use super::replay::ReplayWriter;
use super::telemetry::{digest64, TelemetryHub};
use super::{ServeRequest, TenantClass};
use crate::arch::Arch;
use crate::sched::policy::PolicyEval;
use crate::sched::thermos::{Preference, ThermosSched};
use crate::sched::{BigLittleSched, RelmasSched, Scheduler, SimbaSched, SysSnapshot};
use crate::sim::{Mapping, SimConfig, Simulator};
use crate::util::json::Json;
use crate::workload::{Job, ModelZoo};
use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;

/// A scheduler usable by the server. The single extra hook lets
/// preference-aware schedulers learn each job's tenant preference at
/// dispatch time; baselines ignore it.
pub trait ServeSched: Scheduler {
    fn register_pref(&mut self, _job_id: u64, _pref: Preference) {}
}

impl ServeSched for SimbaSched {}
impl ServeSched for BigLittleSched {}
impl<P: PolicyEval> ServeSched for RelmasSched<P> {}
/// A plain `ThermosSched` serves every tenant under its fixed ω.
impl<P: PolicyEval> ServeSched for ThermosSched<P> {}

/// Routes each job through the single preference-conditioned MORL policy
/// with the ω of the job's tenant class — one set of weights serving all
/// three service classes (§4.1's runtime-preference knob, applied
/// per-request).
pub struct TenantRouter<P: PolicyEval> {
    inner: ThermosSched<P>,
    prefs: std::collections::HashMap<u64, Preference>,
}

impl<P: PolicyEval> TenantRouter<P> {
    pub fn new(inner: ThermosSched<P>) -> TenantRouter<P> {
        TenantRouter { inner, prefs: std::collections::HashMap::new() }
    }
}

impl<P: PolicyEval> Scheduler for TenantRouter<P> {
    fn name(&self) -> &'static str {
        "thermos_mt"
    }

    fn schedule(&mut self, job: &Job, snap: &SysSnapshot) -> Option<Mapping> {
        if let Some(&pref) = self.prefs.get(&job.id) {
            self.inner.omega = pref;
        }
        self.inner.schedule(job, snap)
    }

    fn on_job_completed(&mut self, job_id: u64) {
        self.prefs.remove(&job_id);
        self.inner.on_job_completed(job_id);
    }
}

impl<P: PolicyEval> ServeSched for TenantRouter<P> {
    fn register_pref(&mut self, job_id: u64, pref: Preference) {
        self.prefs.insert(job_id, pref);
    }
}

#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Service horizon (s). The loop also ends early once a finite source
    /// drains and all work completes.
    pub duration_s: f64,
    /// Bound of each tenant queue; arrivals beyond it are rejected.
    pub tenant_queue_cap: usize,
    /// Shed a queued request once it has waited this long without being
    /// dispatched (0 disables shedding).
    pub max_wait_s: f64,
    /// Emit a telemetry snapshot every this many seconds (0 disables).
    pub snapshot_every_s: f64,
    /// Engine knobs (FIFO depth, thermal constraint, seed, …).
    /// `admit_rate`, `warmup_s`, and `mix_jobs` are unused in serve mode —
    /// the traffic source owns the workload.
    pub sim: SimConfig,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            duration_s: 120.0,
            tenant_queue_cap: 64,
            max_wait_s: 30.0,
            snapshot_every_s: 10.0,
            sim: SimConfig { warmup_s: 0.0, ..SimConfig::default() },
        }
    }
}

/// Final output of a server run: the report JSON, its FNV-1a digest (the
/// regression fingerprint), and any periodic snapshots.
#[derive(Debug)]
pub struct ServeReport {
    pub json: Json,
    pub digest: String,
    pub snapshots: Vec<Json>,
}

struct Pending {
    id: u64,
    req: ServeRequest,
}

/// The online scheduling service.
pub struct Server<'a, S: ServeSched> {
    arch: &'a Arch,
    sim: Simulator<'a, S>,
    source: Box<dyn TrafficSource>,
    cfg: ServeConfig,
    zoo: ModelZoo,
    queues: [VecDeque<Pending>; TenantClass::COUNT],
    hub: Rc<RefCell<TelemetryHub>>,
    replay: Option<Rc<RefCell<ReplayWriter>>>,
    snapshots: Vec<Json>,
    next_snapshot_s: f64,
    next_id: u64,
    /// Round-robin cursor for weighted-fair dispatch.
    rr: usize,
    cluster_max_temp_k: Vec<f64>,
    /// Live-telemetry hook: called with each periodic snapshot.
    pub on_snapshot: Option<Box<dyn FnMut(&Json) + 'a>>,
}

impl<'a, S: ServeSched> Server<'a, S> {
    pub fn new(
        arch: &'a Arch,
        sched: S,
        source: Box<dyn TrafficSource>,
        cfg: ServeConfig,
    ) -> Server<'a, S> {
        let mut sim = Simulator::open_loop(arch, sched, cfg.sim.clone());
        let hub = Rc::new(RefCell::new(TelemetryHub::new()));
        let hub_cb = hub.clone();
        sim.on_completed = Some(Box::new(move |stats| {
            hub_cb.borrow_mut().on_completed(stats);
        }));
        let n_clusters = arch.clusters.len();
        let snapshot_every = cfg.snapshot_every_s;
        Server {
            arch,
            sim,
            source,
            cfg,
            zoo: ModelZoo::new(),
            queues: Default::default(),
            hub,
            replay: None,
            snapshots: Vec::new(),
            next_snapshot_s: snapshot_every,
            next_id: 0,
            rr: 0,
            cluster_max_temp_k: vec![arch.t_ambient; n_clusters],
            on_snapshot: None,
        }
    }

    /// Record every offered request and every mapping decision to `w`.
    pub fn with_replay(mut self, w: Rc<RefCell<ReplayWriter>>) -> Self {
        let w_cb = w.clone();
        self.sim.on_mapped = Some(Box::new(move |job, profile| {
            let _ = w_cb.borrow_mut().decision(job, profile);
        }));
        self.replay = Some(w);
        self
    }

    fn offer(&mut self, req: ServeRequest) {
        if let Some(w) = &self.replay {
            let _ = w.borrow_mut().request(&req);
        }
        let ti = req.tenant.index();
        let mut hub = self.hub.borrow_mut();
        hub.on_offered(req.tenant);
        if self.queues[ti].len() >= self.cfg.tenant_queue_cap {
            hub.on_reject(req.tenant);
            return;
        }
        let id = self.next_id;
        self.next_id += 1;
        hub.on_admit(req.tenant, id);
        drop(hub);
        self.queues[ti].push_back(Pending { id, req });
    }

    fn dispatch(&mut self, now: f64) {
        // Shed queue heads that waited past the dispatch deadline.
        if self.cfg.max_wait_s > 0.0 {
            for q in self.queues.iter_mut() {
                while let Some(p) = q.front() {
                    if now - p.req.t_s > self.cfg.max_wait_s {
                        let p = q.pop_front().unwrap();
                        self.hub.borrow_mut().on_shed(p.req.tenant, p.id);
                    } else {
                        break;
                    }
                }
            }
        }
        // Round-robin over tenants into the engine FIFO, bounded by its
        // free room — explicit backpressure instead of a hidden backlog.
        let mut room = self.sim.queue_room();
        while room > 0 {
            let mut dispatched = false;
            for k in 0..TenantClass::COUNT {
                let ti = (self.rr + k) % TenantClass::COUNT;
                if let Some(p) = self.queues[ti].pop_front() {
                    self.rr = (ti + 1) % TenantClass::COUNT;
                    self.sim.sched.register_pref(p.id, p.req.tenant.pref());
                    self.sim.inject_job(Job {
                        id: p.id,
                        dcg: self.zoo.dcg(p.req.model),
                        images: p.req.images,
                        arrival_s: p.req.t_s,
                    });
                    room -= 1;
                    dispatched = true;
                    break;
                }
            }
            if !dispatched {
                break;
            }
        }
    }

    fn service_depth(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    fn post_step(&mut self) {
        self.hub.borrow_mut().sample_depths(self.service_depth(), self.sim.queue_len());
        for (c, &t) in self.sim.temps().iter().enumerate() {
            let cl = self.arch.chiplets[c].pim as usize;
            self.cluster_max_temp_k[cl] = self.cluster_max_temp_k[cl].max(t);
        }
        if self.cfg.snapshot_every_s > 0.0 && self.sim.now() + 1e-9 >= self.next_snapshot_s {
            let snap = self.snapshot_json();
            if let Some(cb) = self.on_snapshot.as_mut() {
                cb(&snap);
            }
            self.snapshots.push(snap);
            self.next_snapshot_s += self.cfg.snapshot_every_s;
        }
    }

    fn snapshot_json(&self) -> Json {
        let hub = self.hub.borrow();
        let (offered, admitted, rejected, shed, completed) = hub.totals();
        Json::obj(vec![
            ("t_s", Json::Num(self.sim.now())),
            ("offered", Json::Num(offered as f64)),
            ("admitted", Json::Num(admitted as f64)),
            ("rejected", Json::Num(rejected as f64)),
            ("shed", Json::Num(shed as f64)),
            ("completed", Json::Num(completed as f64)),
            ("queue_depth", Json::Num(self.service_depth() as f64)),
            ("fifo_depth", Json::Num(self.sim.queue_len() as f64)),
            ("active_jobs", Json::Num(self.sim.active_count() as f64)),
            ("throttle_events", Json::Num(self.sim.throttle_events() as f64)),
            ("max_temp_k", Json::Num(self.sim.max_temp_k())),
            ("p50_e2e_s", Json::Num(hub.e2e_all.quantile(0.50))),
            ("p99_e2e_s", Json::Num(hub.e2e_all.quantile(0.99))),
        ])
    }

    /// Drive the service to its horizon (or until a finite source drains
    /// and all admitted work completes) and produce the final report.
    pub fn run(mut self) -> ServeReport {
        let dt = self.sim.dt_s();
        let steps = (self.cfg.duration_s / dt).ceil() as usize;
        for _ in 0..steps {
            let step_end = self.sim.now() + dt;
            for req in self.source.arrivals_until(step_end) {
                self.offer(req);
            }
            self.dispatch(step_end);
            self.sim.step();
            self.post_step();
            if self.source.peek().is_none()
                && self.service_depth() == 0
                && self.sim.is_idle()
            {
                break;
            }
        }
        self.finish()
    }

    fn finish(mut self) -> ServeReport {
        if let Some(w) = &self.replay {
            let _ = w.borrow_mut().flush();
        }
        let (json, digest) = {
            let hub = self.hub.borrow();
            let (offered, admitted, rejected, shed, completed) = hub.totals();
            let now = self.sim.now();
            let json = Json::obj(vec![
                ("scheduler", Json::Str(self.sim.sched.name().to_string())),
                ("source", Json::Str(self.source.name().to_string())),
                ("seed", Json::Num(self.cfg.sim.seed as f64)),
                ("duration_s", Json::Num(now)),
                ("offered", Json::Num(offered as f64)),
                ("admitted", Json::Num(admitted as f64)),
                ("rejected", Json::Num(rejected as f64)),
                ("shed", Json::Num(shed as f64)),
                ("completed", Json::Num(completed as f64)),
                ("throughput_jobs_s", Json::Num(completed as f64 / now.max(1e-9))),
                ("latency_e2e_s", hub.e2e_all.to_json()),
                ("latency_exec_s", hub.exec_all.to_json()),
                ("energy_j", hub.energy_all.to_json()),
                ("queue_depth_max", Json::Num(hub.queue_depth_max as f64)),
                ("fifo_depth_max", Json::Num(hub.fifo_depth_max as f64)),
                ("host_stalls", Json::Num(self.sim.host_stalls() as f64)),
                ("throttle_events", Json::Num(self.sim.throttle_events() as f64)),
                ("max_temp_k", Json::Num(self.sim.max_temp_k())),
                ("cluster_max_temp_k", Json::arr_f64(&self.cluster_max_temp_k)),
                ("system_energy_j", Json::Num(self.sim.system_energy_j())),
                ("tenants", hub.tenants_json()),
            ]);
            let digest = digest64(&json.to_string_compact());
            (json, digest)
        };
        ServeReport { json, digest, snapshots: std::mem::take(&mut self.snapshots) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noi::NoiTopology;
    use crate::serve::ingest::PoissonSource;

    fn quick_serve_cfg(seed: u64) -> ServeConfig {
        ServeConfig {
            duration_s: 40.0,
            tenant_queue_cap: 16,
            max_wait_s: 20.0,
            snapshot_every_s: 10.0,
            sim: SimConfig {
                warmup_s: 0.0,
                max_images: 500,
                seed,
                ..SimConfig::default()
            },
        }
    }

    #[test]
    fn server_completes_jobs_and_reports() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let sched = SimbaSched::new(arch.clone());
        let source = Box::new(PoissonSource::new(1.0, 50, 500, [1.0, 1.0, 1.0], 17));
        let server = Server::new(&arch, sched, source, quick_serve_cfg(17));
        let report = server.run();
        let completed = report.json.get("completed").as_f64().unwrap();
        assert!(completed > 0.0, "no jobs completed");
        assert!(!report.snapshots.is_empty(), "expected periodic snapshots");
        // Required report fields exist.
        for key in [
            "latency_e2e_s",
            "rejected",
            "shed",
            "throttle_events",
            "cluster_max_temp_k",
            "tenants",
        ] {
            assert!(!matches!(report.json.get(key), Json::Null), "missing {key}");
        }
        let p99 = report.json.get("latency_e2e_s").get("p99").as_f64().unwrap();
        let p50 = report.json.get("latency_e2e_s").get("p50").as_f64().unwrap();
        assert!(p99 >= p50 && p50 > 0.0);
    }

    #[test]
    fn overload_rejects_or_sheds_instead_of_stalling() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let sched = SimbaSched::new(arch.clone());
        // Far beyond service capacity: ~20 jobs/s with a small queue cap.
        let source = Box::new(PoissonSource::new(20.0, 50, 500, [1.0, 1.0, 1.0], 23));
        let mut cfg = quick_serve_cfg(23);
        cfg.tenant_queue_cap = 4;
        cfg.max_wait_s = 5.0;
        let report = Server::new(&arch, sched, source, cfg).run();
        let rejected = report.json.get("rejected").as_f64().unwrap();
        let shed = report.json.get("shed").as_f64().unwrap();
        assert!(rejected + shed > 0.0, "overload must surface as rejects/sheds");
        // The engine's silent backlog must stay silent — serve never
        // overfills the FIFO.
        assert_eq!(report.json.get("host_stalls").as_f64().unwrap(), 0.0);
    }

    #[test]
    fn tenant_router_uses_per_tenant_preferences() {
        use crate::sched::policy::NativeDdt;
        use crate::sched::state::{StateEncoder, NUM_CLUSTERS, STATE_DIM};
        use crate::util::rng::Rng;
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let zoo = ModelZoo::new();
        let encoder = StateEncoder::new(&arch, &zoo, 500);
        let mut rng = Rng::new(9);
        let ddt = NativeDdt::init(STATE_DIM, NUM_CLUSTERS, &mut rng);
        let inner = ThermosSched::new(arch.clone(), encoder, ddt, [0.5, 0.5]);
        let sched = TenantRouter::new(inner);
        let source = Box::new(PoissonSource::new(1.0, 50, 500, [1.0, 1.0, 1.0], 31));
        let report = Server::new(&arch, sched, source, quick_serve_cfg(31)).run();
        assert_eq!(report.json.get("scheduler").as_str().unwrap(), "thermos_mt");
        // All three tenant classes completed work.
        let tenants = report.json.get("tenants");
        for t in TenantClass::ALL {
            let done = tenants.get(t.name()).get("completed").as_f64().unwrap();
            assert!(done > 0.0, "tenant {} completed nothing", t.name());
        }
    }
}
