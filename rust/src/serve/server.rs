//! The serving loop: multi-tenant admission control in front of the
//! open-loop simulation engine.
//!
//! Requests flow `source → per-tenant bounded queues → weighted-fair
//! dispatch → engine FIFO → scheduler → PIM execution`. Backpressure is
//! explicit at every stage: a full tenant queue *rejects* new requests, a
//! request that waits past its dispatch deadline is *shed*, and the
//! engine FIFO is only ever filled up to its free room — the batch
//! engine's silent host-stall backlog never grows in serve mode.
//!
//! Under thermal/power *pressure* (a throttled chiplet or a binding
//! arbiter power cap) the server additionally sheds in SLO order —
//! energy-class tenants first, then balanced, then exec — and stops
//! feeding the engine FIFO, holding work at the service layer where it
//! can still be shed instead of burying it in the engine.
//!
//! The server is driven either by its own [`TrafficSource`] via
//! [`Server::run`], or externally epoch-by-epoch via [`Server::offer`] +
//! [`Server::advance`] (the cluster shard workers).

use super::ingest::TrafficSource;
use super::replay::ReplayWriter;
use super::telemetry::{digest64, TelemetryHub};
use super::{ServeRequest, TenantClass};
use crate::arch::Arch;
use crate::sched::policy::PolicyEval;
use crate::sched::thermos::{Preference, ThermosSched};
use crate::sched::{BigLittleSched, RelmasSched, Scheduler, SimbaSched, SysSnapshot};
use crate::sim::{Mapping, ProfileCache, SimConfig, Simulator};
use crate::util::json::Json;
use crate::util::sync::lock_recover;
use crate::workload::{Job, ModelZoo};
use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

/// A scheduler usable by the server. The single extra hook lets
/// preference-aware schedulers learn each job's tenant preference at
/// dispatch time; baselines ignore it.
pub trait ServeSched: Scheduler {
    fn register_pref(&mut self, _job_id: u64, _pref: Preference) {}
}

impl ServeSched for SimbaSched {}
impl ServeSched for BigLittleSched {}
impl<P: PolicyEval> ServeSched for RelmasSched<P> {}
/// A plain `ThermosSched` serves every tenant under its fixed ω.
impl<P: PolicyEval> ServeSched for ThermosSched<P> {}

/// Routes each job through the single preference-conditioned MORL policy
/// with the ω of the job's tenant class — one set of weights serving all
/// three service classes (§4.1's runtime-preference knob, applied
/// per-request).
pub struct TenantRouter<P: PolicyEval> {
    inner: ThermosSched<P>,
    prefs: std::collections::HashMap<u64, Preference>,
}

impl<P: PolicyEval> TenantRouter<P> {
    pub fn new(inner: ThermosSched<P>) -> TenantRouter<P> {
        TenantRouter { inner, prefs: std::collections::HashMap::new() }
    }
}

impl<P: PolicyEval> Scheduler for TenantRouter<P> {
    fn name(&self) -> &'static str {
        "thermos_mt"
    }

    fn schedule(&mut self, job: &Job, snap: &SysSnapshot) -> Option<Mapping> {
        if let Some(&pref) = self.prefs.get(&job.id) {
            self.inner.omega = pref;
        }
        self.inner.schedule(job, snap)
    }

    fn on_job_completed(&mut self, job_id: u64) {
        self.prefs.remove(&job_id);
        self.inner.on_job_completed(job_id);
    }
}

impl<P: PolicyEval> ServeSched for TenantRouter<P> {
    fn register_pref(&mut self, job_id: u64, pref: Preference) {
        self.prefs.insert(job_id, pref);
    }
}

#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Service horizon (s). The loop also ends early once a finite source
    /// drains and all work completes.
    pub duration_s: f64,
    /// Bound of each tenant queue; arrivals beyond it are rejected.
    pub tenant_queue_cap: usize,
    /// Shed a queued request once it has waited this long without being
    /// dispatched (0 disables shedding).
    pub max_wait_s: f64,
    /// Emit a telemetry snapshot every this many seconds (0 disables).
    pub snapshot_every_s: f64,
    /// SLO-ordered pressure shedding: while the engine reports thermal or
    /// power-cap pressure, shed queued requests — energy class first,
    /// then balanced, then exec — until the total backlog (tenant queues
    /// + engine FIFO) is at most this deep (0 disables).
    pub pressure_depth: usize,
    /// Engine knobs (FIFO depth, thermal constraint, seed, …).
    /// `admit_rate`, `warmup_s`, and `mix_jobs` are unused in serve mode —
    /// the traffic source owns the workload.
    pub sim: SimConfig,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            duration_s: 120.0,
            tenant_queue_cap: 64,
            max_wait_s: 30.0,
            snapshot_every_s: 10.0,
            pressure_depth: 48,
            sim: SimConfig { warmup_s: 0.0, ..SimConfig::default() },
        }
    }
}

/// Final output of a server run: the report JSON, its FNV-1a digest (the
/// regression fingerprint), and any periodic snapshots.
#[derive(Debug)]
pub struct ServeReport {
    pub json: Json,
    pub digest: String,
    pub snapshots: Vec<Json>,
}

struct Pending {
    id: u64,
    req: ServeRequest,
}

/// The online scheduling service.
pub struct Server<'a, S: ServeSched> {
    arch: &'a Arch,
    sim: Simulator<'a, S>,
    source: Box<dyn TrafficSource>,
    cfg: ServeConfig,
    zoo: ModelZoo,
    queues: [VecDeque<Pending>; TenantClass::COUNT],
    hub: Arc<Mutex<TelemetryHub>>,
    replay: Option<Arc<Mutex<ReplayWriter>>>,
    /// Job ids completed since the last [`Server::take_epoch_done`] (fed by
    /// the engine completion callback; the cluster supervisor's at-most-
    /// once accounting reads these at each epoch barrier).
    epoch_done: Arc<Mutex<Vec<u64>>>,
    /// Request ids resolved *negatively* since the last take: rejected at
    /// admission, deadline-shed, or pressure-shed. These will never
    /// complete, so the supervisor can stop tracking them.
    epoch_dropped: Vec<u64>,
    snapshots: Vec<Json>,
    next_snapshot_s: f64,
    next_id: u64,
    /// Round-robin cursor for weighted-fair dispatch.
    rr: usize,
    cluster_max_temp_k: Vec<f64>,
    /// Peak chiplet temperature since the last `take_epoch_peak_temp_k`.
    epoch_peak_temp_k: f64,
    /// Live-telemetry hook: called with each periodic snapshot.
    pub on_snapshot: Option<Box<dyn FnMut(&Json) + 'a>>,
}

impl<'a, S: ServeSched> Server<'a, S> {
    pub fn new(
        arch: &'a Arch,
        sched: S,
        source: Box<dyn TrafficSource>,
        cfg: ServeConfig,
    ) -> Server<'a, S> {
        Self::new_with_hub(arch, sched, source, cfg, Arc::new(Mutex::new(TelemetryHub::new())))
    }

    /// Build a server around an existing telemetry hub. Shard restarts use
    /// this: the hub (and its accumulated counters/histograms) survives
    /// the engine + scheduler it instruments.
    pub fn new_with_hub(
        arch: &'a Arch,
        sched: S,
        source: Box<dyn TrafficSource>,
        cfg: ServeConfig,
        hub: Arc<Mutex<TelemetryHub>>,
    ) -> Server<'a, S> {
        let sim = Simulator::open_loop(arch, sched, cfg.sim.clone());
        let n_clusters = arch.clusters.len();
        let snapshot_every = cfg.snapshot_every_s;
        let mut server = Server {
            arch,
            sim,
            source,
            cfg,
            zoo: ModelZoo::new(),
            queues: Default::default(),
            hub,
            replay: None,
            epoch_done: Arc::new(Mutex::new(Vec::new())),
            epoch_dropped: Vec::new(),
            snapshots: Vec::new(),
            next_snapshot_s: snapshot_every,
            next_id: 0,
            rr: 0,
            cluster_max_temp_k: vec![arch.t_ambient; n_clusters],
            epoch_peak_temp_k: arch.t_ambient,
            on_snapshot: None,
        };
        server.wire_completion();
        server
    }

    /// (Re)attach the engine completion callback to the current hub,
    /// epoch-done buffer, and replay writer.
    fn wire_completion(&mut self) {
        let hub = self.hub.clone();
        let done = self.epoch_done.clone();
        let replay = self.replay.clone();
        self.sim.on_completed = Some(Box::new(move |stats| {
            lock_recover(&hub).on_completed(stats);
            lock_recover(&done).push(stats.id);
            if let Some(w) = &replay {
                let _ = lock_recover(w).done(stats.id, stats.completed_s);
            }
        }));
    }

    /// Record every offered request, mapping decision, and completion to
    /// `w`.
    pub fn with_replay(mut self, w: Arc<Mutex<ReplayWriter>>) -> Self {
        let w_cb = w.clone();
        self.sim.on_mapped = Some(Box::new(move |job, profile| {
            let _ = lock_recover(&w_cb).decision(job, profile);
        }));
        self.replay = Some(w);
        self.wire_completion();
        self
    }

    /// Share an `ExecProfile` memo table with the engine (cluster shards
    /// all pass clones of one cache).
    pub fn set_profile_cache(&mut self, cache: ProfileCache) {
        self.sim.set_profile_cache(cache);
    }

    /// Offer one request at the service boundary. Requests with a future
    /// `t_s` (batched ahead by the cluster router) are admitted now but
    /// held until their arrival time before dispatch.
    pub fn offer(&mut self, req: ServeRequest) {
        let id = self.next_id;
        self.offer_with_id(id, req);
    }

    /// Offer a request under a caller-assigned id (the cluster supervisor
    /// assigns globally-unique ids so a retried request keeps its identity
    /// across a failover — the basis of at-most-once accounting). A
    /// rejected id is recorded as dropped so the caller learns it will
    /// never complete.
    pub fn offer_with_id(&mut self, id: u64, req: ServeRequest) {
        self.next_id = self.next_id.max(id + 1);
        if let Some(w) = &self.replay {
            let _ = lock_recover(w).request(&req);
        }
        let ti = req.tenant.index();
        let mut hub = lock_recover(&self.hub);
        hub.on_offered(req.tenant);
        if self.queues[ti].len() >= self.cfg.tenant_queue_cap {
            hub.on_reject(req.tenant);
            drop(hub);
            self.epoch_dropped.push(id);
            return;
        }
        hub.on_admit(req.tenant, id);
        drop(hub);
        self.queues[ti].push_back(Pending { id, req });
    }

    fn dispatch(&mut self, now: f64) {
        // Shed queue heads that waited past the dispatch deadline.
        if self.cfg.max_wait_s > 0.0 {
            for q in self.queues.iter_mut() {
                while let Some(p) = q.front() {
                    if now - p.req.t_s > self.cfg.max_wait_s {
                        let Some(p) = q.pop_front() else { break };
                        lock_recover(&self.hub).on_shed(p.req.tenant, p.id);
                        self.epoch_dropped.push(p.id);
                    } else {
                        break;
                    }
                }
            }
        }
        // SLO-ordered pressure shedding (energy → balanced → exec), and
        // no new dispatch while the engine reports pressure: work stays
        // at the service layer where it can still be shed.
        let pressure = self.cfg.pressure_depth > 0 && self.sim.under_pressure();
        if pressure {
            let mut backlog = self.service_depth() + self.sim.queue_len();
            for tc in [TenantClass::Energy, TenantClass::Balanced, TenantClass::Exec] {
                while backlog > self.cfg.pressure_depth {
                    let Some(p) = self.queues[tc.index()].pop_front() else { break };
                    lock_recover(&self.hub).on_shed_pressure(tc, p.id);
                    self.epoch_dropped.push(p.id);
                    backlog -= 1;
                }
            }
            return;
        }
        // Round-robin over tenants into the engine FIFO, bounded by its
        // free room — explicit backpressure instead of a hidden backlog.
        let mut room = self.sim.queue_room();
        while room > 0 {
            let mut dispatched = false;
            for k in 0..TenantClass::COUNT {
                let ti = (self.rr + k) % TenantClass::COUNT;
                let ready = self.queues[ti]
                    .front()
                    .map(|p| p.req.t_s <= now + 1e-9)
                    .unwrap_or(false);
                if !ready {
                    continue;
                }
                let Some(p) = self.queues[ti].pop_front() else { continue };
                self.rr = (ti + 1) % TenantClass::COUNT;
                self.sim.sched.register_pref(p.id, p.req.tenant.pref());
                self.sim.inject_job(Job {
                    id: p.id,
                    dcg: self.zoo.dcg(p.req.model),
                    images: p.req.images,
                    arrival_s: p.req.t_s,
                });
                room -= 1;
                dispatched = true;
                break;
            }
            if !dispatched {
                break;
            }
        }
    }

    fn service_depth(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    /// Work-stealing donor side: pop admitted-but-undispatched requests
    /// off the *backs* of the tenant queues — newest first, energy class
    /// first, so the least latency-sensitive backlog migrates — until
    /// their estimated cost reaches `quota_cost_s`. Whole requests only;
    /// each keeps its global id and is re-offered on the recipient shard
    /// by the coordinator (the hub counts the re-offer like a failover
    /// retry; completion ids still settle at most once at the barrier).
    pub fn surrender_queued<C>(&mut self, quota_cost_s: f64, cost: C) -> Vec<(u64, ServeRequest)>
    where
        C: Fn(&ServeRequest) -> f64,
    {
        let mut out = Vec::new();
        if quota_cost_s <= 0.0 {
            return out;
        }
        let mut acc = 0.0;
        for tc in [TenantClass::Energy, TenantClass::Balanced, TenantClass::Exec] {
            while acc + 1e-12 < quota_cost_s {
                let Some(p) = self.queues[tc.index()].pop_back() else { break };
                acc += cost(&p.req);
                out.push((p.id, p.req));
            }
            if acc + 1e-12 >= quota_cost_s {
                break;
            }
        }
        out
    }

    fn post_step(&mut self) {
        lock_recover(&self.hub).sample_depths(self.service_depth(), self.sim.queue_len());
        for (c, &t) in self.sim.temps().iter().enumerate() {
            let cl = self.arch.chiplets[c].pim as usize;
            self.cluster_max_temp_k[cl] = self.cluster_max_temp_k[cl].max(t);
            self.epoch_peak_temp_k = self.epoch_peak_temp_k.max(t);
        }
        if self.cfg.snapshot_every_s > 0.0 && self.sim.now() + 1e-9 >= self.next_snapshot_s {
            let snap = self.snapshot_json();
            if let Some(cb) = self.on_snapshot.as_mut() {
                cb(&snap);
            }
            self.snapshots.push(snap);
            self.next_snapshot_s += self.cfg.snapshot_every_s;
        }
    }

    fn snapshot_json(&self) -> Json {
        let hub = lock_recover(&self.hub);
        let (offered, admitted, rejected, shed, completed) = hub.totals();
        Json::obj(vec![
            ("t_s", Json::Num(self.sim.now())),
            ("offered", Json::Num(offered as f64)),
            ("admitted", Json::Num(admitted as f64)),
            ("rejected", Json::Num(rejected as f64)),
            ("shed", Json::Num(shed as f64)),
            ("completed", Json::Num(completed as f64)),
            ("queue_depth", Json::Num(self.service_depth() as f64)),
            ("fifo_depth", Json::Num(self.sim.queue_len() as f64)),
            ("active_jobs", Json::Num(self.sim.active_count() as f64)),
            ("throttle_events", Json::Num(self.sim.throttle_events() as f64)),
            ("max_temp_k", Json::Num(self.sim.max_temp_k())),
            ("p50_e2e_s", Json::Num(hub.e2e_all.quantile(0.50))),
            ("p99_e2e_s", Json::Num(hub.e2e_all.quantile(0.99))),
        ])
    }

    /// One 100 ms service step: pull source arrivals, dispatch, advance
    /// the engine, sample telemetry.
    fn tick(&mut self) {
        let dt = self.sim.dt_s();
        let step_end = self.sim.now() + dt;
        for req in self.source.arrivals_until(step_end) {
            self.offer(req);
        }
        self.dispatch(step_end);
        self.sim.step();
        self.post_step();
    }

    /// Advance the service by `steps` engine steps (cluster epoch drive).
    pub fn advance(&mut self, steps: usize) {
        for _ in 0..steps {
            self.tick();
        }
    }

    pub fn now(&self) -> f64 {
        self.sim.now()
    }

    /// Source drained, queues empty, engine idle.
    pub fn is_drained(&self) -> bool {
        self.source.peek().is_none() && self.service_depth() == 0 && self.sim.is_idle()
    }

    pub fn set_power_cap_w(&mut self, cap: Option<f64>) {
        self.sim.set_power_cap_w(cap);
    }

    /// Package power of the most recent step (W).
    pub fn power_w(&self) -> f64 {
        self.sim.power_w()
    }

    pub fn any_throttled(&self) -> bool {
        self.sim.throttled().iter().any(|&t| t)
    }

    pub fn cap_gated(&self) -> bool {
        self.sim.cap_gated()
    }

    /// Tenant-queue backlog (requests not yet dispatched to the engine).
    pub fn queue_depth(&self) -> usize {
        self.service_depth()
    }

    /// Engine FIFO depth.
    pub fn fifo_depth(&self) -> usize {
        self.sim.queue_len()
    }

    pub fn completed_total(&self) -> u64 {
        lock_recover(&self.hub).totals().4
    }

    /// Shared handle to the telemetry hub (cluster merges these).
    pub fn hub_handle(&self) -> Arc<Mutex<TelemetryHub>> {
        self.hub.clone()
    }

    /// Drain the ids resolved since the last call: `(completed, dropped)`.
    /// Dropped means rejected or shed — the id will never complete. The
    /// cluster supervisor reads this at each epoch barrier to settle its
    /// in-flight ledger transactionally (crashes land only on barriers, so
    /// there is no completed-but-unreported window).
    pub fn take_epoch_done(&mut self) -> (Vec<u64>, Vec<u64>) {
        let done = std::mem::take(&mut *lock_recover(&self.epoch_done));
        let dropped = std::mem::take(&mut self.epoch_dropped);
        (done, dropped)
    }

    /// Fault injection: force a chiplet offline (thermal trip) or back.
    pub fn set_chiplet_offline(&mut self, chiplet: usize, off: bool) {
        self.sim.set_chiplet_offline(chiplet, off);
    }

    /// Fault recovery: book a supervisor-detected hang of `gap_s` seconds —
    /// the engine clock jumps to cluster time and active jobs record the
    /// gap as stall.
    pub fn stall_for(&mut self, gap_s: f64) {
        self.sim.stall_all(gap_s);
    }

    /// Fast-forward the engine clock (shard restart rejoining cluster
    /// time).
    pub fn set_clock_s(&mut self, t_s: f64) {
        self.sim.set_clock_s(t_s);
    }

    /// Peak chiplet temperature since the previous call (epoch telemetry
    /// for the cluster arbiter); resets the epoch window to the current
    /// temperature field.
    pub fn take_epoch_peak_temp_k(&mut self) -> f64 {
        let current = self
            .sim
            .temps()
            .iter()
            .fold(self.arch.t_ambient, |m, &t| m.max(t));
        std::mem::replace(&mut self.epoch_peak_temp_k, current)
    }

    /// Drive the service to its horizon (or until a finite source drains
    /// and all admitted work completes) and produce the final report.
    pub fn run(mut self) -> ServeReport {
        let dt = self.sim.dt_s();
        let steps = (self.cfg.duration_s / dt).ceil() as usize;
        for _ in 0..steps {
            self.tick();
            if self.is_drained() {
                break;
            }
        }
        self.finish()
    }

    /// Produce the final report (callers driving the server externally
    /// via [`Server::advance`] call this directly).
    pub fn finish(mut self) -> ServeReport {
        if let Some(w) = &self.replay {
            let _ = lock_recover(w).flush();
        }
        let (json, digest) = {
            let hub = lock_recover(&self.hub);
            let (offered, admitted, rejected, shed, completed) = hub.totals();
            let now = self.sim.now();
            let json = Json::obj(vec![
                ("scheduler", Json::Str(self.sim.sched.name().to_string())),
                ("source", Json::Str(self.source.name().to_string())),
                ("seed", Json::Num(self.cfg.sim.seed as f64)),
                ("duration_s", Json::Num(now)),
                ("offered", Json::Num(offered as f64)),
                ("admitted", Json::Num(admitted as f64)),
                ("rejected", Json::Num(rejected as f64)),
                ("shed", Json::Num(shed as f64)),
                ("shed_pressure", Json::Num(hub.shed_pressure_total() as f64)),
                ("completed", Json::Num(completed as f64)),
                ("images_done", Json::Num(hub.images_done_total() as f64)),
                ("throughput_jobs_s", Json::Num(completed as f64 / now.max(1e-9))),
                ("latency_e2e_s", hub.e2e_all.to_json()),
                ("latency_exec_s", hub.exec_all.to_json()),
                ("energy_j", hub.energy_all.to_json()),
                ("queue_depth_max", Json::Num(hub.queue_depth_max as f64)),
                ("fifo_depth_max", Json::Num(hub.fifo_depth_max as f64)),
                ("host_stalls", Json::Num(self.sim.host_stalls() as f64)),
                ("throttle_events", Json::Num(self.sim.throttle_events() as f64)),
                ("cap_gated_steps", Json::Num(self.sim.cap_gated_steps() as f64)),
                ("max_temp_k", Json::Num(self.sim.max_temp_k())),
                ("cluster_max_temp_k", Json::arr_f64(&self.cluster_max_temp_k)),
                ("system_energy_j", Json::Num(self.sim.system_energy_j())),
                ("tenants", hub.tenants_json()),
            ]);
            let digest = digest64(&json.to_string_compact());
            (json, digest)
        };
        ServeReport { json, digest, snapshots: std::mem::take(&mut self.snapshots) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noi::NoiTopology;
    use crate::serve::ingest::PoissonSource;

    fn quick_serve_cfg(seed: u64) -> ServeConfig {
        ServeConfig {
            duration_s: 40.0,
            tenant_queue_cap: 16,
            max_wait_s: 20.0,
            snapshot_every_s: 10.0,
            pressure_depth: 48,
            sim: SimConfig {
                warmup_s: 0.0,
                max_images: 500,
                seed,
                ..SimConfig::default()
            },
        }
    }

    #[test]
    fn server_completes_jobs_and_reports() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let sched = SimbaSched::new(arch.clone());
        let source = Box::new(PoissonSource::new(1.0, 50, 500, [1.0, 1.0, 1.0], 17));
        let server = Server::new(&arch, sched, source, quick_serve_cfg(17));
        let report = server.run();
        let completed = report.json.get("completed").as_f64().unwrap();
        assert!(completed > 0.0, "no jobs completed");
        assert!(!report.snapshots.is_empty(), "expected periodic snapshots");
        // Required report fields exist.
        for key in [
            "latency_e2e_s",
            "rejected",
            "shed",
            "throttle_events",
            "cluster_max_temp_k",
            "tenants",
        ] {
            assert!(!matches!(report.json.get(key), Json::Null), "missing {key}");
        }
        let p99 = report.json.get("latency_e2e_s").get("p99").as_f64().unwrap();
        let p50 = report.json.get("latency_e2e_s").get("p50").as_f64().unwrap();
        assert!(p99 >= p50 && p50 > 0.0);
    }

    #[test]
    fn overload_rejects_or_sheds_instead_of_stalling() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let sched = SimbaSched::new(arch.clone());
        // Far beyond service capacity: ~20 jobs/s with a small queue cap.
        let source = Box::new(PoissonSource::new(20.0, 50, 500, [1.0, 1.0, 1.0], 23));
        let mut cfg = quick_serve_cfg(23);
        cfg.tenant_queue_cap = 4;
        cfg.max_wait_s = 5.0;
        let report = Server::new(&arch, sched, source, cfg).run();
        let rejected = report.json.get("rejected").as_f64().unwrap();
        let shed = report.json.get("shed").as_f64().unwrap();
        assert!(rejected + shed > 0.0, "overload must surface as rejects/sheds");
        // The engine's silent backlog must stay silent — serve never
        // overfills the FIFO.
        assert_eq!(report.json.get("host_stalls").as_f64().unwrap(), 0.0);
    }

    #[test]
    fn pressure_shedding_drops_energy_class_first() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let sched = SimbaSched::new(arch.clone());
        let mut cfg = quick_serve_cfg(5);
        cfg.max_wait_s = 0.0; // isolate pressure sheds from deadline sheds
        cfg.pressure_depth = 4;
        let mut server =
            Server::new(&arch, sched, Box::new(crate::serve::ingest::NullSource), cfg);
        // An impossible 0 W cap puts the engine under pressure after one
        // step establishes nonzero (leakage) package power.
        server.set_power_cap_w(Some(0.0));
        server.advance(2);
        assert!(server.cap_gated(), "cap must be gating by now");
        for tenant in [TenantClass::Exec, TenantClass::Balanced, TenantClass::Energy] {
            for _ in 0..4 {
                server.offer(ServeRequest {
                    t_s: 0.0,
                    tenant,
                    model: crate::workload::DnnModel::ResNet18,
                    images: 100,
                });
            }
        }
        server.advance(1);
        // Backlog 12 must shrink to pressure_depth 4 in SLO order:
        // all 4 energy requests go, then all 4 balanced, exec survives.
        let hub = server.hub_handle();
        let hub = hub.lock().unwrap();
        assert_eq!(hub.tenants[TenantClass::Energy.index()].shed_pressure, 4);
        assert_eq!(hub.tenants[TenantClass::Balanced.index()].shed_pressure, 4);
        assert_eq!(hub.tenants[TenantClass::Exec.index()].shed_pressure, 0);
        assert_eq!(hub.shed_pressure_total(), 8);
        drop(hub);
        assert_eq!(server.queue_depth(), 4, "exec requests must survive");
        // Under pressure nothing is fed to the engine FIFO.
        assert_eq!(server.fifo_depth(), 0);
    }

    #[test]
    fn tenant_router_uses_per_tenant_preferences() {
        use crate::sched::policy::NativeDdt;
        use crate::sched::state::{StateEncoder, NUM_CLUSTERS, STATE_DIM};
        use crate::util::rng::Rng;
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let zoo = ModelZoo::new();
        let encoder = StateEncoder::new(&arch, &zoo, 500);
        let mut rng = Rng::new(9);
        let ddt = NativeDdt::init(STATE_DIM, NUM_CLUSTERS, &mut rng);
        let inner = ThermosSched::new(arch.clone(), encoder, ddt, [0.5, 0.5]);
        let sched = TenantRouter::new(inner);
        let source = Box::new(PoissonSource::new(1.0, 50, 500, [1.0, 1.0, 1.0], 31));
        let report = Server::new(&arch, sched, source, quick_serve_cfg(31)).run();
        assert_eq!(report.json.get("scheduler").as_str().unwrap(), "thermos_mt");
        // All three tenant classes completed work.
        let tenants = report.json.get("tenants");
        for t in TenantClass::ALL {
            let done = tenants.get(t.name()).get("completed").as_f64().unwrap();
            assert!(done > 0.0, "tenant {} completed nothing", t.name());
        }
    }
}
