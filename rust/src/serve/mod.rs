//! Online serving subsystem: a long-running, externally-driven service
//! loop around the batch [`crate::sim::Simulator`] core.
//!
//! THERMOS is pitched as a *runtime* scheduler, but the batch simulator
//! only exercises it through fixed-window runs with an internal Poisson
//! source. This module turns the engine into a service:
//!
//! * [`ingest`] — pluggable traffic sources: Poisson, bursty MMPP
//!   (on/off), and deterministic JSONL trace replay.
//! * [`server`] — multi-tenant admission control: per-preference tenant
//!   classes (`exec` / `balanced` / `energy`) routed through the single
//!   MORL policy, bounded per-tenant queues with backpressure, and
//!   explicit shed/reject accounting (no silent host-stall backlog).
//! * [`telemetry`] — counters, gauges, and streaming latency/energy
//!   histograms (p50/p95/p99), emitted as periodic JSON snapshots and a
//!   final report with a FNV-1a digest.
//! * [`replay`] — records every offered request (and each mapping
//!   decision) to a JSONL log that can be re-fed bit-for-bit: same seed →
//!   identical telemetry digest. The repo's deterministic regression
//!   harness for the scheduler hot path.

pub mod ingest;
pub mod replay;
pub mod server;
pub mod telemetry;

pub use ingest::{MmppSource, NullSource, PoissonSource, TraceSource, TrafficSource};
pub use replay::ReplayWriter;
pub use server::{ServeConfig, ServeReport, ServeSched, Server, TenantRouter};
pub use telemetry::{digest64, Histogram, TelemetryHub};

use crate::sched::thermos::{
    Preference, PREF_BALANCED, PREF_ENERGY, PREF_EXEC_TIME,
};
use crate::workload::DnnModel;

/// Tenant service classes: each maps to one runtime preference vector ω
/// of the single preference-conditioned MORL policy (§4.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TenantClass {
    /// Latency-sensitive: ω = [1, 0].
    Exec = 0,
    /// Balanced: ω = [0.5, 0.5].
    Balanced = 1,
    /// Energy-sensitive: ω = [0, 1].
    Energy = 2,
}

impl TenantClass {
    pub const ALL: [TenantClass; 3] =
        [TenantClass::Exec, TenantClass::Balanced, TenantClass::Energy];

    pub const COUNT: usize = 3;

    pub fn name(self) -> &'static str {
        match self {
            TenantClass::Exec => "exec",
            TenantClass::Balanced => "balanced",
            TenantClass::Energy => "energy",
        }
    }

    pub fn from_name(s: &str) -> Option<TenantClass> {
        match s {
            "exec" | "exec_time" | "time" => Some(TenantClass::Exec),
            "balanced" => Some(TenantClass::Balanced),
            "energy" => Some(TenantClass::Energy),
            _ => None,
        }
    }

    /// The preference vector this tenant's jobs are scheduled under.
    pub fn pref(self) -> Preference {
        match self {
            TenantClass::Exec => PREF_EXEC_TIME,
            TenantClass::Balanced => PREF_BALANCED,
            TenantClass::Energy => PREF_ENERGY,
        }
    }

    pub fn index(self) -> usize {
        self as usize
    }
}

/// One inference request as seen at the service boundary (before it
/// becomes an engine [`crate::workload::Job`]).
#[derive(Clone, Debug)]
pub struct ServeRequest {
    /// Offered-arrival time (s).
    pub t_s: f64,
    pub tenant: TenantClass,
    pub model: DnnModel,
    /// Stream length (frames).
    pub images: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tenant_names_round_trip() {
        for t in TenantClass::ALL {
            assert_eq!(TenantClass::from_name(t.name()), Some(t));
            assert_eq!(TenantClass::ALL[t.index()], t);
        }
        assert_eq!(TenantClass::from_name("nope"), None);
    }

    #[test]
    fn tenant_prefs_sum_to_one() {
        for t in TenantClass::ALL {
            let p = t.pref();
            assert!((p[0] + p[1] - 1.0).abs() < 1e-6);
        }
    }
}
