//! Pluggable traffic sources for the serving loop.
//!
//! All sources are deterministic given their seed (or trace file), which
//! is what makes the replay regression harness possible: the server never
//! draws randomness of its own, so the source fully determines the offered
//! request stream.

use super::{ServeRequest, TenantClass};
use crate::util::rng::Rng;
use crate::workload::WorkloadMix;

/// A stream of timestamped requests, consumed step-by-step by the server.
pub trait TrafficSource {
    fn name(&self) -> &'static str;

    /// Time of the next arrival, or `None` if the stream is exhausted.
    fn peek(&self) -> Option<f64>;

    /// Pop all requests arriving up to (and including) `now`, in
    /// non-decreasing time order.
    fn arrivals_until(&mut self, now: f64) -> Vec<ServeRequest>;
}

/// A source that never produces arrivals. Used by cluster shard workers,
/// whose requests are pushed in externally by the router each epoch
/// (`Server::offer`) instead of pulled from a source.
pub struct NullSource;

impl TrafficSource for NullSource {
    fn name(&self) -> &'static str {
        "null"
    }

    fn peek(&self) -> Option<f64> {
        None
    }

    fn arrivals_until(&mut self, _now: f64) -> Vec<ServeRequest> {
        Vec::new()
    }
}

/// Sample a tenant class from unnormalized weights (exec, balanced,
/// energy).
fn sample_tenant(rng: &mut Rng, weights: &[f64; 3]) -> TenantClass {
    TenantClass::ALL[rng.categorical(weights)]
}

/// Poisson arrivals at a fixed rate — the same process the batch
/// simulator's `TrafficGen` uses, lifted to the service boundary with a
/// tenant class sampled per request.
pub struct PoissonSource {
    mix: WorkloadMix,
    rate_jobs_s: f64,
    tenant_weights: [f64; 3],
    next_t: f64,
    idx: usize,
    rng: Rng,
}

impl PoissonSource {
    pub fn new(
        rate_jobs_s: f64,
        mix_jobs: usize,
        max_images: u64,
        tenant_weights: [f64; 3],
        seed: u64,
    ) -> PoissonSource {
        assert!(rate_jobs_s > 0.0, "Poisson rate must be positive");
        let mut rng = Rng::new(seed);
        let mix = WorkloadMix::random(&mut rng, mix_jobs, max_images);
        let first = rng.exp(rate_jobs_s);
        PoissonSource { mix, rate_jobs_s, tenant_weights, next_t: first, idx: 0, rng }
    }
}

impl TrafficSource for PoissonSource {
    fn name(&self) -> &'static str {
        "poisson"
    }

    fn peek(&self) -> Option<f64> {
        Some(self.next_t)
    }

    fn arrivals_until(&mut self, now: f64) -> Vec<ServeRequest> {
        let mut out = Vec::new();
        while self.next_t <= now {
            let (model, images) = self.mix.entries[self.idx % self.mix.entries.len()];
            let tenant = sample_tenant(&mut self.rng, &self.tenant_weights);
            out.push(ServeRequest { t_s: self.next_t, tenant, model, images });
            self.idx += 1;
            self.next_t += self.rng.exp(self.rate_jobs_s);
        }
        out
    }
}

/// Bursty traffic: a two-state Markov-modulated Poisson process. The
/// source alternates between an *on* state (rate `rate_on`) and an *off*
/// state (rate `rate_off`, may be 0) with exponentially distributed dwell
/// times — the standard model for bursty request arrivals.
pub struct MmppSource {
    mix: WorkloadMix,
    rate_on: f64,
    rate_off: f64,
    mean_on_s: f64,
    mean_off_s: f64,
    tenant_weights: [f64; 3],
    /// Internal clock of the generating process.
    t: f64,
    on: bool,
    state_until: f64,
    next_t: f64,
    idx: usize,
    rng: Rng,
}

impl MmppSource {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        rate_on: f64,
        rate_off: f64,
        mean_on_s: f64,
        mean_off_s: f64,
        mix_jobs: usize,
        max_images: u64,
        tenant_weights: [f64; 3],
        seed: u64,
    ) -> MmppSource {
        assert!(rate_on > 0.0, "MMPP on-state rate must be positive");
        assert!(rate_off >= 0.0);
        assert!(mean_on_s > 0.0 && mean_off_s > 0.0, "dwell times must be positive");
        let mut rng = Rng::new(seed);
        let mix = WorkloadMix::random(&mut rng, mix_jobs, max_images);
        let state_until = rng.exp(1.0 / mean_on_s);
        let mut src = MmppSource {
            mix,
            rate_on,
            rate_off,
            mean_on_s,
            mean_off_s,
            t: 0.0,
            on: true, // start in a burst
            state_until,
            next_t: 0.0,
            idx: 0,
            rng,
        };
        src.next_t = src.gen_next();
        src
    }

    /// Advance the modulated process to its next arrival. Exponential
    /// dwell/inter-arrival times are memoryless, so discarding a candidate
    /// that overshoots the state boundary and redrawing in the next state
    /// is exact.
    fn gen_next(&mut self) -> f64 {
        loop {
            let rate = if self.on { self.rate_on } else { self.rate_off };
            if rate > 1e-12 {
                let cand = self.t + self.rng.exp(rate);
                if cand <= self.state_until {
                    self.t = cand;
                    return cand;
                }
            }
            // No arrival before the state switch: jump to it.
            self.t = self.state_until;
            self.on = !self.on;
            let mean = if self.on { self.mean_on_s } else { self.mean_off_s };
            self.state_until = self.t + self.rng.exp(1.0 / mean);
        }
    }
}

impl TrafficSource for MmppSource {
    fn name(&self) -> &'static str {
        "mmpp"
    }

    fn peek(&self) -> Option<f64> {
        Some(self.next_t)
    }

    fn arrivals_until(&mut self, now: f64) -> Vec<ServeRequest> {
        let mut out = Vec::new();
        while self.next_t <= now {
            let arrival = self.next_t;
            let (model, images) = self.mix.entries[self.idx % self.mix.entries.len()];
            let tenant = sample_tenant(&mut self.rng, &self.tenant_weights);
            out.push(ServeRequest { t_s: arrival, tenant, model, images });
            self.idx += 1;
            self.next_t = self.gen_next();
        }
        out
    }
}

/// Replays a recorded JSONL request log (see [`super::replay`] for the
/// format). The stream is finite; `peek` returns `None` once drained.
pub struct TraceSource {
    reqs: Vec<ServeRequest>,
    idx: usize,
}

impl TraceSource {
    pub fn new(reqs: Vec<ServeRequest>) -> TraceSource {
        for w in reqs.windows(2) {
            assert!(w[0].t_s <= w[1].t_s, "trace requests must be time-ordered");
        }
        TraceSource { reqs, idx: 0 }
    }

    pub fn from_text(text: &str) -> Result<TraceSource, String> {
        Ok(TraceSource::new(super::replay::parse_trace(text)?))
    }

    pub fn from_path(path: &str) -> Result<TraceSource, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        Self::from_text(&text)
    }

    pub fn len(&self) -> usize {
        self.reqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.reqs.is_empty()
    }
}

impl TrafficSource for TraceSource {
    fn name(&self) -> &'static str {
        "replay"
    }

    fn peek(&self) -> Option<f64> {
        self.reqs.get(self.idx).map(|r| r.t_s)
    }

    fn arrivals_until(&mut self, now: f64) -> Vec<ServeRequest> {
        let mut out = Vec::new();
        while let Some(r) = self.reqs.get(self.idx) {
            if r.t_s > now {
                break;
            }
            out.push(r.clone());
            self.idx += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_source_rate_and_order() {
        let mut src = PoissonSource::new(2.0, 50, 1000, [1.0, 1.0, 1.0], 11);
        let reqs = src.arrivals_until(100.0);
        // E[#arrivals in 100 s at 2/s] = 200, σ ≈ 14.
        assert!((150..260).contains(&reqs.len()), "got {}", reqs.len());
        for w in reqs.windows(2) {
            assert!(w[0].t_s < w[1].t_s);
        }
        // All three tenants appear under uniform weights.
        for t in TenantClass::ALL {
            assert!(reqs.iter().any(|r| r.tenant == t), "{} missing", t.name());
        }
    }

    #[test]
    fn poisson_source_is_deterministic() {
        let a: Vec<_> = PoissonSource::new(3.0, 20, 500, [1.0, 2.0, 1.0], 5)
            .arrivals_until(50.0);
        let b: Vec<_> = PoissonSource::new(3.0, 20, 500, [1.0, 2.0, 1.0], 5)
            .arrivals_until(50.0);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.t_s, y.t_s);
            assert_eq!(x.tenant, y.tenant);
            assert_eq!(x.model, y.model);
            assert_eq!(x.images, y.images);
        }
    }

    #[test]
    fn mmpp_is_burstier_than_poisson_at_equal_mean_rate() {
        // MMPP with rate 8 on / 0 off and equal dwell means ⇒ mean rate 4.
        let mut mmpp = MmppSource::new(8.0, 0.0, 5.0, 5.0, 50, 500, [1.0, 1.0, 1.0], 21);
        let mut pois = PoissonSource::new(4.0, 50, 500, [1.0, 1.0, 1.0], 21);
        let horizon = 2000.0;
        let m = mmpp.arrivals_until(horizon);
        let p = pois.arrivals_until(horizon);
        // Comparable totals…
        assert!((m.len() as f64) > 0.5 * p.len() as f64, "{} vs {}", m.len(), p.len());
        // …but a much higher per-second count variance for the MMPP.
        let var = |reqs: &[ServeRequest]| {
            let mut counts = vec![0.0f64; horizon as usize];
            for r in reqs {
                let b = (r.t_s as usize).min(counts.len() - 1);
                counts[b] += 1.0;
            }
            let mean = counts.iter().sum::<f64>() / counts.len() as f64;
            counts.iter().map(|c| (c - mean) * (c - mean)).sum::<f64>() / counts.len() as f64
        };
        assert!(
            var(&m) > 1.5 * var(&p),
            "MMPP variance {} should exceed Poisson {}",
            var(&m),
            var(&p)
        );
    }

    #[test]
    fn mmpp_off_state_produces_gaps() {
        let mut src = MmppSource::new(20.0, 0.0, 1.0, 10.0, 20, 500, [1.0, 1.0, 1.0], 3);
        let reqs = src.arrivals_until(500.0);
        assert!(!reqs.is_empty());
        let max_gap = reqs
            .windows(2)
            .map(|w| w[1].t_s - w[0].t_s)
            .fold(0.0f64, f64::max);
        assert!(max_gap > 3.0, "expected off-state silence, max gap {max_gap}");
    }

    #[test]
    fn trace_source_replays_in_order_and_drains() {
        let reqs = vec![
            ServeRequest {
                t_s: 0.5,
                tenant: TenantClass::Exec,
                model: crate::workload::DnnModel::ResNet18,
                images: 100,
            },
            ServeRequest {
                t_s: 1.5,
                tenant: TenantClass::Energy,
                model: crate::workload::DnnModel::AlexNet,
                images: 200,
            },
        ];
        let mut src = TraceSource::new(reqs);
        assert_eq!(src.len(), 2);
        assert_eq!(src.peek(), Some(0.5));
        let first = src.arrivals_until(1.0);
        assert_eq!(first.len(), 1);
        assert_eq!(first[0].images, 100);
        assert_eq!(src.peek(), Some(1.5));
        let rest = src.arrivals_until(10.0);
        assert_eq!(rest.len(), 1);
        assert_eq!(src.peek(), None);
    }
}
