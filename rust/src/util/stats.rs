//! Small statistics helpers shared by the simulator metrics, the bench
//! harness, and the experiment reports, plus the FNV-1a hash used for
//! telemetry digests, the profile memo cache, and consistent-hash routing.

/// Incremental FNV-1a 64-bit hasher. Deterministic across platforms and
/// runs — the repo's fingerprint for telemetry digests, mapping keys, and
/// hash-ring points (never used for adversarial input).
#[derive(Clone, Debug)]
pub struct Fnv64(u64);

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    pub fn write_u64(&mut self, x: u64) {
        self.write(&x.to_le_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

/// One-shot FNV-1a 64-bit hash of a byte string.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.write(bytes);
    h.finish()
}

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        self.min
    }
    pub fn max(&self) -> f64 {
        self.max
    }
    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.std() / (self.n as f64).sqrt()
        }
    }

    pub fn merge(&mut self, other: &Running) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Percentile of a sample (linear interpolation, like numpy's default).
/// `q` in [0, 100]. Sorts a copy; fine off the hot path.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty sample");
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = q / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean of strictly positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Exponential smoothing used for the Fig. 6 value-loss curves
/// (paper smooths with alpha = 0.8).
pub fn ema(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = f64::NAN;
    for &x in xs {
        acc = if acc.is_nan() { x } else { alpha * acc + (1.0 - alpha) * x };
        out.push(acc);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_matches_direct() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        let m = mean(&xs);
        assert!((r.mean() - m).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((r.var() - var).abs() < 1e-12);
        assert_eq!(r.min(), 1.0);
        assert_eq!(r.max(), 9.0);
    }

    #[test]
    fn merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut a = Running::new();
        let mut b = Running::new();
        let mut all = Running::new();
        for (i, &x) in xs.iter().enumerate() {
            if i % 2 == 0 {
                a.push(x)
            } else {
                b.push(x)
            }
            all.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-12);
        assert!((a.var() - all.var()).abs() < 1e-9);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn ema_smooths() {
        let xs = [0.0, 1.0, 1.0, 1.0];
        let s = ema(&xs, 0.5);
        assert_eq!(s[0], 0.0);
        assert!((s[1] - 0.5).abs() < 1e-12);
        assert!(s[3] > s[1] && s[3] < 1.0);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
    }
}
