//! Criterion-style micro-benchmark harness (the offline vendor set has no
//! `criterion`). Used by the `[[bench]]` targets (all declared with
//! `harness = false`): warm-up, calibrated iteration counts, multiple
//! samples, and mean/σ/percentile reporting, plus a `black_box` to defeat
//! constant folding.

use crate::util::stats::{percentile, Running};
use std::time::{Duration, Instant};

/// Re-export of the compiler fence trick. Stable `std::hint::black_box`.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[derive(Clone, Debug)]
pub struct BenchConfig {
    /// Time spent warming up before measurement.
    pub warmup: Duration,
    /// Target time per sample.
    pub sample_time: Duration,
    /// Number of samples.
    pub samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(200),
            sample_time: Duration::from_millis(100),
            samples: 20,
        }
    }
}

/// Quick preset for end-to-end benches that run whole simulations.
impl BenchConfig {
    pub fn quick() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(0),
            sample_time: Duration::from_millis(0),
            samples: 3,
        }
    }
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Nanoseconds per iteration across samples.
    pub mean_ns: f64,
    pub std_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub iters_per_sample: u64,
    pub samples: usize,
}

impl BenchResult {
    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (σ {:>10}, p95 {:>10}, {} samples × {} iters)",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.std_ns),
            fmt_ns(self.p95_ns),
            self.samples,
            self.iters_per_sample,
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark a closure. The closure should perform one logical iteration
/// and return a value (passed through `black_box` internally).
pub fn bench<F, T>(name: &str, cfg: &BenchConfig, mut f: F) -> BenchResult
where
    F: FnMut() -> T,
{
    // Warm-up + calibration: find iters such that one sample ≈ sample_time.
    let warm_start = Instant::now();
    let mut calib_iters: u64 = 0;
    let mut calib_ns: f64 = 0.0;
    loop {
        let t = Instant::now();
        black_box(f());
        calib_ns += t.elapsed().as_nanos() as f64;
        calib_iters += 1;
        if warm_start.elapsed() >= cfg.warmup && calib_iters >= 3 {
            break;
        }
        if calib_iters >= 1_000_000 {
            break;
        }
    }
    let per_iter = (calib_ns / calib_iters as f64).max(0.5);
    let iters = ((cfg.sample_time.as_nanos() as f64 / per_iter).ceil() as u64).clamp(1, 10_000_000);

    let mut per_iter_samples = Vec::with_capacity(cfg.samples);
    let mut running = Running::new();
    for _ in 0..cfg.samples {
        let t = Instant::now();
        for _ in 0..iters {
            black_box(f());
        }
        let ns = t.elapsed().as_nanos() as f64 / iters as f64;
        per_iter_samples.push(ns);
        running.push(ns);
    }
    BenchResult {
        name: name.to_string(),
        mean_ns: running.mean(),
        std_ns: running.std(),
        p50_ns: percentile(&per_iter_samples, 50.0),
        p95_ns: percentile(&per_iter_samples, 95.0),
        iters_per_sample: iters,
        samples: cfg.samples,
    }
}

/// Time a single run of a long operation (whole-simulation benches).
pub fn time_once<F, T>(f: F) -> (T, Duration)
where
    F: FnOnce() -> T,
{
    let t = Instant::now();
    let out = f();
    (out, t.elapsed())
}

/// Group runner: prints a header and each result as it completes; returns
/// results for CSV export.
pub struct Group {
    pub title: String,
    pub cfg: BenchConfig,
    pub results: Vec<BenchResult>,
}

impl Group {
    pub fn new(title: &str) -> Group {
        // Honor THERMOS_BENCH_FAST=1 for CI-speed runs.
        let cfg = if std::env::var("THERMOS_BENCH_FAST").as_deref() == Ok("1") {
            BenchConfig {
                warmup: Duration::from_millis(20),
                sample_time: Duration::from_millis(10),
                samples: 5,
            }
        } else {
            BenchConfig::default()
        };
        println!("\n== {title} ==");
        Group { title: title.to_string(), cfg, results: Vec::new() }
    }

    pub fn bench<F, T>(&mut self, name: &str, f: F) -> &BenchResult
    where
        F: FnMut() -> T,
    {
        let r = bench(name, &self.cfg, f);
        println!("{}", r.report());
        self.results.push(r);
        self.results.last().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleep_scale() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(1),
            sample_time: Duration::from_millis(2),
            samples: 3,
        };
        let r = bench("spin", &cfg, || {
            // ~micro-scale busy work; black_box the seed so the optimizer
            // cannot constant-fold the loop away.
            let mut acc = black_box(1u64);
            for i in 0..1000u64 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
            }
            acc
        });
        assert!(r.mean_ns > 10.0, "mean {}", r.mean_ns);
        assert!(r.mean_ns < 1e7);
        assert!(r.p95_ns >= r.p50_ns * 0.5);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(2.5e9).contains("s"));
    }
}
