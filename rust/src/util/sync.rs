//! Poison-tolerant synchronization helpers.
//!
//! A worker thread that panics while holding a `Mutex` poisons it; every
//! later `lock().unwrap()` on another thread then panics too, cascading a
//! single shard failure across the whole cluster. For our telemetry and
//! replay handles the guarded data is always left in a consistent state
//! (plain counters / append-only logs mutated without intermediate
//! invariant breakage), so recovering the poisoned guard is safe and the
//! supervisor can keep serving.

use std::sync::{Mutex, MutexGuard, PoisonError};

/// Lock `m`, recovering the guard if a previous holder panicked.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn recovers_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(7u64));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        let mut g = lock_recover(&m);
        *g += 1;
        assert_eq!(*g, 8);
    }
}
