//! Tiny command-line parser (the offline vendor set has no `clap`).
//!
//! Supports the shapes the `thermos` binary and the bench/example binaries
//! need: a subcommand followed by `--flag`, `--key value`, and positional
//! arguments, plus generated `--help` text.

use std::collections::BTreeMap;

/// Declarative description of one option for help text + validation.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
    pub help: &'static str,
}

/// Parsed arguments for one (sub)command.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub cmd: String,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }
    pub fn has(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key) || self.values.contains_key(key)
    }
    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }
    pub fn parse_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: expected a number, got `{v}`")),
        }
    }
    pub fn parse_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: expected an integer, got `{v}`")),
        }
    }
    pub fn parse_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: expected an integer, got `{v}`")),
        }
    }
    /// Comma-separated list, e.g. `--rates 1.5,2,2.5`.
    pub fn parse_f64_list(&self, key: &str, default: &[f64]) -> Result<Vec<f64>, String> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| s.trim().parse().map_err(|_| format!("--{key}: bad list item `{s}`")))
                .collect(),
        }
    }
}

/// Parse `argv[1..]`. `value_opts` lists option names that consume the next
/// token; everything else starting with `--` is a boolean flag.
pub fn parse(argv: &[String], value_opts: &[&str]) -> Result<Args, String> {
    let mut args = Args::default();
    let mut it = argv.iter().peekable();
    if let Some(first) = it.peek() {
        if !first.starts_with("--") {
            args.cmd = it.next().unwrap().clone();
        }
    }
    while let Some(tok) = it.next() {
        if let Some(name) = tok.strip_prefix("--") {
            // --key=value form
            if let Some((k, v)) = name.split_once('=') {
                args.values.insert(k.to_string(), v.to_string());
                continue;
            }
            if value_opts.contains(&name) {
                let v = it
                    .next()
                    .ok_or_else(|| format!("--{name} expects a value"))?;
                args.values.insert(name.to_string(), v.clone());
            } else {
                args.flags.push(name.to_string());
            }
        } else {
            args.positional.push(tok.clone());
        }
    }
    Ok(args)
}

/// Render a help block for a subcommand.
pub fn render_help(cmd: &str, about: &str, opts: &[OptSpec]) -> String {
    let mut s = format!("{cmd} — {about}\n\nOptions:\n");
    for o in opts {
        let arg = if o.takes_value { format!("--{} <v>", o.name) } else { format!("--{}", o.name) };
        let def = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
        s.push_str(&format!("  {arg:<28} {}{def}\n", o.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_values_and_flags() {
        let a = parse(
            &v(&["train", "--steps", "1000", "--verbose", "--rate=2.5", "pos1"]),
            &["steps"],
        )
        .unwrap();
        assert_eq!(a.cmd, "train");
        assert_eq!(a.get("steps"), Some("1000"));
        assert_eq!(a.get("rate"), Some("2.5"));
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["pos1".to_string()]);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(parse(&v(&["run", "--steps"]), &["steps"]).is_err());
    }

    #[test]
    fn typed_accessors() {
        let a = parse(&v(&["x", "--n", "5", "--r", "1.5", "--list", "1,2,3"]), &["n", "r", "list"])
            .unwrap();
        assert_eq!(a.parse_usize("n", 0).unwrap(), 5);
        assert_eq!(a.parse_f64("r", 0.0).unwrap(), 1.5);
        assert_eq!(a.parse_f64("missing", 7.5).unwrap(), 7.5);
        assert_eq!(a.parse_f64_list("list", &[]).unwrap(), vec![1.0, 2.0, 3.0]);
        assert!(a.parse_usize("r", 0).is_err());
    }
}
