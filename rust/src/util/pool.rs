//! Deterministic fork-join work pool.
//!
//! Executes an indexed task list on N worker threads (plain
//! `std::thread::scope`, no extra dependencies) and returns results in
//! submission order. Workers claim task indices from an atomic counter, so
//! scheduling is racy — but every task is a pure function of its index,
//! and results are re-sorted by index before returning. The contract:
//! **output is byte-identical for 1 worker and N workers**. Experiment
//! sweeps, training rollouts, and benches all ride on this pool, which is
//! what lets `--threads 4` reports digest-match `--threads 1`.
//!
//! The pool size comes from (highest priority first) `set_global_threads`
//! (the `--threads` CLI flag), the `THERMOS_THREADS` environment variable,
//! and finally `std::thread::available_parallelism`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// 0 = unset (fall back to `THERMOS_THREADS`, then the core count).
static GLOBAL_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Pin the global pool width (the `--threads` CLI flag). Clamped to ≥ 1.
pub fn set_global_threads(n: usize) {
    GLOBAL_THREADS.store(n.max(1), Ordering::Relaxed);
}

/// Resolve the global pool width: `set_global_threads` override, else the
/// `THERMOS_THREADS` environment variable, else all available cores.
pub fn global_threads() -> usize {
    let n = GLOBAL_THREADS.load(Ordering::Relaxed);
    if n != 0 {
        return n;
    }
    if let Ok(v) = std::env::var("THERMOS_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// A fixed-width fork-join pool. Stateless between calls; each `run` is
/// one `thread::scope` fork-join, so there are no idle threads to manage
/// and a panicking task propagates at the join.
pub struct WorkPool {
    threads: usize,
}

impl WorkPool {
    pub fn new(threads: usize) -> WorkPool {
        WorkPool { threads: threads.max(1) }
    }

    /// Pool sized by the global thread configuration (see module docs).
    pub fn global() -> WorkPool {
        WorkPool::new(global_threads())
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Execute `f(0), f(1), …, f(n-1)` across the pool and return the
    /// results in index order. `f` must be a pure function of its index
    /// (it may capture shared read-only state) — that is what makes the
    /// output independent of the thread count.
    pub fn run<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        let workers = self.threads.min(n);
        if workers == 1 {
            return (0..n).map(f).collect();
        }
        let next = AtomicUsize::new(0);
        let done: Mutex<Vec<(usize, T)>> = Mutex::new(Vec::with_capacity(n));
        let f = &f;
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| {
                    let mut local: Vec<(usize, T)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(i)));
                    }
                    done.lock().expect("work pool result mutex").extend(local);
                });
            }
        });
        let mut pairs = done.into_inner().expect("work pool result mutex");
        debug_assert_eq!(pairs.len(), n, "every task index produces one result");
        pairs.sort_by_key(|&(i, _)| i);
        pairs.into_iter().map(|(_, t)| t).collect()
    }

    /// Map over a slice, in order: `out[i] = f(i, &items[i])`.
    pub fn map<I, T, F>(&self, items: &[I], f: F) -> Vec<T>
    where
        I: Sync,
        T: Send,
        F: Fn(usize, &I) -> T + Sync,
    {
        self.run(items.len(), |i| f(i, &items[i]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn results_come_back_in_submission_order() {
        let pool = WorkPool::new(8);
        // Make early tasks slow so completion order inverts submission
        // order — results must still come back sorted.
        let out = pool.run(32, |i| {
            if i < 4 {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            i * i
        });
        assert_eq!(out, (0..32).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn one_thread_and_many_threads_agree() {
        let f = |i: usize| {
            // Index-seeded pseudo-work: deterministic per index.
            let mut acc = i as u64 + 1;
            for k in 0..100u64 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(k);
            }
            acc
        };
        let serial = WorkPool::new(1).run(100, f);
        let pooled = WorkPool::new(7).run(100, f);
        assert_eq!(serial, pooled);
    }

    #[test]
    fn all_tasks_execute_exactly_once() {
        let count = AtomicU64::new(0);
        let out = WorkPool::new(3).run(250, |i| {
            count.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(count.load(Ordering::Relaxed), 250);
        assert_eq!(out.len(), 250);
    }

    #[test]
    fn map_passes_items_by_reference() {
        let items: Vec<String> = (0..10).map(|i| format!("job{i}")).collect();
        let out = WorkPool::new(4).map(&items, |i, s| format!("{i}:{s}"));
        assert_eq!(out[3], "3:job3");
        assert_eq!(out.len(), 10);
    }

    #[test]
    fn empty_and_singleton_inputs() {
        let pool = WorkPool::new(4);
        assert!(pool.run(0, |i| i).is_empty());
        assert_eq!(pool.run(1, |i| i + 41), vec![41]);
    }

    #[test]
    fn width_clamps_to_one() {
        assert_eq!(WorkPool::new(0).threads(), 1);
    }
}
