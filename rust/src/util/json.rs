//! Minimal JSON parser + writer.
//!
//! The offline vendor set carries `serde_core`/`serde_derive` but not the
//! `serde` facade or `serde_json`, so we implement the small JSON surface
//! this project needs: reading `artifacts/abi.json`, reading/writing
//! experiment configs and result files. Strict enough for our own files,
//! tolerant of whitespace and `//` line comments in configs.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Numbers are kept as f64 (all our payloads are dims,
/// rates, and metrics — none exceed 2^53).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    /// Object field lookup; `Json::Null` if missing or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }
    /// Required-field helpers used by the abi/config readers.
    pub fn req_usize(&self, key: &str) -> Result<usize, JsonError> {
        self.get(key)
            .as_usize()
            .ok_or_else(|| JsonError(format!("missing/invalid usize field `{key}`")))
    }
    pub fn req_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.get(key)
            .as_f64()
            .ok_or_else(|| JsonError(format!("missing/invalid number field `{key}`")))
    }
    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.get(key)
            .as_str()
            .ok_or_else(|| JsonError(format!("missing/invalid string field `{key}`")))
    }

    // -- construction helpers --------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn from(x: f64) -> Json {
        Json::Num(x)
    }

    /// Serialize. `indent > 0` pretty-prints.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, depth: usize, pretty: bool) {
        let pad = |out: &mut String, d: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..d {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if pretty {
                            out.push(' ');
                        }
                    }
                    item.write(out, depth + 1, false); // arrays stay on one line
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, depth + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, depth);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug, Clone)]
pub struct JsonError(pub String);

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error: {}", self.0)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError(format!("{msg} at byte {}", self.i))
    }

    fn skip_ws(&mut self) {
        loop {
            while self.i < self.b.len() && (self.b[self.i] as char).is_ascii_whitespace() {
                self.i += 1;
            }
            // `//` line comments (for hand-edited config files).
            if self.i + 1 < self.b.len() && self.b[self.i] == b'/' && self.b[self.i + 1] == b'/' {
                while self.i < self.b.len() && self.b[self.i] != b'\n' {
                    self.i += 1;
                }
            } else {
                return;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).unwrap_or("");
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-' {
                self.i += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [1.5, true, null, "x\ny"], "c": {"d": -2e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").as_usize(), Some(1));
        assert_eq!(v.get("b").as_arr().unwrap().len(), 4);
        assert_eq!(v.get("c").get("d").as_f64(), Some(-2000.0));
        // Re-parse our own output.
        let v2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn parses_comments_in_configs() {
        let src = "{\n// a comment\n \"x\": 3 // trailing\n}";
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("x").as_usize(), Some(3));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""A\t\"""#).unwrap();
        assert_eq!(v.as_str(), Some("A\t\""));
        let s = Json::Str("a\"b\\c\n".into()).to_string_compact();
        assert_eq!(Json::parse(&s).unwrap().as_str(), Some("a\"b\\c\n"));
    }

    #[test]
    fn req_helpers_error_on_missing() {
        let v = Json::parse(r#"{"n": 4}"#).unwrap();
        assert_eq!(v.req_usize("n").unwrap(), 4);
        assert!(v.req_usize("missing").is_err());
        assert!(v.req_str("n").is_err());
    }
}
