//! Foundation utilities built in-repo because the build environment is
//! offline (no `rand`, `serde`, `clap`, `criterion`, `proptest` facades):
//! PRNG, JSON, stats, CLI parsing, dense linear algebra, a deterministic
//! work pool, a property-test kit, and a micro-benchmark harness.

pub mod bench;
pub mod cli;
pub mod json;
pub mod linalg;
pub mod pool;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod testkit;
