//! Property-testing micro-framework (the offline vendor set has no
//! `proptest`). Provides seeded random-input sweeps with failure reporting
//! that includes the seed + case index so any failure is reproducible:
//!
//! ```ignore
//! forall(100, |rng| {
//!     let n = rng.range_usize(1, 20);
//!     ...
//!     check(cond, "message")
//! });
//! ```

use crate::util::rng::Rng;

/// Outcome of one property case.
pub type PropResult = Result<(), String>;

/// Helper for readable property bodies.
pub fn check(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn check_close(a: f64, b: f64, tol: f64, what: &str) -> PropResult {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

/// Run `cases` random cases of the property. The base seed is fixed (tests
/// must be deterministic in CI) but can be overridden with the
/// `THERMOS_PROP_SEED` environment variable to explore more of the space.
/// Panics with seed + case index on the first failure.
pub fn forall<F>(cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> PropResult,
{
    let base_seed: u64 = std::env::var("THERMOS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    for case in 0..cases {
        let mut rng = Rng::new(base_seed.wrapping_add(case as u64));
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property failed at case {case} (seed {base_seed}): {msg}\n\
                 reproduce with THERMOS_PROP_SEED={base_seed}"
            );
        }
    }
}

/// Generate a random vector of f32 in [lo, hi).
pub fn vec_f32(rng: &mut Rng, len: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..len).map(|_| lo + (hi - lo) * rng.f32()).collect()
}

/// Generate a random vector of f64 in [lo, hi).
pub fn vec_f64(rng: &mut Rng, len: usize, lo: f64, hi: f64) -> Vec<f64> {
    (0..len).map(|_| rng.range_f64(lo, hi)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_valid_property() {
        forall(50, |rng| {
            let x = rng.f64();
            check((0.0..1.0).contains(&x), "f64 out of range")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(50, |rng| {
            let x = rng.f64();
            check(x < 0.5, "will fail for some case")
        });
    }

    #[test]
    fn check_close_tolerates_scale() {
        assert!(check_close(1e9, 1e9 + 1.0, 1e-6, "big").is_ok());
        assert!(check_close(1.0, 2.0, 1e-6, "off").is_err());
    }
}
