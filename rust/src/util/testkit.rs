//! Property-testing micro-framework (the offline vendor set has no
//! `proptest`). Provides seeded random-input sweeps with failure reporting
//! that includes the seed + case index so any failure is reproducible:
//!
//! ```ignore
//! forall(100, |rng| {
//!     let n = rng.range_usize(1, 20);
//!     ...
//!     check(cond, "message")
//! });
//! ```
//!
//! Also hosts the cluster scenario harness shared by the steal/fault
//! integration tests: [`ClusterScenario`] builds a deterministic cluster
//! config + traffic source from a handful of knobs (shards, spares,
//! steal, chaos seed, traffic mix), and [`SkewedSource`] offers a
//! worst-case single-hot-model stream that consistent-hash routing
//! concentrates onto one shard — the scenario work-stealing exists to
//! fix.

use crate::cluster::{
    run_cluster, ClusterConfig, ClusterReport, FaultPlan, ShardSchedSpec, StealConfig,
};
use crate::serve::{PoissonSource, ServeConfig, ServeRequest, TenantClass, TrafficSource};
use crate::sim::SimConfig;
use crate::util::rng::Rng;
use crate::workload::DnnModel;

/// Outcome of one property case.
pub type PropResult = Result<(), String>;

/// Helper for readable property bodies.
pub fn check(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn check_close(a: f64, b: f64, tol: f64, what: &str) -> PropResult {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

/// Run `cases` random cases of the property. The base seed is fixed (tests
/// must be deterministic in CI) but can be overridden with the
/// `THERMOS_PROP_SEED` environment variable to explore more of the space.
/// Panics with seed + case index on the first failure.
pub fn forall<F>(cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> PropResult,
{
    let base_seed: u64 = std::env::var("THERMOS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    for case in 0..cases {
        let mut rng = Rng::new(base_seed.wrapping_add(case as u64));
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property failed at case {case} (seed {base_seed}): {msg}\n\
                 reproduce with THERMOS_PROP_SEED={base_seed}"
            );
        }
    }
}

/// Generate a random vector of f32 in [lo, hi).
pub fn vec_f32(rng: &mut Rng, len: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..len).map(|_| lo + (hi - lo) * rng.f32()).collect()
}

/// Generate a random vector of f64 in [lo, hi).
pub fn vec_f64(rng: &mut Rng, len: usize, lo: f64, hi: f64) -> Vec<f64> {
    (0..len).map(|_| rng.range_f64(lo, hi)).collect()
}

/// Adversarial single-model traffic: every request targets one hot model,
/// so consistent-hash routing sends the entire stream to one shard.
/// Arrivals are on a fixed grid (`1/rate`), tenants round-robin, images
/// fixed per request — no randomness at all, so skew experiments isolate
/// the scheduling policy, not the sampling noise.
pub struct SkewedSource {
    model: DnnModel,
    images: u64,
    period_s: f64,
    horizon_s: f64,
    next_t: f64,
    idx: usize,
}

impl SkewedSource {
    pub fn new(model: DnnModel, rate_jobs_s: f64, horizon_s: f64, images: u64) -> SkewedSource {
        assert!(rate_jobs_s > 0.0, "skewed source rate must be positive");
        let period_s = 1.0 / rate_jobs_s;
        SkewedSource { model, images, period_s, horizon_s, next_t: period_s, idx: 0 }
    }
}

impl TrafficSource for SkewedSource {
    fn name(&self) -> &'static str {
        "skewed"
    }

    fn peek(&self) -> Option<f64> {
        (self.next_t <= self.horizon_s).then_some(self.next_t)
    }

    fn arrivals_until(&mut self, now: f64) -> Vec<ServeRequest> {
        let mut out = Vec::new();
        while self.next_t <= now && self.next_t <= self.horizon_s {
            let tenant = TenantClass::ALL[self.idx % TenantClass::COUNT];
            let req =
                ServeRequest { t_s: self.next_t, tenant, model: self.model, images: self.images };
            out.push(req);
            self.idx += 1;
            self.next_t += self.period_s;
        }
        out
    }
}

/// Declarative cluster scenario shared by the steal and fault
/// integration tests: a handful of knobs expand into a full
/// [`ClusterConfig`] + traffic source with the same deterministic
/// defaults everywhere, so "the same scenario with stealing on" is a
/// one-builder-call diff, not a copy-pasted config block.
#[derive(Clone, Debug)]
pub struct ClusterScenario {
    pub shards: usize,
    pub seed: u64,
    pub spares: usize,
    pub steal: bool,
    pub steal_slack: f64,
    pub duration_s: f64,
    pub epoch_s: f64,
    pub drain_max_s: f64,
    pub rate_jobs_s: f64,
    pub tenant_mix: [f64; 3],
    pub max_images: u64,
    pub queue_cap: usize,
    pub max_wait_s: f64,
    /// Route *all* traffic at one model via [`SkewedSource`]; `None`
    /// uses the default Poisson mix.
    pub hot_model: Option<DnnModel>,
    pub faults: Option<FaultPlan>,
    /// Generate a chaos [`FaultPlan`] from this seed (ignored when
    /// `faults` is set explicitly).
    pub chaos_seed: Option<u64>,
    pub threads: Option<usize>,
    pub record_base: Option<String>,
}

impl ClusterScenario {
    pub fn new(shards: usize, seed: u64) -> ClusterScenario {
        ClusterScenario {
            shards,
            seed,
            spares: 0,
            steal: false,
            steal_slack: 0.25,
            duration_s: 30.0,
            epoch_s: 1.0,
            drain_max_s: 20.0,
            rate_jobs_s: 4.0,
            tenant_mix: [1.0, 1.0, 1.0],
            max_images: 500,
            queue_cap: 32,
            max_wait_s: 30.0,
            hot_model: None,
            faults: None,
            chaos_seed: None,
            threads: None,
            record_base: None,
        }
    }

    pub fn with_spares(mut self, k: usize) -> Self {
        self.spares = k;
        self
    }

    pub fn with_steal(mut self, on: bool) -> Self {
        self.steal = on;
        self
    }

    pub fn with_steal_slack(mut self, slack: f64) -> Self {
        self.steal_slack = slack;
        self
    }

    pub fn with_duration(mut self, duration_s: f64) -> Self {
        self.duration_s = duration_s;
        self
    }

    pub fn with_drain_max(mut self, drain_max_s: f64) -> Self {
        self.drain_max_s = drain_max_s;
        self
    }

    pub fn with_rate(mut self, rate_jobs_s: f64) -> Self {
        self.rate_jobs_s = rate_jobs_s;
        self
    }

    pub fn with_queue_cap(mut self, cap: usize) -> Self {
        self.queue_cap = cap;
        self
    }

    pub fn with_max_wait(mut self, max_wait_s: f64) -> Self {
        self.max_wait_s = max_wait_s;
        self
    }

    pub fn with_hot_model(mut self, model: DnnModel) -> Self {
        self.hot_model = Some(model);
        self
    }

    pub fn with_faults(mut self, plan: FaultPlan) -> Self {
        self.faults = Some(plan);
        self
    }

    pub fn with_chaos(mut self, chaos_seed: u64) -> Self {
        self.chaos_seed = Some(chaos_seed);
        self
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads);
        self
    }

    pub fn with_record_base(mut self, base: &str) -> Self {
        self.record_base = Some(base.to_string());
        self
    }

    /// Epochs the coordinator will run (mirrors the cluster's rounding).
    pub fn total_epochs(&self) -> usize {
        ((self.duration_s / self.epoch_s).ceil() as usize).max(1)
    }

    /// Expand into a full [`ClusterConfig`] with the shared defaults
    /// (Simba shards — deterministic and fast — with per-shard
    /// snapshotting off and pressure shedding at `queue_cap + 16`).
    pub fn config(&self) -> ClusterConfig {
        let faults = self.faults.clone().or_else(|| {
            self.chaos_seed.map(|c| FaultPlan::chaos(c, self.shards, self.total_epochs()))
        });
        ClusterConfig {
            shards: self.shards,
            epoch_s: self.epoch_s,
            duration_s: self.duration_s,
            drain_max_s: self.drain_max_s,
            serve: ServeConfig {
                duration_s: self.duration_s,
                tenant_queue_cap: self.queue_cap,
                max_wait_s: self.max_wait_s,
                snapshot_every_s: 0.0,
                pressure_depth: self.queue_cap + 16,
                sim: SimConfig {
                    warmup_s: 0.0,
                    max_images: self.max_images,
                    seed: self.seed,
                    ..SimConfig::default()
                },
            },
            sched: ShardSchedSpec::Simba,
            record_base: self.record_base.clone(),
            faults,
            spares: self.spares,
            steal: self.steal.then(|| StealConfig { seed: self.seed, slack: self.steal_slack }),
            threads: self.threads,
            ..ClusterConfig::default()
        }
    }

    /// The scenario's traffic source: [`SkewedSource`] when a hot model
    /// is set, the default Poisson mix otherwise.
    pub fn source(&self) -> Box<dyn TrafficSource> {
        match self.hot_model {
            Some(m) => Box::new(SkewedSource::new(m, self.rate_jobs_s, self.duration_s, 24)),
            None => Box::new(PoissonSource::new(
                self.rate_jobs_s,
                60,
                self.max_images,
                self.tenant_mix,
                self.seed,
            )),
        }
    }

    /// Run the scenario to completion.
    pub fn run(&self) -> ClusterReport {
        run_cluster(self.config(), self.source()).expect("cluster scenario run")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{steal_schedule, StealMove};

    #[test]
    fn forall_passes_valid_property() {
        forall(50, |rng| {
            let x = rng.f64();
            check((0.0..1.0).contains(&x), "f64 out of range")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn forall_reports_failure() {
        forall(50, |rng| {
            let x = rng.f64();
            check(x < 0.5, "will fail for some case")
        });
    }

    #[test]
    fn check_close_tolerates_scale() {
        assert!(check_close(1e9, 1e9 + 1.0, 1e-6, "big").is_ok());
        assert!(check_close(1.0, 2.0, 1e-6, "off").is_err());
    }

    #[test]
    fn scenario_expands_to_the_shared_defaults() {
        let base = ClusterScenario::new(4, 42);
        let cfg = base.config();
        assert_eq!(cfg.shards, 4);
        assert_eq!(cfg.serve.sim.seed, 42);
        assert!(cfg.steal.is_none() && cfg.faults.is_none());
        assert_eq!(cfg.spares, 0);
        assert_eq!(cfg.serve.pressure_depth, cfg.serve.tenant_queue_cap + 16);
        // One-call diffs flip exactly one plane on.
        let cfg = base.clone().with_steal(true).config();
        let sc = cfg.steal.expect("steal config");
        assert_eq!(sc.seed, 42);
        assert!((sc.slack - 0.25).abs() < 1e-12);
        let cfg = base.clone().with_chaos(7).config();
        assert!(cfg.faults.is_some(), "chaos seed expands to a fault plan");
        let cfg = base.clone().with_spares(2).with_threads(3).config();
        assert_eq!(cfg.spares, 2);
        assert_eq!(cfg.threads, Some(3));
    }

    #[test]
    fn skewed_source_is_a_fixed_grid_of_one_model() {
        let mut src = SkewedSource::new(DnnModel::ResNet50, 2.0, 3.0, 24);
        let first = src.arrivals_until(1.0);
        assert_eq!(first.len(), 2, "rate 2/s for 1 s");
        assert!(first.iter().all(|r| r.model == DnnModel::ResNet50));
        assert_eq!(first[0].t_s, 0.5);
        // The horizon caps the stream even for a later `now`.
        let rest = src.arrivals_until(100.0);
        assert_eq!(rest.len(), 4, "grid stops at the 3 s horizon");
        assert!(src.peek().is_none());
        // Tenants round-robin deterministically.
        assert_ne!(first[0].tenant, first[1].tenant);
    }

    #[test]
    fn steal_schedule_is_permutation_stable_under_relabeling() {
        forall(60, |rng| {
            let n = rng.range_usize(2, 8);
            let loads = vec_f64(rng, n, 0.0, 100.0);
            // Exact duplicates make the value ordering id-dependent;
            // skip those (measure-zero) draws.
            for i in 0..n {
                for j in i + 1..n {
                    if loads[i] == loads[j] {
                        return Ok(());
                    }
                }
            }
            let seed = rng.next_u64();
            let epoch = rng.range_usize(0, 50) as u64;
            let mut perm: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut perm);
            let mut relabeled = vec![0.0; n];
            for i in 0..n {
                relabeled[perm[i]] = loads[i];
            }
            let a = steal_schedule(seed, epoch, &loads, 0.25);
            let b = steal_schedule(seed, epoch, &relabeled, 0.25);
            let mapped: Vec<StealMove> = a
                .iter()
                .map(|m| StealMove { from: perm[m.from], to: perm[m.to], cost_s: m.cost_s })
                .collect();
            check(
                mapped == b,
                format!("relabeling changed the schedule: {mapped:?} vs {b:?} (perm {perm:?})"),
            )
        });
    }
}
