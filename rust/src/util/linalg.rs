//! Dense linear algebra needed by the thermal DSS model and the native
//! policy evaluator: row-major matrices, matmul/matvec, LU solve, and a
//! scaling-and-squaring Padé matrix exponential (used once at thermal-model
//! construction to discretize the continuous RC system).

/// Row-major dense matrix of f64.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[&[f64]]) -> Mat {
        let r = rows.len();
        let c = rows[0].len();
        let mut m = Mat::zeros(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c);
            m.data[i * c..(i + 1) * c].copy_from_slice(row);
        }
        m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        // ikj loop order: streams `other` rows, decent cache behaviour for
        // the few-hundred-node thermal matrices.
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.data[i * self.cols + k];
                if a == 0.0 {
                    continue;
                }
                let orow = &other.data[k * other.cols..(k + 1) * other.cols];
                let out_row = &mut out.data[i * other.cols..(i + 1) * other.cols];
                for j in 0..other.cols {
                    out_row[j] += a * orow[j];
                }
            }
        }
        out
    }

    pub fn matvec(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(self.cols, x.len());
        assert_eq!(self.rows, out.len());
        for i in 0..self.rows {
            let row = self.row(i);
            // Four independent accumulators break the FP add dependency
            // chain so the loop can saturate the FMA pipes
            // (EXPERIMENTS.md §Perf).
            let mut acc = [0.0f64; 4];
            let chunks = self.cols / 4;
            for c in 0..chunks {
                let b = 4 * c;
                acc[0] += row[b] * x[b];
                acc[1] += row[b + 1] * x[b + 1];
                acc[2] += row[b + 2] * x[b + 2];
                acc[3] += row[b + 3] * x[b + 3];
            }
            let mut tail = 0.0;
            for j in 4 * chunks..self.cols {
                tail += row[j] * x[j];
            }
            out[i] = acc[0] + acc[1] + acc[2] + acc[3] + tail;
        }
    }

    pub fn scale(&self, s: f64) -> Mat {
        let mut m = self.clone();
        for v in &mut m.data {
            *v *= s;
        }
        m
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut m = self.clone();
        for (a, b) in m.data.iter_mut().zip(&other.data) {
            *a += b;
        }
        m
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut m = self.clone();
        for (a, b) in m.data.iter_mut().zip(&other.data) {
            *a -= b;
        }
        m
    }

    /// 1-norm (max column sum) — used to pick the expm scaling factor.
    pub fn norm1(&self) -> f64 {
        let mut best = 0.0f64;
        for j in 0..self.cols {
            let mut s = 0.0;
            for i in 0..self.rows {
                s += self.data[i * self.cols + j].abs();
            }
            best = best.max(s);
        }
        best
    }

    /// LU decomposition with partial pivoting; returns (LU, perm) or None
    /// if singular.
    pub fn lu(&self) -> Option<(Mat, Vec<usize>)> {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        let mut lu = self.clone();
        let mut perm: Vec<usize> = (0..n).collect();
        for k in 0..n {
            // Pivot.
            let mut p = k;
            let mut pmax = lu[(k, k)].abs();
            for i in (k + 1)..n {
                let v = lu[(i, k)].abs();
                if v > pmax {
                    pmax = v;
                    p = i;
                }
            }
            if pmax < 1e-300 {
                return None;
            }
            if p != k {
                perm.swap(p, k);
                for j in 0..n {
                    let tmp = lu[(k, j)];
                    lu[(k, j)] = lu[(p, j)];
                    lu[(p, j)] = tmp;
                }
            }
            let pivot = lu[(k, k)];
            for i in (k + 1)..n {
                let f = lu[(i, k)] / pivot;
                lu[(i, k)] = f;
                for j in (k + 1)..n {
                    lu[(i, j)] -= f * lu[(k, j)];
                }
            }
        }
        Some((lu, perm))
    }

    /// Solve A X = B for X (A = self, square). Panics on singular A.
    /// Factors once; callers that solve repeatedly against the same matrix
    /// should hold a [`LuFactor`] instead.
    pub fn solve(&self, b: &Mat) -> Mat {
        let f = LuFactor::of(self).expect("solve: singular matrix");
        let n = self.rows;
        let mut x = Mat::zeros(n, b.cols);
        let mut col = vec![0.0; n];
        let mut out = vec![0.0; n];
        for c in 0..b.cols {
            for i in 0..n {
                col[i] = b[(i, c)];
            }
            f.solve_vec(&col, &mut out);
            for i in 0..n {
                x[(i, c)] = out[i];
            }
        }
        x
    }

    /// Matrix exponential via scaling-and-squaring with a [6/6] Padé
    /// approximant. Accurate to ~1e-12 for the well-conditioned RC system
    /// matrices we feed it (verified against series expansion in tests).
    pub fn expm(&self) -> Mat {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        let norm = self.norm1();
        // Scale so the norm is below 0.5.
        let s = if norm > 0.5 { (norm / 0.5).log2().ceil() as i32 } else { 0 };
        let a = self.scale(0.5f64.powi(s));

        // Padé [6/6]: N(A) = sum c_k A^k, D(A) = N(-A); coefficients
        // c_k = (2m-k)! m! / ((2m)! k! (m-k)!), m = 6.
        let m = 6usize;
        let mut c = vec![1.0f64; m + 1];
        for k in 1..=m {
            c[k] = c[k - 1] * ((m - k + 1) as f64) / ((k * (2 * m - k + 1)) as f64);
        }
        let mut num = Mat::eye(n).scale(c[0]);
        let mut den = Mat::eye(n).scale(c[0]);
        let mut pow = Mat::eye(n);
        for (k, &ck) in c.iter().enumerate().skip(1) {
            pow = pow.matmul(&a);
            num = num.add(&pow.scale(ck));
            let sign = if k % 2 == 0 { 1.0 } else { -1.0 };
            den = den.add(&pow.scale(sign * ck));
        }
        let mut e = den.solve(&num);
        for _ in 0..s {
            e = e.matmul(&e);
        }
        e
    }
}

/// Precomputed LU factorization (with partial-pivot permutation) for
/// repeated solves against one matrix: factor O(n³) once, then each
/// [`LuFactor::solve_vec`] is an allocation-free O(n²) substitution pair.
/// The thermal model holds one for `I − A_d` so steady-state queries in
/// candidate sweeps stop re-factoring per call.
#[derive(Clone, Debug)]
pub struct LuFactor {
    lu: Mat,
    perm: Vec<usize>,
}

impl LuFactor {
    /// Factor `m` (square). Returns `None` if singular.
    pub fn of(m: &Mat) -> Option<LuFactor> {
        let (lu, perm) = m.lu()?;
        Some(LuFactor { lu, perm })
    }

    pub fn n(&self) -> usize {
        self.lu.rows
    }

    /// Solve `A x = b` into `x` (both length n). No allocation.
    pub fn solve_vec(&self, b: &[f64], x: &mut [f64]) {
        let n = self.lu.rows;
        assert_eq!(b.len(), n);
        assert_eq!(x.len(), n);
        // Apply permutation.
        for i in 0..n {
            x[i] = b[self.perm[i]];
        }
        // Forward substitution (L has unit diagonal).
        for i in 1..n {
            let mut acc = x[i];
            for j in 0..i {
                acc -= self.lu[(i, j)] * x[j];
            }
            x[i] = acc;
        }
        // Back substitution.
        for i in (0..n).rev() {
            let mut acc = x[i];
            for j in (i + 1)..n {
                acc -= self.lu[(i, j)] * x[j];
            }
            x[i] = acc / self.lu[(i, i)];
        }
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: &Mat, b: &Mat, tol: f64) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() < tol, "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        approx(&c, &Mat::from_rows(&[&[19.0, 22.0], &[43.0, 50.0]]), 1e-12);
    }

    #[test]
    fn solve_roundtrip() {
        let a = Mat::from_rows(&[&[4.0, 1.0, 0.0], &[1.0, 3.0, 1.0], &[0.0, 1.0, 2.0]]);
        let x_true = Mat::from_rows(&[&[1.0], &[-2.0], &[0.5]]);
        let b = a.matmul(&x_true);
        let x = a.solve(&b);
        approx(&x, &x_true, 1e-10);
    }

    #[test]
    fn lu_factor_reuse_matches_solve() {
        let a = Mat::from_rows(&[&[4.0, 1.0, 0.0], &[1.0, 3.0, 1.0], &[0.0, 1.0, 2.0]]);
        let f = LuFactor::of(&a).unwrap();
        assert_eq!(f.n(), 3);
        let mut x = vec![0.0; 3];
        for b in [[1.0, 0.0, 0.0], [0.5, -2.0, 3.0], [7.0, 7.0, 7.0]] {
            f.solve_vec(&b, &mut x);
            let bm = Mat::from_rows(&[&[b[0]], &[b[1]], &[b[2]]]);
            let xm = a.solve(&bm);
            for i in 0..3 {
                assert!((x[i] - xm[(i, 0)]).abs() < 1e-12, "{} vs {}", x[i], xm[(i, 0)]);
            }
            // Round-trip: A x == b.
            for i in 0..3 {
                let ax: f64 = (0..3).map(|j| a[(i, j)] * x[j]).sum();
                assert!((ax - b[i]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn lu_detects_singular() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(a.lu().is_none());
    }

    #[test]
    fn expm_of_zero_is_identity() {
        let e = Mat::zeros(3, 3).expm();
        approx(&e, &Mat::eye(3), 1e-14);
    }

    #[test]
    fn expm_diagonal() {
        let a = Mat::from_rows(&[&[-1.0, 0.0], &[0.0, 2.0]]);
        let e = a.expm();
        let expected =
            Mat::from_rows(&[&[(-1.0f64).exp(), 0.0], &[0.0, (2.0f64).exp()]]);
        approx(&e, &expected, 1e-10);
    }

    #[test]
    fn expm_matches_series_for_rc_like_matrix() {
        // A stiff-ish RC-style matrix (negative diagonal, positive coupling).
        let a = Mat::from_rows(&[
            &[-3.0, 1.0, 0.5],
            &[1.0, -2.0, 0.5],
            &[0.25, 0.5, -1.0],
        ])
        .scale(2.0);
        // Taylor series with many terms as reference.
        let mut series = Mat::eye(3);
        let mut term = Mat::eye(3);
        for k in 1..60 {
            term = term.matmul(&a).scale(1.0 / k as f64);
            series = series.add(&term);
        }
        approx(&a.expm(), &series, 1e-9);
    }

    #[test]
    fn expm_semigroup_property() {
        let a = Mat::from_rows(&[&[-1.0, 0.3], &[0.2, -0.8]]);
        let e1 = a.expm();
        let e2 = a.scale(2.0).expm();
        approx(&e1.matmul(&e1), &e2, 1e-10);
    }
}
