//! Seedable, fast PRNG used across the simulator, trainer, and test kit.
//!
//! The offline vendor set has no `rand` crate, so we implement
//! xoshiro256++ (Blackman & Vigna) plus the distributions the simulator
//! needs: uniform, exponential (Poisson arrivals), categorical sampling,
//! and Gaussian (parameter init). Deterministic given a seed, `Clone` so
//! parallel environments can fork independent streams via `split`.

/// xoshiro256++ PRNG. 256-bit state, passes BigCrush, 1.2 ns/u64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a seed. Uses splitmix64 to expand the seed
    /// so that small seeds (0, 1, 2, ...) still yield well-mixed states.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Rng { s: [next_sm(), next_sm(), next_sm(), next_sm()] }
    }

    /// Fork an independent stream (jump-free split via re-seeding from the
    /// parent's output — adequate for simulation workloads).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Lemire's debiased multiply-shift.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng::below(0)");
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Exponential variate with the given rate (mean 1/rate); used for
    /// Poisson inter-arrival times of DL workloads.
    #[inline]
    pub fn exp(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        // Avoid ln(0) by nudging the uniform away from zero.
        -(1.0 - self.f64()).max(1e-300).ln() / rate
    }

    /// Standard Gaussian via Box–Muller (polar form avoided; trig is fine
    /// off the hot path — this is used only for parameter init).
    pub fn gaussian(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Sample an index from unnormalized non-negative weights.
    /// Returns `weights.len() - 1` on accumulated round-off.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical: all-zero weights");
        let mut u = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_uniform_enough() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut r = Rng::new(5);
        let rate = 2.5;
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exp(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(13);
        let w = [1.0, 3.0, 6.0];
        let mut counts = [0usize; 3];
        for _ in 0..100_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert!((counts[2] as f64 / counts[0] as f64 - 6.0).abs() < 0.6);
        assert!((counts[1] as f64 / counts[0] as f64 - 3.0).abs() < 0.4);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_are_independent() {
        let mut parent = Rng::new(23);
        let mut child = parent.split();
        let same = (0..64)
            .filter(|_| parent.next_u64() == child.next_u64())
            .count();
        assert!(same < 2);
    }
}
