//! DL workload characterization (paper §3.1, Definition 1).
//!
//! A workload is a DL Characterization Graph (DCG): vertices are neural
//! layers with `(w_i, o_i)` — weight memory and MAC count — and arcs carry
//! the activation volume `f_ij` between layers. Workloads stream into the
//! system as `(DNN, #images)` jobs (§5.2).

pub mod traffic;
pub mod zoo;

pub use traffic::{JobQueue, TrafficGen, WorkloadMix};
pub use zoo::{DnnModel, ModelZoo};

/// One neural layer: vertex of the DCG.
#[derive(Clone, Debug)]
pub struct Layer {
    /// Weight memory in bits (`w_i`). INT8 weights throughout (§2: PIM
    /// favours quantized DNNs).
    pub weight_bits: u64,
    /// Multiply-accumulate operations per input frame (`o_i`).
    pub macs: u64,
    /// Activation volume produced per input frame, bits — the DCG arc
    /// `f_{i,i+1}` to the next layer. DCGs of the six evaluation CNNs are
    /// chain-structured after fusing residual/branch structure (§4.4 notes
    /// G_DCG is largely linear).
    pub out_bits: u64,
    /// Human-readable layer label for reports.
    pub name: String,
}

/// DL Characterization Graph. Chain DCG: layer i feeds layer i+1; the
/// input arc of layer 0 is the image itself.
#[derive(Clone, Debug)]
pub struct Dcg {
    pub model: DnnModel,
    pub layers: Vec<Layer>,
    /// Input frame volume in bits (f_{0,1} into the first layer).
    pub input_bits: u64,
}

impl Dcg {
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }
    /// Total weight memory of the model, bits (Σ w_i).
    pub fn total_weight_bits(&self) -> u64 {
        self.layers.iter().map(|l| l.weight_bits).sum()
    }
    /// Total MACs per image (Σ o_i).
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs).sum()
    }
    /// Total inter-layer activation volume per image (Σ f_ij).
    pub fn total_activation_bits(&self) -> u64 {
        self.input_bits + self.layers.iter().map(|l| l.out_bits).sum::<u64>()
    }
    /// Activation volume flowing *into* layer `i` (Σ_k f_ki — chain DCG, so
    /// a single arc).
    pub fn in_bits(&self, i: usize) -> u64 {
        if i == 0 {
            self.input_bits
        } else {
            self.layers[i - 1].out_bits
        }
    }
}

/// A job: run `images` inference frames through `dcg` (§3.3).
#[derive(Clone, Debug)]
pub struct Job {
    pub id: u64,
    pub dcg: Dcg,
    pub images: u64,
    /// Simulation time the host admitted the job into the FIFO queue (s).
    pub arrival_s: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dcg_aggregates() {
        let zoo = ModelZoo::new();
        let dcg = zoo.dcg(DnnModel::AlexNet);
        assert_eq!(dcg.total_weight_bits(), dcg.layers.iter().map(|l| l.weight_bits).sum());
        assert!(dcg.total_macs() > 0);
        assert_eq!(dcg.in_bits(0), dcg.input_bits);
        assert_eq!(dcg.in_bits(1), dcg.layers[0].out_bits);
    }
}
