//! Model zoo: the six CNN evaluation workloads of §5.2 — AlexNet,
//! ResNet18, ResNet50, EfficientNet-B3, MobileNetV3-Large, Inception-v3 —
//! expressed as chain DCGs with per-layer weight bits, MACs, and
//! activation volumes.
//!
//! Layer shapes are derived with a small builder that tracks the feature
//! map (H, W, C) exactly as the reference architectures define them; all
//! tensors are INT8 (PIM-friendly quantization, §2). Branchy topologies
//! (ResNet residuals, Inception modules) are flattened to a chain — the
//! paper notes G_DCG is "largely linear" and its scheduler (like ours)
//! consumes the chain form; weights and MACs are preserved exactly,
//! activation arcs carry each layer's produced volume.

use super::{Dcg, Layer};

/// The six evaluation DNNs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DnnModel {
    AlexNet,
    ResNet18,
    ResNet50,
    EfficientNetB3,
    MobileNetV3Large,
    InceptionV3,
}

impl DnnModel {
    pub fn all() -> [DnnModel; 6] {
        [
            DnnModel::AlexNet,
            DnnModel::ResNet18,
            DnnModel::ResNet50,
            DnnModel::EfficientNetB3,
            DnnModel::MobileNetV3Large,
            DnnModel::InceptionV3,
        ]
    }
    pub fn name(self) -> &'static str {
        match self {
            DnnModel::AlexNet => "alexnet",
            DnnModel::ResNet18 => "resnet18",
            DnnModel::ResNet50 => "resnet50",
            DnnModel::EfficientNetB3 => "efficientnet_b3",
            DnnModel::MobileNetV3Large => "mobilenetv3_large",
            DnnModel::InceptionV3 => "inception_v3",
        }
    }
    pub fn from_name(s: &str) -> Option<DnnModel> {
        DnnModel::all().into_iter().find(|m| m.name() == s.to_ascii_lowercase())
    }
}

const BITS: u64 = 8; // INT8 activations and weights

/// Feature-map tracking layer builder.
struct Builder {
    h: u64,
    w: u64,
    c: u64,
    layers: Vec<Layer>,
    input_bits: u64,
}

impl Builder {
    fn new(h: u64, w: u64, c: u64) -> Builder {
        Builder { h, w, c, layers: Vec::new(), input_bits: h * w * c * BITS }
    }

    fn out_dim(dim: u64, k: u64, stride: u64, pad: u64) -> u64 {
        (dim + 2 * pad - k) / stride + 1
    }

    /// Standard convolution; `pad` defaults to "same-ish" k/2.
    fn conv(&mut self, name: &str, cout: u64, k: u64, stride: u64) {
        self.conv_p(name, cout, k, stride, k / 2)
    }

    fn conv_p(&mut self, name: &str, cout: u64, k: u64, stride: u64, pad: u64) {
        let ho = Self::out_dim(self.h, k, stride, pad);
        let wo = Self::out_dim(self.w, k, stride, pad);
        let macs = ho * wo * k * k * self.c * cout;
        let weights = k * k * self.c * cout;
        self.h = ho;
        self.w = wo;
        self.c = cout;
        self.layers.push(Layer {
            weight_bits: weights * BITS,
            macs,
            out_bits: ho * wo * cout * BITS,
            name: name.to_string(),
        });
    }

    /// Depthwise convolution (channel count unchanged).
    fn dwconv(&mut self, name: &str, k: u64, stride: u64) {
        let ho = Self::out_dim(self.h, k, stride, k / 2);
        let wo = Self::out_dim(self.w, k, stride, k / 2);
        let macs = ho * wo * k * k * self.c;
        let weights = k * k * self.c;
        self.h = ho;
        self.w = wo;
        self.layers.push(Layer {
            weight_bits: weights * BITS,
            macs,
            out_bits: ho * wo * self.c * BITS,
            name: name.to_string(),
        });
    }

    /// Pointwise 1×1 convolution.
    fn pwconv(&mut self, name: &str, cout: u64) {
        self.conv_p(name, cout, 1, 1, 0)
    }

    /// Pooling: changes dimensions and shrinks the activation volume the
    /// previous layer ships to its consumer (pools have no weights; their
    /// negligible compute is folded into the producer).
    fn pool(&mut self, k: u64, stride: u64, pad: u64) {
        self.h = Self::out_dim(self.h, k, stride, pad);
        self.w = Self::out_dim(self.w, k, stride, pad);
        if let Some(last) = self.layers.last_mut() {
            last.out_bits = self.h * self.w * self.c * BITS;
        } else {
            self.input_bits = self.h * self.w * self.c * BITS;
        }
    }

    fn global_pool(&mut self) {
        self.h = 1;
        self.w = 1;
        if let Some(last) = self.layers.last_mut() {
            last.out_bits = self.c * BITS;
        }
    }

    fn fc(&mut self, name: &str, out: u64) {
        let inp = self.h * self.w * self.c;
        self.h = 1;
        self.w = 1;
        self.c = out;
        self.layers.push(Layer {
            weight_bits: inp * out * BITS,
            macs: inp * out,
            out_bits: out * BITS,
            name: name.to_string(),
        });
    }

    /// Squeeze-and-excitation: two small FCs on globally pooled features.
    /// Feature map dims are unchanged; weight/MAC contribution recorded as
    /// one fused layer.
    fn se(&mut self, name: &str, reduced: u64) {
        let c = self.c;
        let weights = c * reduced + reduced * c;
        self.layers.push(Layer {
            weight_bits: weights * BITS,
            macs: weights, // one MAC per weight (1×1 spatial)
            out_bits: self.h * self.w * c * BITS,
            name: name.to_string(),
        });
    }

    /// Used by Inception modules: set the channel count after a (virtual)
    /// concat of parallel branches.
    fn set_channels(&mut self, c: u64) {
        self.c = c;
        if let Some(last) = self.layers.last_mut() {
            last.out_bits = self.h * self.w * c * BITS;
        }
    }

    fn finish(self, model: DnnModel) -> Dcg {
        Dcg { model, layers: self.layers, input_bits: self.input_bits }
    }
}

/// Zoo with cached DCGs and normalization statistics used by the RL state
/// encoder.
#[derive(Clone, Debug)]
pub struct ModelZoo {
    dcgs: Vec<Dcg>,
}

impl Default for ModelZoo {
    fn default() -> Self {
        Self::new()
    }
}

impl ModelZoo {
    pub fn new() -> ModelZoo {
        ModelZoo { dcgs: DnnModel::all().iter().map(|&m| build_model(m)).collect() }
    }

    pub fn dcg(&self, m: DnnModel) -> Dcg {
        self.dcgs[DnnModel::all().iter().position(|&x| x == m).unwrap()].clone()
    }

    pub fn all_dcgs(&self) -> &[Dcg] {
        &self.dcgs
    }

    /// Normalization constants for RL state features (max over the zoo).
    pub fn max_layer_weight_bits(&self) -> u64 {
        self.dcgs.iter().flat_map(|d| &d.layers).map(|l| l.weight_bits).max().unwrap()
    }
    pub fn max_layer_macs(&self) -> u64 {
        self.dcgs.iter().flat_map(|d| &d.layers).map(|l| l.macs).max().unwrap()
    }
    pub fn max_layer_act_bits(&self) -> u64 {
        self.dcgs
            .iter()
            .flat_map(|d| (0..d.num_layers()).map(move |i| d.in_bits(i)))
            .max()
            .unwrap()
    }
    pub fn max_model_weight_bits(&self) -> u64 {
        self.dcgs.iter().map(|d| d.total_weight_bits()).max().unwrap()
    }
    pub fn max_model_macs(&self) -> u64 {
        self.dcgs.iter().map(|d| d.total_macs()).max().unwrap()
    }
    pub fn max_model_act_bits(&self) -> u64 {
        self.dcgs.iter().map(|d| d.total_activation_bits()).max().unwrap()
    }
    pub fn max_layers(&self) -> usize {
        self.dcgs.iter().map(|d| d.num_layers()).max().unwrap()
    }
}

pub fn build_model(m: DnnModel) -> Dcg {
    match m {
        DnnModel::AlexNet => alexnet(),
        DnnModel::ResNet18 => resnet18(),
        DnnModel::ResNet50 => resnet50(),
        DnnModel::EfficientNetB3 => efficientnet_b3(),
        DnnModel::MobileNetV3Large => mobilenetv3_large(),
        DnnModel::InceptionV3 => inception_v3(),
    }
}

fn alexnet() -> Dcg {
    let mut b = Builder::new(224, 224, 3);
    b.conv_p("conv1", 64, 11, 4, 2);
    b.pool(3, 2, 0);
    b.conv_p("conv2", 192, 5, 1, 2);
    b.pool(3, 2, 0);
    b.conv("conv3", 384, 3, 1);
    b.conv("conv4", 256, 3, 1);
    b.conv("conv5", 256, 3, 1);
    b.pool(3, 2, 0);
    b.fc("fc6", 4096);
    b.fc("fc7", 4096);
    b.fc("fc8", 1000);
    b.finish(DnnModel::AlexNet)
}

fn resnet18() -> Dcg {
    let mut b = Builder::new(224, 224, 3);
    b.conv_p("conv1", 64, 7, 2, 3);
    b.pool(3, 2, 1);
    let stages: [(u64, usize); 4] = [(64, 2), (128, 2), (256, 2), (512, 2)];
    for (si, &(c, blocks)) in stages.iter().enumerate() {
        for blk in 0..blocks {
            let stride = if si > 0 && blk == 0 { 2 } else { 1 };
            if stride == 2 {
                // Projection shortcut 1×1 conv.
                b.conv_p(&format!("s{si}b{blk}_down"), c, 1, 2, 0);
                // Restore the pre-downsample input for the block's first conv
                // is already reflected: shortcut consumed the map; the main
                // path convs operate on the downsampled map (weight/MAC
                // equivalent chainization).
                b.conv(&format!("s{si}b{blk}_conv1"), c, 3, 1);
            } else {
                b.conv(&format!("s{si}b{blk}_conv1"), c, 3, stride);
            }
            b.conv(&format!("s{si}b{blk}_conv2"), c, 3, 1);
        }
    }
    b.global_pool();
    b.fc("fc", 1000);
    b.finish(DnnModel::ResNet18)
}

fn resnet50() -> Dcg {
    let mut b = Builder::new(224, 224, 3);
    b.conv_p("conv1", 64, 7, 2, 3);
    b.pool(3, 2, 1);
    let stages: [(u64, usize); 4] = [(64, 3), (128, 4), (256, 6), (512, 3)];
    for (si, &(c, blocks)) in stages.iter().enumerate() {
        for blk in 0..blocks {
            let stride = if si > 0 && blk == 0 { 2 } else { 1 };
            if blk == 0 {
                // Projection shortcut to 4c channels.
                b.conv_p(&format!("s{si}b{blk}_down"), c * 4, 1, stride, 0);
                // Bottleneck operates from the projected map's spatial dims;
                // channel bookkeeping for the main path:
                b.set_channels(c * 4);
            }
            b.pwconv(&format!("s{si}b{blk}_reduce"), c);
            b.conv(&format!("s{si}b{blk}_conv3x3"), c, 3, 1);
            b.pwconv(&format!("s{si}b{blk}_expand"), c * 4);
        }
    }
    b.global_pool();
    b.fc("fc", 1000);
    b.finish(DnnModel::ResNet50)
}

/// EfficientNet-B3: B0 stage table scaled by width 1.2 / depth 1.4,
/// 300×300 input.
fn efficientnet_b3() -> Dcg {
    fn wscale(c: u64) -> u64 {
        // Round to nearest multiple of 8, standard EfficientNet rule.
        let scaled = c as f64 * 1.2;
        (((scaled / 8.0).round() as u64).max(1)) * 8
    }
    fn dscale(n: u64) -> u64 {
        (n as f64 * 1.4).ceil() as u64
    }
    let mut b = Builder::new(300, 300, 3);
    b.conv("stem", wscale(32), 3, 2);
    // (expansion, channels, repeats, kernel, stride)
    let table: [(u64, u64, u64, u64, u64); 7] = [
        (1, 16, 1, 3, 1),
        (6, 24, 2, 3, 2),
        (6, 40, 2, 5, 2),
        (6, 80, 3, 3, 2),
        (6, 112, 3, 5, 1),
        (6, 192, 4, 5, 2),
        (6, 320, 1, 3, 1),
    ];
    for (bi, &(exp, c, n, k, s)) in table.iter().enumerate() {
        let cout = wscale(c);
        for r in 0..dscale(n) {
            let stride = if r == 0 { s } else { 1 };
            let cin = b.c;
            let expanded = cin * exp;
            if exp > 1 {
                b.pwconv(&format!("mb{bi}_{r}_expand"), expanded);
            }
            b.dwconv(&format!("mb{bi}_{r}_dw"), k, stride);
            b.se(&format!("mb{bi}_{r}_se"), (cin / 4).max(1));
            b.pwconv(&format!("mb{bi}_{r}_project"), cout);
        }
    }
    b.pwconv("head", 1536);
    b.global_pool();
    b.fc("fc", 1000);
    b.finish(DnnModel::EfficientNetB3)
}

/// MobileNetV3-Large standard bneck table.
fn mobilenetv3_large() -> Dcg {
    let mut b = Builder::new(224, 224, 3);
    b.conv("stem", 16, 3, 2);
    // (kernel, expansion size, out channels, SE?, stride)
    let rows: [(u64, u64, u64, bool, u64); 15] = [
        (3, 16, 16, false, 1),
        (3, 64, 24, false, 2),
        (3, 72, 24, false, 1),
        (5, 72, 40, true, 2),
        (5, 120, 40, true, 1),
        (5, 120, 40, true, 1),
        (3, 240, 80, false, 2),
        (3, 200, 80, false, 1),
        (3, 184, 80, false, 1),
        (3, 184, 80, false, 1),
        (3, 480, 112, true, 1),
        (3, 672, 112, true, 1),
        (5, 672, 160, true, 2),
        (5, 960, 160, true, 1),
        (5, 960, 160, true, 1),
    ];
    for (i, &(k, exp, cout, se, s)) in rows.iter().enumerate() {
        if exp != b.c {
            b.pwconv(&format!("bneck{i}_expand"), exp);
        }
        b.dwconv(&format!("bneck{i}_dw"), k, s);
        if se {
            b.se(&format!("bneck{i}_se"), exp / 4);
        }
        b.pwconv(&format!("bneck{i}_project"), cout);
    }
    b.pwconv("conv_last", 960);
    b.global_pool();
    b.fc("fc1", 1280);
    b.fc("fc2", 1000);
    b.finish(DnnModel::MobileNetV3Large)
}

/// Inception-v3 flattened to a chain: branch convs are emitted
/// sequentially with correct input channels; the module output channel
/// count is set by the (virtual) concat.
fn inception_v3() -> Dcg {
    let mut b = Builder::new(299, 299, 3);
    // Stem.
    b.conv_p("stem1", 32, 3, 2, 0);
    b.conv_p("stem2", 32, 3, 1, 0);
    b.conv("stem3", 64, 3, 1);
    b.pool(3, 2, 0);
    b.conv_p("stem4", 80, 1, 1, 0);
    b.conv_p("stem5", 192, 3, 1, 0);
    b.pool(3, 2, 0);

    // Inception-A ×3 (output 256/288/288 channels).
    for (i, pool_proj) in [32u64, 64, 64].iter().enumerate() {
        let cin = b.c;
        let emit = |b: &mut Builder, name: String, cin: u64, cout: u64, k: u64| {
            b.c = cin;
            b.conv(&name, cout, k, 1);
        };
        emit(&mut b, format!("iA{i}_b1_1x1"), cin, 64, 1);
        emit(&mut b, format!("iA{i}_b2_1x1"), cin, 48, 1);
        emit(&mut b, format!("iA{i}_b2_5x5"), 48, 64, 5);
        emit(&mut b, format!("iA{i}_b3_1x1"), cin, 64, 1);
        emit(&mut b, format!("iA{i}_b3_3x3a"), 64, 96, 3);
        emit(&mut b, format!("iA{i}_b3_3x3b"), 96, 96, 3);
        emit(&mut b, format!("iA{i}_pool_proj"), cin, *pool_proj, 1);
        b.set_channels(64 + 64 + 96 + pool_proj);
    }

    // Reduction-A: 3x3 stride-2 convs; grid 35→17.
    {
        let cin = b.c;
        b.conv_p("rA_b1_3x3", 384, 3, 2, 0);
        let (h, w) = (b.h, b.w);
        b.c = cin;
        b.h = 35;
        b.w = 35;
        b.conv("rA_b2_1x1", 64, 1, 1);
        b.conv("rA_b2_3x3", 96, 3, 1);
        b.conv_p("rA_b2_3x3s2", 96, 3, 2, 0);
        b.h = h;
        b.w = w;
        b.set_channels(384 + 96 + cin); // concat with pooled input branch
    }

    // Inception-B ×4 with 7×1/1×7 factorized convs (c7 = 128/160/160/192).
    for (i, &c7) in [128u64, 160, 160, 192].iter().enumerate() {
        let cin = b.c;
        let emit = |b: &mut Builder, name: String, cin: u64, cout: u64, k: (u64, u64)| {
            b.c = cin;
            // Factorized kxl conv: model as conv with k*l footprint.
            let ho = b.h;
            let wo = b.w;
            let macs = ho * wo * k.0 * k.1 * b.c * cout;
            let weights = k.0 * k.1 * b.c * cout;
            b.c = cout;
            b.layers.push(Layer {
                weight_bits: weights * BITS,
                macs,
                out_bits: ho * wo * cout * BITS,
                name,
            });
        };
        emit(&mut b, format!("iB{i}_b1_1x1"), cin, 192, (1, 1));
        emit(&mut b, format!("iB{i}_b2_1x1"), cin, c7, (1, 1));
        emit(&mut b, format!("iB{i}_b2_1x7"), c7, c7, (1, 7));
        emit(&mut b, format!("iB{i}_b2_7x1"), c7, 192, (7, 1));
        emit(&mut b, format!("iB{i}_b3_1x1"), cin, c7, (1, 1));
        emit(&mut b, format!("iB{i}_b3_7x1a"), c7, c7, (7, 1));
        emit(&mut b, format!("iB{i}_b3_1x7a"), c7, c7, (1, 7));
        emit(&mut b, format!("iB{i}_b3_7x1b"), c7, c7, (7, 1));
        emit(&mut b, format!("iB{i}_b3_1x7b"), c7, 192, (1, 7));
        emit(&mut b, format!("iB{i}_pool_proj"), cin, 192, (1, 1));
        b.set_channels(192 * 4);
    }

    // Reduction-B: grid 17→8.
    {
        let cin = b.c;
        b.conv("rB_b1_1x1", 192, 1, 1);
        b.conv_p("rB_b1_3x3s2", 320, 3, 2, 0);
        let (h, w) = (b.h, b.w);
        b.c = cin;
        b.h = 17;
        b.w = 17;
        b.conv("rB_b2_1x1", 192, 1, 1);
        b.conv("rB_b2_1x7", 192, 7, 1); // factorized pair approximated
        b.conv_p("rB_b2_3x3s2", 192, 3, 2, 0);
        b.h = h;
        b.w = w;
        b.set_channels(320 + 192 + cin);
    }

    // Inception-C ×2 (output 2048).
    for i in 0..2 {
        let cin = b.c;
        let emit = |b: &mut Builder, name: String, cin: u64, cout: u64, k: u64| {
            b.c = cin;
            b.conv(&name, cout, k, 1);
        };
        emit(&mut b, format!("iC{i}_b1_1x1"), cin, 320, 1);
        emit(&mut b, format!("iC{i}_b2_1x1"), cin, 384, 1);
        emit(&mut b, format!("iC{i}_b2_1x3"), 384, 384, 3);
        emit(&mut b, format!("iC{i}_b2_3x1"), 384, 384, 3);
        emit(&mut b, format!("iC{i}_b3_1x1"), cin, 448, 1);
        emit(&mut b, format!("iC{i}_b3_3x3"), 448, 384, 3);
        emit(&mut b, format!("iC{i}_b3_1x3"), 384, 384, 3);
        emit(&mut b, format!("iC{i}_pool_proj"), cin, 192, 1);
        b.set_channels(320 + 768 + 768 + 192);
    }
    b.global_pool();
    b.fc("fc", 1000);
    b.finish(DnnModel::InceptionV3)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Published parameter counts (millions, INT8 → bits/8e6) within
    /// tolerance: the chain flattening must not distort model size.
    #[test]
    fn parameter_counts_near_published() {
        let zoo = ModelZoo::new();
        let expect: [(DnnModel, f64, f64); 6] = [
            (DnnModel::AlexNet, 61.0, 0.1),
            (DnnModel::ResNet18, 11.7, 0.15),
            (DnnModel::ResNet50, 25.6, 0.15),
            (DnnModel::EfficientNetB3, 12.0, 0.35),
            (DnnModel::MobileNetV3Large, 5.4, 0.3),
            (DnnModel::InceptionV3, 23.8, 0.25),
        ];
        for (m, millions, tol) in expect {
            let got = zoo.dcg(m).total_weight_bits() as f64 / 8.0 / 1e6;
            let rel = (got - millions).abs() / millions;
            assert!(rel < tol, "{m:?}: got {got:.1}M params, expected ~{millions}M");
        }
    }

    /// Published MAC counts per image (billions).
    #[test]
    fn mac_counts_near_published() {
        let zoo = ModelZoo::new();
        let expect: [(DnnModel, f64, f64); 6] = [
            (DnnModel::AlexNet, 0.72, 0.3),
            (DnnModel::ResNet18, 1.8, 0.25),
            (DnnModel::ResNet50, 4.1, 0.25),
            (DnnModel::EfficientNetB3, 1.8, 0.4),
            (DnnModel::MobileNetV3Large, 0.22, 0.4),
            (DnnModel::InceptionV3, 5.7, 0.35),
        ];
        for (m, giga, tol) in expect {
            let got = zoo.dcg(m).total_macs() as f64 / 1e9;
            let rel = (got - giga).abs() / giga;
            assert!(rel < tol, "{m:?}: got {got:.2}G MACs, expected ~{giga}G");
        }
    }

    #[test]
    fn layer_counts_reasonable() {
        let zoo = ModelZoo::new();
        assert_eq!(zoo.dcg(DnnModel::AlexNet).num_layers(), 8);
        let r18 = zoo.dcg(DnnModel::ResNet18).num_layers();
        assert!((18..=22).contains(&r18), "resnet18 layers {r18}");
        let r50 = zoo.dcg(DnnModel::ResNet50).num_layers();
        assert!((50..=56).contains(&r50), "resnet50 layers {r50}");
        let inc = zoo.dcg(DnnModel::InceptionV3).num_layers();
        assert!((80..=110).contains(&inc), "inception layers {inc}");
    }

    #[test]
    fn all_layers_positive() {
        let zoo = ModelZoo::new();
        for dcg in zoo.all_dcgs() {
            for l in &dcg.layers {
                assert!(l.weight_bits > 0, "{:?}/{}", dcg.model, l.name);
                assert!(l.macs > 0, "{:?}/{}", dcg.model, l.name);
                assert!(l.out_bits > 0, "{:?}/{}", dcg.model, l.name);
            }
        }
    }

    #[test]
    fn models_fit_in_paper_system_memory() {
        // §4.1 feasibility: every single model must fit the 78-chiplet
        // system's total crossbar memory (sum Table 3 capacities ≈ 87 MB).
        let zoo = ModelZoo::new();
        let total_bits: u64 = 25 * 9568 * 1024 + 28 * 9792 * 1024 + 10 * 19200 * 1024 + 15 * 2416 * 1024;
        for dcg in zoo.all_dcgs() {
            assert!(
                dcg.total_weight_bits() < total_bits,
                "{:?} does not fit: {} vs {}",
                dcg.model,
                dcg.total_weight_bits(),
                total_bits
            );
        }
    }

    #[test]
    fn zoo_normalization_stats() {
        let zoo = ModelZoo::new();
        assert!(zoo.max_layer_weight_bits() > 0);
        assert!(zoo.max_model_weight_bits() >= zoo.max_layer_weight_bits());
        assert!(zoo.max_layers() >= 80);
    }
}
