//! Workload streaming: the §5.2 evaluation mix (500 random
//! `(DNN, #images)` tuples, up to 20 000 images each), Poisson arrivals
//! from the host, and the FIFO job queue (depth 20, Table 4) the host
//! stalls against.

use super::zoo::{DnnModel, ModelZoo};
use super::{Dcg, Job};
use crate::util::rng::Rng;
use std::collections::VecDeque;

/// A mix of `(model, images)` tuples sampled like the paper's evaluation
/// workload.
#[derive(Clone, Debug)]
pub struct WorkloadMix {
    pub entries: Vec<(DnnModel, u64)>,
}

impl WorkloadMix {
    /// The paper's mix: 500 tuples, model uniform over the zoo, image
    /// count uniform up to `max_images` (paper: 20 000).
    pub fn paper(rng: &mut Rng, max_images: u64) -> WorkloadMix {
        Self::random(rng, 500, max_images)
    }

    pub fn random(rng: &mut Rng, count: usize, max_images: u64) -> WorkloadMix {
        let models = DnnModel::all();
        let entries = (0..count)
            .map(|_| {
                let m = *rng.choose(&models);
                // At least 100 images so every job has a meaningful stream.
                let images = rng.range_usize(100, max_images as usize) as u64;
                (m, images)
            })
            .collect();
        WorkloadMix { entries }
    }
}

/// Poisson job source: exponential inter-arrival times at `rate_jobs_s`,
/// drawing `(model, images)` round-robin from the mix.
#[derive(Clone, Debug)]
pub struct TrafficGen {
    mix: WorkloadMix,
    zoo: ModelZoo,
    rate_jobs_s: f64,
    next_arrival_s: f64,
    next_index: usize,
    next_id: u64,
    rng: Rng,
    /// Stop emitting after this many jobs (None = endless stream).
    limit: Option<usize>,
}

impl TrafficGen {
    pub fn new(mix: WorkloadMix, zoo: ModelZoo, rate_jobs_s: f64, mut rng: Rng) -> TrafficGen {
        let first = rng.exp(rate_jobs_s);
        TrafficGen {
            mix,
            zoo,
            rate_jobs_s,
            next_arrival_s: first,
            next_index: 0,
            next_id: 0,
            rng,
            limit: None,
        }
    }

    pub fn with_limit(mut self, limit: usize) -> TrafficGen {
        self.set_limit(limit);
        self
    }

    /// Cap the stream in place — no clone of the generator (or its
    /// workload mix) required.
    pub fn set_limit(&mut self, limit: usize) {
        self.limit = Some(limit);
    }

    pub fn rate(&self) -> f64 {
        self.rate_jobs_s
    }

    /// Next arrival time, or None if the stream is exhausted.
    pub fn peek_arrival(&self) -> Option<f64> {
        match self.limit {
            Some(l) if self.next_index >= l => None,
            _ => Some(self.next_arrival_s),
        }
    }

    /// Pop all jobs arriving up to (and including) `now`.
    pub fn arrivals_until(&mut self, now: f64) -> Vec<Job> {
        let mut out = Vec::new();
        while let Some(t) = self.peek_arrival() {
            if t > now {
                break;
            }
            let (model, images) = self.mix.entries[self.next_index % self.mix.entries.len()];
            let dcg: Dcg = self.zoo.dcg(model);
            out.push(Job { id: self.next_id, dcg, images, arrival_s: t });
            self.next_id += 1;
            self.next_index += 1;
            self.next_arrival_s = t + self.rng.exp(self.rate_jobs_s);
        }
        out
    }
}

/// FIFO job queue with bounded depth (Table 4: 20). The host stalls when
/// the queue is full; we track rejected-push counts as "host stall" events
/// (the job is retried by the caller).
#[derive(Clone, Debug)]
pub struct JobQueue {
    q: VecDeque<Job>,
    capacity: usize,
    pub total_enqueued: u64,
    pub host_stalls: u64,
}

impl JobQueue {
    pub fn new(capacity: usize) -> JobQueue {
        JobQueue { q: VecDeque::new(), capacity, total_enqueued: 0, host_stalls: 0 }
    }

    pub fn push(&mut self, job: Job) -> Result<(), Job> {
        if self.q.len() >= self.capacity {
            self.host_stalls += 1;
            return Err(job);
        }
        self.total_enqueued += 1;
        self.q.push_back(job);
        Ok(())
    }

    pub fn front(&self) -> Option<&Job> {
        self.q.front()
    }
    pub fn pop(&mut self) -> Option<Job> {
        self.q.pop_front()
    }
    pub fn len(&self) -> usize {
        self.q.len()
    }
    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }
    pub fn is_full(&self) -> bool {
        self.q.len() >= self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_respects_bounds() {
        let mut rng = Rng::new(1);
        let mix = WorkloadMix::paper(&mut rng, 20_000);
        assert_eq!(mix.entries.len(), 500);
        for &(_, images) in &mix.entries {
            assert!((100..=20_000).contains(&images));
        }
        // All six models should appear in 500 draws.
        for m in DnnModel::all() {
            assert!(mix.entries.iter().any(|&(x, _)| x == m), "{m:?} missing");
        }
    }

    #[test]
    fn poisson_rate_approximately_correct() {
        let mut rng = Rng::new(2);
        let mix = WorkloadMix::random(&mut rng, 50, 1000);
        let zoo = ModelZoo::new();
        let mut gen = TrafficGen::new(mix, zoo, 2.0, Rng::new(3));
        let jobs = gen.arrivals_until(100.0);
        // E[#arrivals in 100 s at 2/s] = 200, σ ≈ 14.
        assert!((150..260).contains(&jobs.len()), "got {}", jobs.len());
        // Arrival times strictly increasing.
        for w in jobs.windows(2) {
            assert!(w[0].arrival_s < w[1].arrival_s);
        }
    }

    #[test]
    fn queue_capacity_enforced() {
        let mut rng = Rng::new(4);
        let mix = WorkloadMix::random(&mut rng, 10, 500);
        let zoo = ModelZoo::new();
        let mut gen = TrafficGen::new(mix, zoo, 100.0, Rng::new(5));
        let jobs = gen.arrivals_until(1.0);
        let mut q = JobQueue::new(3);
        let mut rejected = 0;
        for j in jobs {
            if q.push(j).is_err() {
                rejected += 1;
            }
        }
        assert_eq!(q.len(), 3);
        assert!(rejected > 0);
        assert_eq!(q.host_stalls, rejected);
    }

    #[test]
    fn limited_stream_ends() {
        let mut rng = Rng::new(6);
        let mix = WorkloadMix::random(&mut rng, 10, 500);
        let zoo = ModelZoo::new();
        let mut gen = TrafficGen::new(mix, zoo, 10.0, Rng::new(7)).with_limit(5);
        let jobs = gen.arrivals_until(1e9);
        assert_eq!(jobs.len(), 5);
        assert!(gen.peek_arrival().is_none());
    }
}
