//! # THERMOS — Thermally-Aware Multi-Objective Scheduling of AI Workloads
//! # on Heterogeneous Multi-Chiplet PIM Architectures
//!
//! Production-quality reproduction of the THERMOS paper (Kanani et al.,
//! 2025) as a three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the coordinator: heterogeneous chiplet
//!   system model, NoI, PIM compute model, RC thermal model, streaming
//!   multi-workload simulator, the two-level THERMOS scheduler, the
//!   baseline schedulers (Simba / Big-Little / RELMAS), and a PPO trainer
//!   that drives the AOT-compiled update graph.
//! * **Layer 2 (python/compile, build-time)** — the jax actor-critic and
//!   PPO update, lowered once to HLO text artifacts.
//! * **Layer 1 (python/compile/kernels, build-time)** — Pallas kernels for
//!   the differentiable-decision-tree policy forward pass and the MLP
//!   critic, verified against pure-jnp oracles.
//!
//! Python never runs at simulation/serving time: the rust binary loads
//! `artifacts/*.hlo.txt` through the PJRT C API (`xla` crate) and is
//! self-contained after `make artifacts`.

pub mod arch;
pub mod cluster;
pub mod experiments;
pub mod fault;
pub mod noi;
pub mod pim;
pub mod rl;
pub mod runtime;
pub mod thermal;
pub mod util;
pub mod sched;
pub mod serve;
pub mod sim;
pub mod workload;
