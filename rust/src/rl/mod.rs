//! MORL-PPO training driver (§4.3): rust collects trajectories from the
//! simulator with the native policy evaluators, computes vector GAE, and
//! drives the AOT-compiled `ppo_update_*` artifacts (forward + backward +
//! Adam fused inside XLA) through PJRT. Python never runs during training.

pub mod gae;
pub mod relmas_trainer;
pub mod trainer;

pub use gae::{gae, normalize, Transition};
pub use trainer::{TrainConfig, TrainLogEntry, Trainer};

/// Reward normalization scales (DESIGN.md §4): per-image execution time
/// and energy are O(1e-3 s) / O(1e-3 J) on this system; dividing by these
/// puts both objectives on comparable O(1) footing (§4.3.3 "normalize and
/// balance the reward values").
pub const TIME_SCALE: f64 = 1.0e-3;
pub const ENERGY_SCALE: f64 = 1.0e-3;

/// Primary reward (deterministic execution, assigned at mapping; §4.3.3):
/// negative normalized per-image execution time and energy.
pub fn primary_reward(ideal_exec_s: f64, ideal_energy_j: f64, images: u64) -> [f32; 2] {
    let img = images.max(1) as f64;
    [
        (-(ideal_exec_s / img) / TIME_SCALE) as f32,
        (-(ideal_energy_j / img) / ENERGY_SCALE) as f32,
    ]
}

/// Secondary reward (non-deterministic throttling effects, assigned after
/// execution; §4.3.3): negative normalized stall time and stall leakage.
pub fn secondary_reward(stall_s: f64, stall_leak_j: f64, images: u64) -> [f32; 2] {
    let img = images.max(1) as f64;
    [
        (-(stall_s / img) / TIME_SCALE) as f32,
        (-(stall_leak_j / img) / ENERGY_SCALE) as f32,
    ]
}

/// Build fixed-size minibatch index sets, padding the tail by resampling
/// (the AOT update graph has a baked batch dimension).
pub fn minibatch_indices(
    n: usize,
    batch: usize,
    rng: &mut crate::util::rng::Rng,
) -> Vec<Vec<usize>> {
    assert!(n > 0);
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut out = Vec::new();
    for chunk in order.chunks(batch) {
        let mut idx = chunk.to_vec();
        while idx.len() < batch {
            idx.push(order[rng.below(n)]);
        }
        out.push(idx);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn rewards_are_negative_and_scaled() {
        let p = primary_reward(10.0, 5.0, 10_000);
        assert!(p[0] < 0.0 && p[1] < 0.0);
        // 10 s / 10k images = 1 ms/img => -1.0 after scaling.
        assert!((p[0] + 1.0).abs() < 1e-6);
        let s = secondary_reward(0.0, 0.0, 100);
        assert_eq!(s, [0.0, 0.0]);
    }

    #[test]
    fn minibatches_cover_all_and_are_fixed_size() {
        let mut rng = Rng::new(1);
        let batches = minibatch_indices(700, 256, &mut rng);
        assert_eq!(batches.len(), 3);
        let mut seen = vec![false; 700];
        for b in &batches {
            assert_eq!(b.len(), 256);
            for &i in b {
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "every transition appears at least once");
    }
}
