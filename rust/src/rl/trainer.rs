//! The THERMOS MORL trainer: three parallel preference environments
//! (ω = [1,0], [0.5,0.5], [0,1]) roll out the *same* policy parameters,
//! their vector-reward trajectories are pooled, and a single
//! preference-conditioned actor-critic is updated through the AOT
//! `ppo_update_thermos` artifact (§4.3.2, Fig. 3b).

#[cfg(feature = "pjrt")]
use super::{gae, minibatch_indices, normalize};
use super::{primary_reward, secondary_reward, Transition};
use crate::arch::Arch;
use crate::noi::NoiTopology;
#[cfg(feature = "pjrt")]
use crate::runtime::{F32Tensor, Runtime};
use crate::sched::policy::{NativeDdt, NativeMlp};
use crate::sched::state::{StateEncoder, NUM_CLUSTERS, STATE_DIM};
use crate::sched::thermos::{Preference, ThermosSched, PREF_BALANCED, PREF_ENERGY, PREF_EXEC_TIME};
use crate::sim::{SimConfig, Simulator};
use crate::util::pool::WorkPool;
use crate::util::rng::Rng;
use crate::workload::ModelZoo;
#[cfg(feature = "pjrt")]
use anyhow::Result;
use std::cell::RefCell;
use std::collections::HashMap;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub noi: NoiTopology,
    /// Episodes; each runs the three preference environments.
    pub episodes: usize,
    pub jobs_per_episode: usize,
    pub max_images: u64,
    /// PPO epochs over each episode's pooled transitions.
    pub epochs: usize,
    pub gamma: f32,
    pub lambda: f32,
    pub seed: u64,
    /// Wall-clock cap per episode (sim seconds).
    pub episode_max_s: f64,
    /// Admit-rate range sampled per episode ("randomly selected target
    /// throughput", §4.3.2).
    pub rate_range: (f64, f64),
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            noi: NoiTopology::Mesh,
            episodes: 40,
            jobs_per_episode: 60,
            max_images: 4_000,
            epochs: 4,
            gamma: 0.95,
            lambda: 0.95,
            seed: 7,
            episode_max_s: 400.0,
            rate_range: (0.8, 6.0),
        }
    }
}

/// One policy-update-cycle log row (Fig. 6 feeds on `value_loss`).
#[derive(Clone, Debug)]
pub struct TrainLogEntry {
    pub update: usize,
    pub env_steps: usize,
    pub policy_loss: f32,
    pub value_loss: f32,
    pub entropy: f32,
    /// Mean undiscounted episode reward per preference env
    /// ([exec, balanced, energy]).
    pub episode_reward: [f32; 3],
}

#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
pub struct Trainer {
    pub cfg: TrainConfig,
    pub arch: Arch,
    #[allow(dead_code)]
    zoo: ModelZoo,
    encoder: StateEncoder,
    /// Flat [θ | φ].
    pub params: Vec<f32>,
    adam_m: Vec<f32>,
    adam_v: Vec<f32>,
    adam_t: f32,
    pub log: Vec<TrainLogEntry>,
    pub total_env_steps: usize,
    rng: Rng,
}

pub const PREFS: [Preference; 3] = [PREF_EXEC_TIME, PREF_BALANCED, PREF_ENERGY];

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Trainer {
        let arch = Arch::paper_heterogeneous(cfg.noi);
        let zoo = ModelZoo::new();
        let encoder = StateEncoder::new(&arch, &zoo, cfg.max_images);
        let mut rng = Rng::new(cfg.seed);
        let ddt = NativeDdt::init(STATE_DIM, NUM_CLUSTERS, &mut rng);
        let critic = NativeMlp::init(vec![STATE_DIM, 64, 64, 64, 2], &mut rng);
        let mut params = ddt.theta;
        params.extend_from_slice(&critic.params);
        let n = params.len();
        Trainer {
            cfg,
            arch,
            zoo,
            encoder,
            params,
            adam_m: vec![0.0; n],
            adam_v: vec![0.0; n],
            adam_t: 0.0,
            log: Vec::new(),
            total_env_steps: 0,
            rng,
        }
    }

    fn theta_len(&self) -> usize {
        crate::sched::policy::ddt_theta_len(STATE_DIM, NUM_CLUSTERS)
    }

    fn native_policy(&self) -> NativeDdt {
        NativeDdt::new(STATE_DIM, NUM_CLUSTERS, self.params[..self.theta_len()].to_vec())
    }

    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    fn native_critic(&self) -> NativeMlp {
        NativeMlp::new(vec![STATE_DIM, 64, 64, 64, 2], self.params[self.theta_len()..].to_vec())
    }

    /// Roll out one environment with preference ω; returns transitions
    /// (vector rewards attached per §4.3.3) and the mean per-job reward.
    pub fn rollout(&self, omega: Preference, seed: u64, admit_rate: f64) -> (Vec<Transition>, f32) {
        let sched = ThermosSched::new(
            self.arch.clone(),
            self.encoder.clone(),
            self.native_policy(),
            omega,
        )
        .sampling(Rng::new(seed ^ 0x5eed))
        .recording();

        let cfg = SimConfig {
            admit_rate,
            warmup_s: 0.0,
            duration_s: self.cfg.episode_max_s,
            max_images: self.cfg.max_images,
            mix_jobs: self.cfg.jobs_per_episode,
            seed,
            ..SimConfig::default()
        };
        // Primary rewards become known at mapping; secondary at completion.
        // Stack-local cells declared before `sim`, borrowed by its
        // callbacks — the rollout owns everything it touches, which is
        // what makes `&self` rollouts Send-able onto the work pool.
        let mapped: RefCell<HashMap<u64, [f32; 2]>> = RefCell::new(HashMap::new());
        let secondary: RefCell<HashMap<u64, [f32; 2]>> = RefCell::new(HashMap::new());
        let mut sim = Simulator::new(&self.arch, sched, cfg);
        sim.limit_jobs(self.cfg.jobs_per_episode);
        sim.on_mapped = Some(Box::new(|job, profile| {
            mapped.borrow_mut().insert(
                job.id,
                primary_reward(
                    profile.ideal_exec_s(job.images),
                    profile.ideal_dynamic_j(job.images),
                    job.images,
                ),
            );
        }));
        sim.on_completed = Some(Box::new(|stats| {
            secondary
                .borrow_mut()
                .insert(stats.id, secondary_reward(stats.stall_s, stats.stall_leak_j, stats.images));
        }));
        let (_result, mut sched) = sim.run_drain(self.cfg.episode_max_s);
        let decisions = sched.take_decisions();

        // Last decision index per job.
        let mut last_of_job: HashMap<u64, usize> = HashMap::new();
        for (i, d) in decisions.iter().enumerate() {
            last_of_job.insert(d.job_id, i);
        }
        let mapped = mapped.into_inner();
        let secondary = secondary.into_inner();
        let mut reward_sum = 0.0f32;
        let mut reward_jobs = 0usize;
        let transitions: Vec<Transition> = decisions
            .into_iter()
            .enumerate()
            .map(|(i, d)| {
                let mut reward = [0.0f32; 2];
                if last_of_job.get(&d.job_id) == Some(&i) {
                    if let Some(p) = mapped.get(&d.job_id) {
                        reward[0] += p[0];
                        reward[1] += p[1];
                    }
                    if let Some(s) = secondary.get(&d.job_id) {
                        reward[0] += s[0];
                        reward[1] += s[1];
                    }
                    reward_sum += omega[0] * reward[0] + omega[1] * reward[1];
                    reward_jobs += 1;
                }
                Transition {
                    state: d.state,
                    mask: d.mask.to_vec(),
                    action: d.action,
                    logp: d.logp,
                    reward,
                }
            })
            .collect();
        let mean_reward = if reward_jobs > 0 { reward_sum / reward_jobs as f32 } else { 0.0 };
        (transitions, mean_reward)
    }

    /// The three preference-environment rollouts of one episode, executed
    /// concurrently on a work pool and returned in fixed (exec, balanced,
    /// energy) order. Each rollout clones the policy and is seeded
    /// `base_seed ^ (i + 1)` — the same per-environment scheme the serial
    /// path used — so the pooled result is identical at any pool width.
    pub fn episode_rollouts(
        &self,
        base_seed: u64,
        admit_rate: f64,
        pool: &WorkPool,
    ) -> Vec<(Vec<Transition>, f32, Preference)> {
        pool.run(PREFS.len(), |i| {
            let omega = PREFS[i];
            let (t, r) = self.rollout(omega, base_seed ^ (i as u64 + 1), admit_rate);
            (t, r, omega)
        })
    }

    /// One episode: the three preference environments on the work pool
    /// (§4.3.2 "multi-threading to run all three preferences in parallel"),
    /// then PPO epochs through the AOT update artifact.
    #[cfg(feature = "pjrt")]
    pub fn episode(&mut self, runtime: &mut Runtime, ep: usize) -> Result<()> {
        let admit_rate = self.rng.range_f64(self.cfg.rate_range.0, self.cfg.rate_range.1);
        let base_seed = self.rng.next_u64();
        let rollouts = self.episode_rollouts(base_seed, admit_rate, &WorkPool::global());

        // Per-env GAE with the current critic, scalarized by each env's ω.
        let critic = self.native_critic();
        let mut pool: Vec<(Transition, f32, [f32; 2])> = Vec::new(); // (tr, adv_scalar, ret)
        let mut episode_reward = [0.0f32; 3];
        for (ei, (transitions, mean_r, omega)) in rollouts.into_iter().enumerate() {
            episode_reward[ei] = mean_r;
            if transitions.is_empty() {
                continue;
            }
            let values: Vec<[f32; 2]> = transitions
                .iter()
                .map(|t| {
                    let v = critic.forward(&t.state);
                    [v[0], v[1]]
                })
                .collect();
            let rewards: Vec<[f32; 2]> = transitions.iter().map(|t| t.reward).collect();
            let (adv, ret) = gae(&rewards, &values, self.cfg.gamma, self.cfg.lambda);
            for ((tr, a), r) in transitions.into_iter().zip(adv).zip(ret) {
                let scalar = omega[0] * a[0] + omega[1] * a[1];
                pool.push((tr, scalar, r));
            }
        }
        if pool.is_empty() {
            return Ok(());
        }
        self.total_env_steps += pool.len();

        // Advantage normalization across the pooled batch.
        let mut advs: Vec<f32> = pool.iter().map(|p| p.1).collect();
        normalize(&mut advs);
        for (p, a) in pool.iter_mut().zip(&advs) {
            p.1 = *a;
        }

        // PPO epochs through the AOT update graph.
        let batch = runtime.abi.update_batch;
        let mut last = (0.0f32, 0.0f32, 0.0f32);
        for _ in 0..self.cfg.epochs {
            let batches = minibatch_indices(pool.len(), batch, &mut self.rng);
            for idx in batches {
                let mut x = Vec::with_capacity(batch * STATE_DIM);
                let mut a_onehot = vec![0.0f32; batch * NUM_CLUSTERS];
                let mut mask = vec![0.0f32; batch * NUM_CLUSTERS];
                let mut logp_old = Vec::with_capacity(batch);
                let mut adv = Vec::with_capacity(batch);
                let mut ret = Vec::with_capacity(batch * 2);
                for (row, &i) in idx.iter().enumerate() {
                    let (tr, a, r) = &pool[i];
                    x.extend_from_slice(&tr.state);
                    a_onehot[row * NUM_CLUSTERS + tr.action] = 1.0;
                    for (k, &mv) in tr.mask.iter().enumerate() {
                        mask[row * NUM_CLUSTERS + k] = if mv { 1.0 } else { 0.0 };
                    }
                    logp_old.push(tr.logp);
                    adv.push(*a);
                    ret.extend_from_slice(r);
                }
                let art = runtime.artifact("ppo_update_thermos")?;
                let out = art.run_f32(&[
                    F32Tensor::vec(self.params.clone()),
                    F32Tensor::vec(self.adam_m.clone()),
                    F32Tensor::vec(self.adam_v.clone()),
                    F32Tensor::scalar1(self.adam_t),
                    F32Tensor::mat(x, batch, STATE_DIM),
                    F32Tensor::mat(a_onehot, batch, NUM_CLUSTERS),
                    F32Tensor::mat(mask, batch, NUM_CLUSTERS),
                    F32Tensor::vec(logp_old),
                    F32Tensor::vec(adv),
                    F32Tensor::mat(ret, batch, 2),
                ])?;
                self.params = out[0].clone();
                self.adam_m = out[1].clone();
                self.adam_v = out[2].clone();
                self.adam_t = out[3][0];
                last = (out[4][0], out[5][0], out[6][0]);
            }
        }
        self.log.push(TrainLogEntry {
            update: ep,
            env_steps: self.total_env_steps,
            policy_loss: last.0,
            value_loss: last.1,
            entropy: last.2,
            episode_reward,
        });
        Ok(())
    }

    /// Full training run; returns the trained flat parameters.
    #[cfg(feature = "pjrt")]
    pub fn train(&mut self, runtime: &mut Runtime) -> Result<Vec<f32>> {
        for ep in 0..self.cfg.episodes {
            self.episode(runtime, ep)?;
            if let Some(e) = self.log.last() {
                eprintln!(
                    "[train {}] ep {ep:>3} steps {:>7} pol {:+.4} val {:.4} ent {:.3} R[exec {:+.3} bal {:+.3} energy {:+.3}]",
                    self.cfg.noi.name(),
                    e.env_steps,
                    e.policy_loss,
                    e.value_loss,
                    e.entropy,
                    e.episode_reward[0],
                    e.episode_reward[1],
                    e.episode_reward[2],
                );
            }
        }
        Ok(self.params.clone())
    }

    /// Write the Fig. 6 value-loss curve as CSV.
    pub fn write_log_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut s = String::from(
            "update,env_steps,policy_loss,value_loss,entropy,r_exec,r_balanced,r_energy\n",
        );
        for e in &self.log {
            s.push_str(&format!(
                "{},{},{},{},{},{},{},{}\n",
                e.update,
                e.env_steps,
                e.policy_loss,
                e.value_loss,
                e.entropy,
                e.episode_reward[0],
                e.episode_reward[1],
                e.episode_reward[2]
            ));
        }
        std::fs::write(path, s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rollout_produces_consistent_transitions() {
        let cfg = TrainConfig {
            jobs_per_episode: 6,
            max_images: 300,
            episode_max_s: 120.0,
            ..TrainConfig::default()
        };
        let tr = Trainer::new(cfg);
        let (ts, _r) = tr.rollout(PREF_BALANCED, 3, 2.0);
        assert!(!ts.is_empty());
        // Rewards are attached only at job-final decisions and are ≤ 0.
        let nonzero = ts.iter().filter(|t| t.reward != [0.0, 0.0]).count();
        assert!(nonzero >= 1);
        assert!(nonzero <= 6, "at most one rewarded step per job");
        for t in &ts {
            assert_eq!(t.state.len(), STATE_DIM);
            assert!(t.mask[t.action], "recorded action must be valid");
            assert!(t.reward[0] <= 0.0 && t.reward[1] <= 0.0);
            // ω embedded in the state.
            assert_eq!(t.state[20], 0.5);
        }
    }

    #[test]
    fn preference_environments_differ_only_in_omega() {
        let cfg = TrainConfig {
            jobs_per_episode: 3,
            max_images: 200,
            episode_max_s: 60.0,
            ..TrainConfig::default()
        };
        let tr = Trainer::new(cfg);
        let (t_exec, _) = tr.rollout(PREF_EXEC_TIME, 9, 1.5);
        let (t_energy, _) = tr.rollout(PREF_ENERGY, 9, 1.5);
        assert_eq!(t_exec[0].state[20], 1.0);
        assert_eq!(t_energy[0].state[20], 0.0);
    }

    #[test]
    fn episode_rollouts_identical_across_pool_widths() {
        let cfg = TrainConfig {
            jobs_per_episode: 4,
            max_images: 200,
            episode_max_s: 80.0,
            ..TrainConfig::default()
        };
        let tr = Trainer::new(cfg);
        let serial = tr.episode_rollouts(0xABCD, 2.0, &WorkPool::new(1));
        let pooled = tr.episode_rollouts(0xABCD, 2.0, &WorkPool::new(3));
        assert_eq!(serial.len(), PREFS.len());
        // Transition has no PartialEq; the Debug form captures every field.
        assert_eq!(format!("{serial:?}"), format!("{pooled:?}"));
    }
}
