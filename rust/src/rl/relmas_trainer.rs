//! PPO trainer for the RELMAS baseline [8]: identical training rig, but a
//! flat MLP policy over individual chiplets and a scalar (balanced)
//! objective — RELMAS is single-objective, so its reward is the balanced
//! scalarization. Trained through the AOT `ppo_update_relmas` artifact.

#[cfg(feature = "pjrt")]
use super::{gae, minibatch_indices, normalize};
use super::{primary_reward, secondary_reward, Transition};
use crate::arch::Arch;
#[cfg(feature = "pjrt")]
use crate::runtime::{F32Tensor, Runtime};
use crate::sched::policy::{mlp_param_len, NativeMlp};
use crate::sched::relmas::RelmasSched;
use crate::sched::state::{relmas_obs_dim, StateEncoder};
use crate::sim::{SimConfig, Simulator};
use crate::util::rng::Rng;
use crate::workload::ModelZoo;
#[cfg(feature = "pjrt")]
use anyhow::Result;
use std::cell::RefCell;
use std::collections::HashMap;

#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
pub struct RelmasTrainer {
    pub cfg: super::trainer::TrainConfig,
    pub arch: Arch,
    encoder: StateEncoder,
    actor_dims: Vec<usize>,
    critic_dims: Vec<usize>,
    /// Flat [θ_R | φ_R].
    pub params: Vec<f32>,
    adam_m: Vec<f32>,
    adam_v: Vec<f32>,
    adam_t: f32,
    pub log: Vec<(usize, f32, f32)>, // (env_steps, value_loss, mean_reward)
    pub total_env_steps: usize,
    rng: Rng,
}

impl RelmasTrainer {
    pub fn new(cfg: super::trainer::TrainConfig) -> RelmasTrainer {
        let arch = Arch::paper_heterogeneous(cfg.noi);
        let zoo = ModelZoo::new();
        let encoder = StateEncoder::new(&arch, &zoo, cfg.max_images);
        let n = arch.num_chiplets();
        let obs = relmas_obs_dim(n);
        let actor_dims = vec![obs, 128, 128, n];
        let critic_dims = vec![obs, 128, 128, 1];
        let mut rng = Rng::new(cfg.seed ^ 0x7e1u64);
        let actor = NativeMlp::init(actor_dims.clone(), &mut rng);
        let critic = NativeMlp::init(critic_dims.clone(), &mut rng);
        let mut params = actor.params;
        params.extend_from_slice(&critic.params);
        let plen = params.len();
        RelmasTrainer {
            cfg,
            arch,
            encoder,
            actor_dims,
            critic_dims,
            params,
            adam_m: vec![0.0; plen],
            adam_v: vec![0.0; plen],
            adam_t: 0.0,
            log: Vec::new(),
            total_env_steps: 0,
            rng,
        }
    }

    fn theta_len(&self) -> usize {
        mlp_param_len(&self.actor_dims)
    }

    pub fn native_actor(&self) -> NativeMlp {
        NativeMlp::new(self.actor_dims.clone(), self.params[..self.theta_len()].to_vec())
    }

    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    fn native_critic(&self) -> NativeMlp {
        NativeMlp::new(self.critic_dims.clone(), self.params[self.theta_len()..].to_vec())
    }

    fn rollout(&self, seed: u64, admit_rate: f64) -> (Vec<Transition>, f32) {
        let mut sched = RelmasSched::new(self.arch.clone(), self.encoder.clone(), self.native_actor())
            .sampling(Rng::new(seed ^ 0xbeef));
        sched.record = true;
        let cfg = SimConfig {
            admit_rate,
            warmup_s: 0.0,
            duration_s: self.cfg.episode_max_s,
            max_images: self.cfg.max_images,
            mix_jobs: self.cfg.jobs_per_episode,
            seed,
            ..SimConfig::default()
        };
        // Stack-local cells borrowed by the sim callbacks (see
        // `Trainer::rollout`) — no shared-ownership plumbing.
        let mapped: RefCell<HashMap<u64, [f32; 2]>> = RefCell::new(HashMap::new());
        let secondary: RefCell<HashMap<u64, [f32; 2]>> = RefCell::new(HashMap::new());
        let mut sim = Simulator::new(&self.arch, sched, cfg);
        sim.limit_jobs(self.cfg.jobs_per_episode);
        sim.on_mapped = Some(Box::new(|job, profile| {
            mapped.borrow_mut().insert(
                job.id,
                primary_reward(
                    profile.ideal_exec_s(job.images),
                    profile.ideal_dynamic_j(job.images),
                    job.images,
                ),
            );
        }));
        sim.on_completed = Some(Box::new(|stats| {
            secondary.borrow_mut().insert(
                stats.id,
                secondary_reward(stats.stall_s, stats.stall_leak_j, stats.images),
            );
        }));
        let (_res, mut sched) = sim.run_drain(self.cfg.episode_max_s);
        let decisions = sched.take_decisions();
        let mut last_of_job: HashMap<u64, usize> = HashMap::new();
        for (i, d) in decisions.iter().enumerate() {
            last_of_job.insert(d.job_id, i);
        }
        let mapped = mapped.into_inner();
        let secondary = secondary.into_inner();
        let mut rsum = 0.0f32;
        let mut rjobs = 0usize;
        let transitions: Vec<Transition> = decisions
            .into_iter()
            .enumerate()
            .map(|(i, d)| {
                // Balanced scalar reward in channel 0; channel 1 unused.
                let mut reward = [0.0f32; 2];
                if last_of_job.get(&d.job_id) == Some(&i) {
                    let p = mapped.get(&d.job_id).copied().unwrap_or([0.0, 0.0]);
                    let s = secondary.get(&d.job_id).copied().unwrap_or([0.0, 0.0]);
                    reward[0] = 0.5 * (p[0] + s[0]) + 0.5 * (p[1] + s[1]);
                    rsum += reward[0];
                    rjobs += 1;
                }
                Transition {
                    state: d.obs,
                    mask: d.mask,
                    action: d.action,
                    logp: d.logp,
                    reward,
                }
            })
            .collect();
        (transitions, if rjobs > 0 { rsum / rjobs as f32 } else { 0.0 })
    }

    #[cfg(feature = "pjrt")]
    pub fn train(&mut self, runtime: &mut Runtime) -> Result<Vec<f32>> {
        let n_chiplets = self.arch.num_chiplets();
        let obs_dim = relmas_obs_dim(n_chiplets);
        let batch = runtime.abi.update_batch;
        for ep in 0..self.cfg.episodes {
            let admit = self.rng.range_f64(self.cfg.rate_range.0, self.cfg.rate_range.1);
            let seed = self.rng.next_u64();
            let (transitions, mean_r) = self.rollout(seed, admit);
            if transitions.is_empty() {
                continue;
            }
            self.total_env_steps += transitions.len();
            let critic = self.native_critic();
            let values: Vec<[f32; 2]> = transitions
                .iter()
                .map(|t| {
                    let v = critic.forward(&t.state);
                    [v[0], 0.0]
                })
                .collect();
            let rewards: Vec<[f32; 2]> = transitions.iter().map(|t| t.reward).collect();
            let (adv2, ret2) = gae(&rewards, &values, self.cfg.gamma, self.cfg.lambda);
            let mut adv: Vec<f32> = adv2.iter().map(|a| a[0]).collect();
            normalize(&mut adv);
            let mut last_vl = 0.0f32;
            for _ in 0..self.cfg.epochs {
                for idx in minibatch_indices(transitions.len(), batch, &mut self.rng) {
                    let mut x = Vec::with_capacity(batch * obs_dim);
                    let mut a_onehot = vec![0.0f32; batch * n_chiplets];
                    let mut mask = vec![0.0f32; batch * n_chiplets];
                    let mut logp_old = Vec::with_capacity(batch);
                    let mut advb = Vec::with_capacity(batch);
                    let mut ret = Vec::with_capacity(batch);
                    for (row, &i) in idx.iter().enumerate() {
                        let t = &transitions[i];
                        x.extend_from_slice(&t.state);
                        a_onehot[row * n_chiplets + t.action] = 1.0;
                        for (k, &mv) in t.mask.iter().enumerate() {
                            mask[row * n_chiplets + k] = if mv { 1.0 } else { 0.0 };
                        }
                        logp_old.push(t.logp);
                        advb.push(adv[i]);
                        ret.push(ret2[i][0]);
                    }
                    let art = runtime.artifact("ppo_update_relmas")?;
                    let out = art.run_f32(&[
                        F32Tensor::vec(self.params.clone()),
                        F32Tensor::vec(self.adam_m.clone()),
                        F32Tensor::vec(self.adam_v.clone()),
                        F32Tensor::scalar1(self.adam_t),
                        F32Tensor::mat(x, batch, obs_dim),
                        F32Tensor::mat(a_onehot, batch, n_chiplets),
                        F32Tensor::mat(mask, batch, n_chiplets),
                        F32Tensor::vec(logp_old),
                        F32Tensor::vec(advb),
                        F32Tensor::mat(ret, batch, 1),
                    ])?;
                    self.params = out[0].clone();
                    self.adam_m = out[1].clone();
                    self.adam_v = out[2].clone();
                    self.adam_t = out[3][0];
                    last_vl = out[5][0];
                }
            }
            self.log.push((self.total_env_steps, last_vl, mean_r));
            eprintln!(
                "[relmas {}] ep {ep:>3} steps {:>7} val {:.4} R {:+.3}",
                self.cfg.noi.name(),
                self.total_env_steps,
                last_vl,
                mean_r
            );
        }
        Ok(self.params.clone())
    }
}
