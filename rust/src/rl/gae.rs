//! Vectorized generalized advantage estimation (Eq. 3 with TD(λ)).
//!
//! Rewards and values are 2-vectors (execution time, energy); the
//! advantage is computed per objective and scalarized with ω only at the
//! loss (Eq. 4), matching the paper's "reward vectors, not a scalar
//! weighted sum" training design.

/// One transition of a trajectory (already time-ordered).
#[derive(Clone, Debug)]
pub struct Transition {
    pub state: Vec<f32>,
    pub mask: Vec<bool>,
    pub action: usize,
    pub logp: f32,
    /// Vector reward assigned to this step (mostly zeros; job-final steps
    /// carry primary + secondary, §4.3.3).
    pub reward: [f32; 2],
}

/// GAE over a finite episode (terminal bootstrap value = 0).
/// Returns per-step vector advantages and vector return targets
/// (`adv + V(s)` — the TD(λ) critic target of Eq. 5).
pub fn gae(
    rewards: &[[f32; 2]],
    values: &[[f32; 2]],
    gamma: f32,
    lambda: f32,
) -> (Vec<[f32; 2]>, Vec<[f32; 2]>) {
    let n = rewards.len();
    assert_eq!(values.len(), n);
    let mut adv = vec![[0.0f32; 2]; n];
    let mut acc = [0.0f32; 2];
    for t in (0..n).rev() {
        let next_v = if t + 1 < n { values[t + 1] } else { [0.0, 0.0] };
        for k in 0..2 {
            let delta = rewards[t][k] + gamma * next_v[k] - values[t][k];
            acc[k] = delta + gamma * lambda * acc[k];
            adv[t][k] = acc[k];
        }
    }
    let ret: Vec<[f32; 2]> = adv
        .iter()
        .zip(values)
        .map(|(a, v)| [a[0] + v[0], a[1] + v[1]])
        .collect();
    (adv, ret)
}

/// Normalize scalarized advantages to zero mean / unit variance (standard
/// PPO stabilization; applied per update batch).
pub fn normalize(adv: &mut [f32]) {
    if adv.len() < 2 {
        return;
    }
    let n = adv.len() as f32;
    let mean: f32 = adv.iter().sum::<f32>() / n;
    let var: f32 = adv.iter().map(|a| (a - mean) * (a - mean)).sum::<f32>() / n;
    let std = var.sqrt().max(1e-6);
    for a in adv.iter_mut() {
        *a = (*a - mean) / std;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_step_advantage_is_td_error() {
        let rewards = vec![[1.0, -1.0]];
        let values = vec![[0.25, 0.5]];
        let (adv, ret) = gae(&rewards, &values, 0.95, 0.95);
        assert!((adv[0][0] - (1.0 - 0.25)).abs() < 1e-6);
        assert!((adv[0][1] - (-1.0 - 0.5)).abs() < 1e-6);
        assert!((ret[0][0] - 1.0).abs() < 1e-6);
        assert!((ret[0][1] - (-1.0)).abs() < 1e-6);
    }

    #[test]
    fn lambda_zero_is_one_step_td() {
        let rewards = vec![[0.0, 0.0], [1.0, 2.0]];
        let values = vec![[0.1, 0.2], [0.3, 0.4]];
        let (adv, _) = gae(&rewards, &values, 0.9, 0.0);
        // t=0: delta = 0 + 0.9*0.3 - 0.1
        assert!((adv[0][0] - (0.9 * 0.3 - 0.1)).abs() < 1e-6);
        assert!((adv[1][0] - (1.0 - 0.3)).abs() < 1e-6);
    }

    #[test]
    fn lambda_one_is_monte_carlo() {
        // With λ=1 and V=0, advantage = discounted return.
        let rewards = vec![[0.0, 0.0], [0.0, 0.0], [1.0, 0.0]];
        let values = vec![[0.0, 0.0]; 3];
        let g = 0.9f32;
        let (adv, _) = gae(&rewards, &values, g, 1.0);
        assert!((adv[0][0] - g * g).abs() < 1e-6);
        assert!((adv[1][0] - g).abs() < 1e-6);
        assert!((adv[2][0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn normalize_standardizes() {
        let mut a = vec![1.0, 2.0, 3.0, 4.0];
        normalize(&mut a);
        let mean: f32 = a.iter().sum::<f32>() / 4.0;
        let var: f32 = a.iter().map(|x| x * x).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-5);
    }
}
