//! `thermos` — leader binary: train policies, run simulations, sweep
//! experiments, and print system info. All heavy lifting lives in the
//! library; this is the CLI entrypoint.

use anyhow::{bail, Context, Result};
use thermos::arch::Arch;
use thermos::noi::NoiTopology;
use thermos::rl::relmas_trainer::RelmasTrainer;
use thermos::rl::trainer::{TrainConfig, Trainer};
use thermos::runtime::{params_io, Runtime};
use thermos::sched::policy::NativeDdt;
use thermos::sched::state::{StateEncoder, NUM_CLUSTERS, STATE_DIM};
use thermos::sched::thermos::{Preference, ThermosSched};
use thermos::sched::{BigLittleSched, SimbaSched};
use thermos::sim::{SimConfig, SimResult, Simulator};
use thermos::util::cli;
use thermos::workload::ModelZoo;

const HELP: &str = "\
thermos — thermally-aware multi-objective scheduling of AI workloads on
heterogeneous multi-chiplet PIM architectures (paper reproduction).

USAGE: thermos <command> [options]

COMMANDS:
  info                      Print the Table 3 system + Table 4 parameters
  train                     Train the THERMOS MORL policy (AOT PPO updates)
  train-relmas              Train the RELMAS baseline policy
  sim                       Run one streaming simulation and print metrics
  explain                   Render a trained DDT policy human-readably (4.3.1)
  smoke                     Load artifacts, run one policy call end-to-end

Common options:
  --noi <mesh|kite|floret|hexamesh>   NoI topology [mesh]
  --seed <n>                          RNG seed [1]
  --artifacts <dir>                   artifacts directory [artifacts]

train options:
  --episodes <n>            [40]      --jobs <n> per episode [60]
  --max-images <n>          [4000]    --out <file> params output
  --log-csv <file>          value-loss curve CSV (Fig. 6)

sim options:
  --sched <thermos|simba|biglittle>   [thermos]
  --params <file>           trained params (thermos)
  --pref <exec|balanced|energy>       runtime preference [balanced]
  --rate <jobs/s>           [2.0]     --duration <s> [240]
  --warmup <s>              [60]      --max-images <n> [20000]
  --pjrt                    evaluate the policy through the PJRT artifact
                            (default uses the bit-checked native evaluator)
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match cli::parse(
        &argv,
        &[
            "noi", "seed", "artifacts", "episodes", "jobs", "max-images", "out", "log-csv",
            "sched", "params", "pref", "rate", "duration", "warmup", "epochs",
        ],
    ) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{HELP}");
            std::process::exit(2);
        }
    };
    if args.cmd.is_empty() || args.has("help") {
        println!("{HELP}");
        return;
    }
    let r = match args.cmd.as_str() {
        "info" => cmd_info(&args),
        "train" => cmd_train(&args),
        "train-relmas" => cmd_train_relmas(&args),
        "sim" => cmd_sim(&args),
        "explain" => cmd_explain(&args),
        "smoke" => cmd_smoke(&args),
        other => {
            eprintln!("unknown command `{other}`\n\n{HELP}");
            std::process::exit(2);
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn noi_of(args: &cli::Args) -> Result<NoiTopology> {
    let name = args.get_or("noi", "mesh");
    NoiTopology::from_name(name).with_context(|| format!("unknown NoI `{name}`"))
}

fn runtime_of(args: &cli::Args) -> Result<Runtime> {
    Runtime::open(args.get_or("artifacts", "artifacts"))
}

fn pref_of(args: &cli::Args) -> Result<Preference> {
    match args.get_or("pref", "balanced") {
        "exec" | "exec_time" | "time" => Ok([1.0, 0.0]),
        "balanced" => Ok([0.5, 0.5]),
        "energy" => Ok([0.0, 1.0]),
        other => bail!("unknown preference `{other}`"),
    }
}

fn cmd_info(args: &cli::Args) -> Result<()> {
    let noi = noi_of(args)?;
    let arch = Arch::paper_heterogeneous(noi);
    println!("THERMOS evaluation system (Table 3) on {} NoI", noi.name());
    println!(
        "{:<12} {:>6} {:>9} {:>10} {:>8} {:>10} {:>9} {:>8}",
        "PIM type", "count", "crossbar", "mem/chip", "area", "rate", "pJ/MAC", "Tmax"
    );
    for (cl, spec) in arch.specs.iter().enumerate() {
        println!(
            "{:<12} {:>6} {:>9} {:>8}Kb {:>6}mm² {:>7.1}G/s {:>9.2} {:>7}K",
            spec.pim.name(),
            arch.clusters[cl].len(),
            format!("{}×{}", spec.crossbar, spec.crossbar),
            spec.mem_bits / 1024,
            spec.area_mm2,
            spec.rate_mac_s / 1e9,
            spec.energy_per_mac_j * 1e12,
            spec.t_max_k
        );
    }
    println!(
        "\nchiplets: {}  total memory: {:.1} MB  total area: {:.0} mm²",
        arch.num_chiplets(),
        arch.total_memory_bits() as f64 / 8e6,
        arch.total_area_mm2()
    );
    println!(
        "NoI: {} links, mean hops {:.2}, diameter {}",
        arch.topology.num_links,
        arch.topology.mean_hops(),
        arch.topology.diameter()
    );
    let zoo = ModelZoo::new();
    println!("\nworkload zoo:");
    for dcg in zoo.all_dcgs() {
        println!(
            "  {:<20} {:>3} layers {:>7.1}M params {:>7.2}G MACs",
            dcg.model.name(),
            dcg.num_layers(),
            dcg.total_weight_bits() as f64 / 8e6,
            dcg.total_macs() as f64 / 1e9
        );
    }
    Ok(())
}

fn cmd_train(args: &cli::Args) -> Result<()> {
    let noi = noi_of(args)?;
    let cfg = TrainConfig {
        noi,
        episodes: args.parse_usize("episodes", 40).map_err(anyhow::Error::msg)?,
        jobs_per_episode: args.parse_usize("jobs", 60).map_err(anyhow::Error::msg)?,
        max_images: args.parse_u64("max-images", 4000).map_err(anyhow::Error::msg)?,
        epochs: args.parse_usize("epochs", 4).map_err(anyhow::Error::msg)?,
        seed: args.parse_u64("seed", 7).map_err(anyhow::Error::msg)?,
        ..TrainConfig::default()
    };
    let mut runtime = runtime_of(args)?;
    eprintln!("training THERMOS policy on {} (pjrt platform: {})", noi.name(), runtime.platform());
    let mut trainer = Trainer::new(cfg);
    let params = trainer.train(&mut runtime)?;
    let default_out = format!("results/thermos_{}.params", noi.name());
    let out = args.get_or("out", &default_out);
    params_io::save(out, &params)?;
    eprintln!("saved trained params to {out}");
    if let Some(csv) = args.get("log-csv") {
        trainer.write_log_csv(csv)?;
        eprintln!("wrote training log to {csv}");
    } else {
        let csv = format!("results/train_{}.csv", noi.name());
        trainer.write_log_csv(&csv)?;
        eprintln!("wrote training log to {csv}");
    }
    Ok(())
}

fn cmd_train_relmas(args: &cli::Args) -> Result<()> {
    let noi = noi_of(args)?;
    let cfg = TrainConfig {
        noi,
        episodes: args.parse_usize("episodes", 40).map_err(anyhow::Error::msg)?,
        jobs_per_episode: args.parse_usize("jobs", 60).map_err(anyhow::Error::msg)?,
        max_images: args.parse_u64("max-images", 4000).map_err(anyhow::Error::msg)?,
        epochs: args.parse_usize("epochs", 4).map_err(anyhow::Error::msg)?,
        seed: args.parse_u64("seed", 7).map_err(anyhow::Error::msg)?,
        ..TrainConfig::default()
    };
    let mut runtime = runtime_of(args)?;
    let mut trainer = RelmasTrainer::new(cfg);
    let params = trainer.train(&mut runtime)?;
    let default_out = format!("results/relmas_{}.params", noi.name());
    let out = args.get_or("out", &default_out);
    params_io::save(out, &params)?;
    eprintln!("saved RELMAS params to {out}");
    Ok(())
}

fn print_result(r: &SimResult) {
    println!(
        "{:<22} throughput {:>5.2} DNN/s | exec {:>7.2} s | e2e {:>7.2} s | energy {:>7.3} J | EDP {:>8.2} | maxT {:>5.1} K | throttles {} | jobs {}",
        r.scheduler,
        r.throughput_jobs_s,
        r.mean_exec_s,
        r.mean_e2e_s,
        r.mean_energy_j,
        r.mean_edp,
        r.max_temp_k,
        r.throttle_events,
        r.jobs.len()
    );
}

fn cmd_sim(args: &cli::Args) -> Result<()> {
    let noi = noi_of(args)?;
    let arch = Arch::paper_heterogeneous(noi);
    let cfg = SimConfig {
        admit_rate: args.parse_f64("rate", 2.0).map_err(anyhow::Error::msg)?,
        warmup_s: args.parse_f64("warmup", 60.0).map_err(anyhow::Error::msg)?,
        duration_s: args.parse_f64("duration", 240.0).map_err(anyhow::Error::msg)?,
        max_images: args.parse_u64("max-images", 20_000).map_err(anyhow::Error::msg)?,
        seed: args.parse_u64("seed", 1).map_err(anyhow::Error::msg)?,
        ..SimConfig::default()
    };
    let sched_name = args.get_or("sched", "thermos");
    let result = match sched_name {
        "simba" => Simulator::new(&arch, SimbaSched::new(arch.clone()), cfg).run().0,
        "biglittle" | "big_little" => {
            Simulator::new(&arch, BigLittleSched::new(arch.clone()), cfg).run().0
        }
        "thermos" => {
            let zoo = ModelZoo::new();
            let encoder = StateEncoder::new(&arch, &zoo, cfg.max_images);
            let omega = pref_of(args)?;
            let theta = match args.get("params") {
                Some(p) => {
                    let params = params_io::load(p)?;
                    params[..thermos::sched::policy::ddt_theta_len(STATE_DIM, NUM_CLUSTERS)]
                        .to_vec()
                }
                None => {
                    eprintln!("note: no --params given; using untrained policy");
                    let mut rng = thermos::util::rng::Rng::new(cfg.seed);
                    NativeDdt::init(STATE_DIM, NUM_CLUSTERS, &mut rng).theta
                }
            };
            if args.has("pjrt") {
                let runtime = runtime_of(args)?;
                let policy = thermos::runtime::PjrtPolicy::new(
                    runtime, "ddt_policy", STATE_DIM, NUM_CLUSTERS, theta,
                )?;
                let sched = ThermosSched::new(arch.clone(), encoder, policy, omega);
                Simulator::new(&arch, sched, cfg).run().0
            } else {
                let policy = NativeDdt::new(STATE_DIM, NUM_CLUSTERS, theta);
                let sched = ThermosSched::new(arch.clone(), encoder, policy, omega);
                Simulator::new(&arch, sched, cfg).run().0
            }
        }
        other => bail!("unknown scheduler `{other}`"),
    };
    print_result(&result);
    Ok(())
}

/// Render a trained DDT policy (requires --params).
fn cmd_explain(args: &cli::Args) -> Result<()> {
    let path = args.get("params").map(str::to_string).unwrap_or_else(|| {
        format!("results/thermos_{}.params", args.get_or("noi", "mesh"))
    });
    let params = params_io::load(&path)?;
    let tl = thermos::sched::policy::ddt_theta_len(STATE_DIM, NUM_CLUSTERS);
    anyhow::ensure!(params.len() >= tl, "params file too short");
    let ddt = NativeDdt::new(STATE_DIM, NUM_CLUSTERS, params[..tl].to_vec());
    print!("{}", thermos::sched::explain::render(&ddt, 4));
    Ok(())
}

/// End-to-end smoke test: artifacts load, PJRT runs, native matches.
fn cmd_smoke(args: &cli::Args) -> Result<()> {
    let mut runtime = runtime_of(args)?;
    println!("platform: {}", runtime.platform());
    println!("abi: state_dim={} theta_len={} phi_len={}", runtime.abi.state_dim,
        runtime.abi.theta_len, runtime.abi.phi_len);
    let mut rng = thermos::util::rng::Rng::new(3);
    let ddt = NativeDdt::init(STATE_DIM, NUM_CLUSTERS, &mut rng);
    let x: Vec<f32> = (0..STATE_DIM).map(|i| (i as f32 * 0.37).sin()).collect();
    let native = ddt.forward(&x);
    let art = runtime.artifact("ddt_policy")?;
    let out = art.run_f32(&[
        thermos::runtime::F32Tensor::vec(ddt.theta.clone()),
        thermos::runtime::F32Tensor::mat(x.clone(), 1, STATE_DIM),
    ])?;
    println!("native logits: {native:?}");
    println!("pjrt   logits: {:?}", out[0]);
    for (a, b) in native.iter().zip(&out[0]) {
        anyhow::ensure!((a - b).abs() < 1e-4, "native/pjrt mismatch: {a} vs {b}");
    }
    println!("smoke OK — native == artifact");
    Ok(())
}
