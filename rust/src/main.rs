//! `thermos` — leader binary: train policies, run simulations, serve an
//! online request stream, sweep experiments, and print system info. All
//! heavy lifting lives in the library; this is the CLI entrypoint.

use anyhow::{bail, Context, Result};
use std::sync::{Arc, Mutex};
use thermos::arch::Arch;
use thermos::cluster::{
    run_cluster, AutoscaleConfig, ClusterConfig, FaultPlan, ShardSchedSpec, StealConfig,
};
use thermos::noi::NoiTopology;
#[cfg(feature = "pjrt")]
use thermos::rl::relmas_trainer::RelmasTrainer;
#[cfg(feature = "pjrt")]
use thermos::rl::trainer::{TrainConfig, Trainer};
use thermos::runtime::params_io;
#[cfg(feature = "pjrt")]
use thermos::runtime::Runtime;
use thermos::sched::policy::NativeDdt;
use thermos::sched::state::{StateEncoder, NUM_CLUSTERS, STATE_DIM};
use thermos::sched::thermos::{Preference, ThermosSched};
use thermos::sched::{BigLittleSched, SimbaSched};
use thermos::serve::{
    MmppSource, PoissonSource, ReplayWriter, ServeConfig, ServeReport, ServeSched, Server,
    TenantRouter, TraceSource, TrafficSource,
};
use thermos::sim::{SimConfig, SimResult, Simulator};
use thermos::util::cli;
use thermos::util::json::Json;
use thermos::workload::ModelZoo;

const HELP: &str = "\
thermos — thermally-aware multi-objective scheduling of AI workloads on
heterogeneous multi-chiplet PIM architectures (paper reproduction).

USAGE: thermos <command> [options]

COMMANDS:
  info                      Print the Table 3 system + Table 4 parameters
  train                     Train the THERMOS MORL policy (needs `pjrt` feature)
  train-relmas              Train the RELMAS baseline policy (needs `pjrt`)
  sim                       Run one streaming simulation and print metrics
  serve                     Run the online scheduling service (admission
                            control, multi-tenant queues, live telemetry)
  explain                   Render a trained DDT policy human-readably (4.3.1)
  smoke                     Load artifacts, run one policy call (needs `pjrt`)

Common options:
  --noi <mesh|kite|floret|hexamesh>   NoI topology [mesh]
  --seed <n>                          RNG seed [1]
  --artifacts <dir>                   artifacts directory [artifacts]
  --threads <n>                       work-pool width for sweeps and training
                                      rollouts (or THERMOS_THREADS) [all cores];
                                      results are identical for any value

train options:
  --episodes <n>            [40]      --jobs <n> per episode [60]
  --max-images <n>          [4000]    --out <file> params output
  --log-csv <file>          value-loss curve CSV (Fig. 6)

sim options:
  --sched <thermos|simba|biglittle>   [thermos]
  --params <file>           trained params (thermos)
  --pref <exec|balanced|energy>       runtime preference [balanced]
  --rate <jobs/s>           [2.0]     --duration <s> [240]
  --warmup <s>              [60]      --max-images <n> [20000]
  --pjrt                    evaluate the policy through the PJRT artifact
                            (needs the `pjrt` feature; default uses the
                            bit-checked native evaluator)

serve options:
  --source <poisson|mmpp|replay>      traffic source [poisson]
  --trace <file>            JSONL request log (required for --source replay)
  --record <file>           record every offered request + mapping decision
  --out <file>              write the final report JSON here (else stdout)
  --sched <thermos|simba|biglittle>   [thermos] (thermos = per-tenant ω router)
  --params <file>           trained params (thermos)
  --rate <jobs/s>           [2.0]     --duration <s> [120]
  --max-images <n>          [4000]    --mix-jobs <n> [500]
  --tenants <we,wb,wn>      tenant mix weights exec,balanced,energy [1,1,1]
  --queue-cap <n>           per-tenant queue bound [64]
  --max-wait <s>            shed deadline, 0 = never shed [30]
  --pressure-depth <n>      under thermal/power pressure, shed queued work
                            (energy class first) down to this backlog [48]
  --snapshot-every <s>      live telemetry period, 0 = off [10]
  --rate-on/--rate-off <jobs/s>, --on-s/--off-s <s>   MMPP burst shape
  --quiet                   suppress live snapshot lines on stderr

serve cluster options (sharded serving; implies the cluster path):
  --shards <n>              shard count: one engine + scheduler per shard,
                            consistent-hash routed, global power arbiter
  --epoch <s>               router/arbiter telemetry epoch [1]
  --budget <w>              package power budget (W) [0.75 x TDP x shards]
  --batch-images <n>        coalesced batch image cap [8000]
  --no-coalesce             disable same-model batch coalescing
  --drain-max <s>           post-horizon drain bound per shard [30]
  --autoscale               enable the utilization autoscaler
  --autoscale-min/--autoscale-max <n>   active-shard bounds [1 / shards]
  --shard-capacity <jobs/s> autoscaler per-shard capacity [2]
  --faults <plan.json>      inject faults from a JSON schedule (shard
                            crash/hang, chiplet trip, mailbox drop/delay,
                            report loss); the supervisor restarts crashed
                            shards and fails their work over
  --chaos <seed>            generate a deterministic fault schedule from a
                            chaos seed (mutually exclusive with --faults)
  --spares <k>              keep k warm-standby engines idle; on a crash a
                            standby adopts the dead shard's ring position,
                            checkpoint and in-flight ids at the next
                            barrier instead of a cold rebuild [0]
  --steal[=off]             deterministic work-stealing at epoch barriers:
                            migrate whole queued requests from backlogged
                            shards to idle ones (backlog = queued requests
                            x canonical per-model cost estimate)
  --steal-slack <f>         imbalance dead-band as a fraction of the mean
                            backlog [0.25]
  --steal-seed <n>          seed for the steal schedule's recipient
                            rotation [the run seed]
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match cli::parse(
        &argv,
        &[
            "noi", "seed", "artifacts", "episodes", "jobs", "max-images", "out", "log-csv",
            "sched", "params", "pref", "rate", "duration", "warmup", "epochs", "source", "trace",
            "record", "mix-jobs", "tenants", "queue-cap", "max-wait", "snapshot-every", "rate-on",
            "rate-off", "on-s", "off-s", "shards", "epoch", "budget", "batch-images",
            "pressure-depth", "drain-max", "autoscale-min", "autoscale-max", "shard-capacity",
            "faults", "chaos", "threads", "spares", "steal-slack", "steal-seed",
        ],
    ) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{HELP}");
            std::process::exit(2);
        }
    };
    if args.cmd.is_empty() || args.has("help") {
        println!("{HELP}");
        return;
    }
    // 0 = unset: fall through to THERMOS_THREADS, then the core count.
    match args.parse_usize("threads", 0) {
        Ok(0) => {}
        Ok(n) => thermos::util::pool::set_global_threads(n),
        Err(e) => {
            eprintln!("error: {e}\n\n{HELP}");
            std::process::exit(2);
        }
    }
    let r = match args.cmd.as_str() {
        "info" => cmd_info(&args),
        "train" => cmd_train(&args),
        "train-relmas" => cmd_train_relmas(&args),
        "sim" => cmd_sim(&args),
        "serve" => cmd_serve(&args),
        "explain" => cmd_explain(&args),
        "smoke" => cmd_smoke(&args),
        other => {
            eprintln!("unknown command `{other}`\n\n{HELP}");
            std::process::exit(2);
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn noi_of(args: &cli::Args) -> Result<NoiTopology> {
    let name = args.get_or("noi", "mesh");
    NoiTopology::from_name(name).with_context(|| format!("unknown NoI `{name}`"))
}

#[cfg(feature = "pjrt")]
fn runtime_of(args: &cli::Args) -> Result<Runtime> {
    Runtime::open(args.get_or("artifacts", "artifacts"))
}

fn pref_of(args: &cli::Args) -> Result<Preference> {
    match args.get_or("pref", "balanced") {
        "exec" | "exec_time" | "time" => Ok([1.0, 0.0]),
        "balanced" => Ok([0.5, 0.5]),
        "energy" => Ok([0.0, 1.0]),
        other => bail!("unknown preference `{other}`"),
    }
}

/// Build the native DDT policy from `--params`, or an untrained one.
fn native_ddt(args: &cli::Args, seed: u64) -> Result<NativeDdt> {
    let theta = match args.get("params") {
        Some(p) => {
            let params = params_io::load(p)?;
            params[..thermos::sched::policy::ddt_theta_len(STATE_DIM, NUM_CLUSTERS)].to_vec()
        }
        None => {
            eprintln!("note: no --params given; using untrained policy");
            let mut rng = thermos::util::rng::Rng::new(seed);
            NativeDdt::init(STATE_DIM, NUM_CLUSTERS, &mut rng).theta
        }
    };
    Ok(NativeDdt::new(STATE_DIM, NUM_CLUSTERS, theta))
}

fn cmd_info(args: &cli::Args) -> Result<()> {
    let noi = noi_of(args)?;
    let arch = Arch::paper_heterogeneous(noi);
    println!("THERMOS evaluation system (Table 3) on {} NoI", noi.name());
    println!(
        "{:<12} {:>6} {:>9} {:>10} {:>8} {:>10} {:>9} {:>8}",
        "PIM type", "count", "crossbar", "mem/chip", "area", "rate", "pJ/MAC", "Tmax"
    );
    for (cl, spec) in arch.specs.iter().enumerate() {
        println!(
            "{:<12} {:>6} {:>9} {:>8}Kb {:>6}mm² {:>7.1}G/s {:>9.2} {:>7}K",
            spec.pim.name(),
            arch.clusters[cl].len(),
            format!("{}×{}", spec.crossbar, spec.crossbar),
            spec.mem_bits / 1024,
            spec.area_mm2,
            spec.rate_mac_s / 1e9,
            spec.energy_per_mac_j * 1e12,
            spec.t_max_k
        );
    }
    println!(
        "\nchiplets: {}  total memory: {:.1} MB  total area: {:.0} mm²",
        arch.num_chiplets(),
        arch.total_memory_bits() as f64 / 8e6,
        arch.total_area_mm2()
    );
    println!(
        "NoI: {} links, mean hops {:.2}, diameter {}",
        arch.topology.num_links,
        arch.topology.mean_hops(),
        arch.topology.diameter()
    );
    let zoo = ModelZoo::new();
    println!("\nworkload zoo:");
    for dcg in zoo.all_dcgs() {
        println!(
            "  {:<20} {:>3} layers {:>7.1}M params {:>7.2}G MACs",
            dcg.model.name(),
            dcg.num_layers(),
            dcg.total_weight_bits() as f64 / 8e6,
            dcg.total_macs() as f64 / 1e9
        );
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_train(args: &cli::Args) -> Result<()> {
    let noi = noi_of(args)?;
    let cfg = TrainConfig {
        noi,
        episodes: args.parse_usize("episodes", 40).map_err(anyhow::Error::msg)?,
        jobs_per_episode: args.parse_usize("jobs", 60).map_err(anyhow::Error::msg)?,
        max_images: args.parse_u64("max-images", 4000).map_err(anyhow::Error::msg)?,
        epochs: args.parse_usize("epochs", 4).map_err(anyhow::Error::msg)?,
        seed: args.parse_u64("seed", 7).map_err(anyhow::Error::msg)?,
        ..TrainConfig::default()
    };
    let mut runtime = runtime_of(args)?;
    eprintln!("training THERMOS policy on {} (pjrt platform: {})", noi.name(), runtime.platform());
    let mut trainer = Trainer::new(cfg);
    let params = trainer.train(&mut runtime)?;
    let default_out = format!("results/thermos_{}.params", noi.name());
    let out = args.get_or("out", &default_out);
    params_io::save(out, &params)?;
    eprintln!("saved trained params to {out}");
    if let Some(csv) = args.get("log-csv") {
        trainer.write_log_csv(csv)?;
        eprintln!("wrote training log to {csv}");
    } else {
        let csv = format!("results/train_{}.csv", noi.name());
        trainer.write_log_csv(&csv)?;
        eprintln!("wrote training log to {csv}");
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(_args: &cli::Args) -> Result<()> {
    bail!("`train` needs the PJRT runtime: rebuild with `--features pjrt`")
}

#[cfg(feature = "pjrt")]
fn cmd_train_relmas(args: &cli::Args) -> Result<()> {
    let noi = noi_of(args)?;
    let cfg = TrainConfig {
        noi,
        episodes: args.parse_usize("episodes", 40).map_err(anyhow::Error::msg)?,
        jobs_per_episode: args.parse_usize("jobs", 60).map_err(anyhow::Error::msg)?,
        max_images: args.parse_u64("max-images", 4000).map_err(anyhow::Error::msg)?,
        epochs: args.parse_usize("epochs", 4).map_err(anyhow::Error::msg)?,
        seed: args.parse_u64("seed", 7).map_err(anyhow::Error::msg)?,
        ..TrainConfig::default()
    };
    let mut runtime = runtime_of(args)?;
    let mut trainer = RelmasTrainer::new(cfg);
    let params = trainer.train(&mut runtime)?;
    let default_out = format!("results/relmas_{}.params", noi.name());
    let out = args.get_or("out", &default_out);
    params_io::save(out, &params)?;
    eprintln!("saved RELMAS params to {out}");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train_relmas(_args: &cli::Args) -> Result<()> {
    bail!("`train-relmas` needs the PJRT runtime: rebuild with `--features pjrt`")
}

fn print_result(r: &SimResult) {
    println!(
        "{:<22} throughput {:>5.2} DNN/s | exec {:>7.2} s | e2e {:>7.2} s | energy {:>7.3} J | EDP {:>8.2} | maxT {:>5.1} K | throttles {} | jobs {}",
        r.scheduler,
        r.throughput_jobs_s,
        r.mean_exec_s,
        r.mean_e2e_s,
        r.mean_energy_j,
        r.mean_edp,
        r.max_temp_k,
        r.throttle_events,
        r.jobs.len()
    );
}

#[cfg(feature = "pjrt")]
fn run_sim_pjrt(
    args: &cli::Args,
    arch: &Arch,
    encoder: StateEncoder,
    omega: Preference,
    theta: Vec<f32>,
    cfg: SimConfig,
) -> Result<SimResult> {
    let runtime = runtime_of(args)?;
    let policy =
        thermos::runtime::PjrtPolicy::new(runtime, "ddt_policy", STATE_DIM, NUM_CLUSTERS, theta)?;
    let sched = ThermosSched::new(arch.clone(), encoder, policy, omega);
    Ok(Simulator::new(arch, sched, cfg).run().0)
}

#[cfg(not(feature = "pjrt"))]
fn run_sim_pjrt(
    _args: &cli::Args,
    _arch: &Arch,
    _encoder: StateEncoder,
    _omega: Preference,
    _theta: Vec<f32>,
    _cfg: SimConfig,
) -> Result<SimResult> {
    bail!("--pjrt needs the PJRT runtime: rebuild with `--features pjrt`")
}

fn cmd_sim(args: &cli::Args) -> Result<()> {
    let noi = noi_of(args)?;
    let arch = Arch::paper_heterogeneous(noi);
    let cfg = SimConfig {
        admit_rate: args.parse_f64("rate", 2.0).map_err(anyhow::Error::msg)?,
        warmup_s: args.parse_f64("warmup", 60.0).map_err(anyhow::Error::msg)?,
        duration_s: args.parse_f64("duration", 240.0).map_err(anyhow::Error::msg)?,
        max_images: args.parse_u64("max-images", 20_000).map_err(anyhow::Error::msg)?,
        seed: args.parse_u64("seed", 1).map_err(anyhow::Error::msg)?,
        ..SimConfig::default()
    };
    let sched_name = args.get_or("sched", "thermos");
    let result = match sched_name {
        "simba" => Simulator::new(&arch, SimbaSched::new(arch.clone()), cfg).run().0,
        "biglittle" | "big_little" => {
            Simulator::new(&arch, BigLittleSched::new(arch.clone()), cfg).run().0
        }
        "thermos" => {
            let zoo = ModelZoo::new();
            let encoder = StateEncoder::new(&arch, &zoo, cfg.max_images);
            let omega = pref_of(args)?;
            let ddt = native_ddt(args, cfg.seed)?;
            if args.has("pjrt") {
                run_sim_pjrt(args, &arch, encoder, omega, ddt.theta, cfg)?
            } else {
                let sched = ThermosSched::new(arch.clone(), encoder, ddt, omega);
                Simulator::new(&arch, sched, cfg).run().0
            }
        }
        other => bail!("unknown scheduler `{other}`"),
    };
    print_result(&result);
    Ok(())
}

fn run_server<S: ServeSched>(
    arch: &Arch,
    sched: S,
    source: Box<dyn TrafficSource>,
    cfg: ServeConfig,
    replay: Option<Arc<Mutex<ReplayWriter>>>,
    live: bool,
) -> ServeReport {
    let mut server = Server::new(arch, sched, source, cfg);
    if let Some(w) = replay {
        server = server.with_replay(w);
    }
    if live {
        server.on_snapshot =
            Some(Box::new(|snap: &Json| eprintln!("{}", snap.to_string_compact())));
    }
    server.run()
}

/// Build the serve traffic source from the shared `--source` options.
fn serve_source(args: &cli::Args) -> Result<Box<dyn TrafficSource>> {
    let seed = args.parse_u64("seed", 1).map_err(anyhow::Error::msg)?;
    let rate = args.parse_f64("rate", 2.0).map_err(anyhow::Error::msg)?;
    let mix_jobs = args.parse_usize("mix-jobs", 500).map_err(anyhow::Error::msg)?;
    let max_images = args.parse_u64("max-images", 4000).map_err(anyhow::Error::msg)?;
    let tenants = args.parse_f64_list("tenants", &[1.0, 1.0, 1.0]).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(
        tenants.len() == 3,
        "--tenants expects three weights: exec,balanced,energy"
    );
    let weights = [tenants[0], tenants[1], tenants[2]];
    Ok(match args.get_or("source", "poisson") {
        "poisson" => Box::new(PoissonSource::new(rate, mix_jobs, max_images, weights, seed)),
        "mmpp" => Box::new(MmppSource::new(
            args.parse_f64("rate-on", rate * 4.0).map_err(anyhow::Error::msg)?,
            args.parse_f64("rate-off", 0.0).map_err(anyhow::Error::msg)?,
            args.parse_f64("on-s", 10.0).map_err(anyhow::Error::msg)?,
            args.parse_f64("off-s", 30.0).map_err(anyhow::Error::msg)?,
            mix_jobs,
            max_images,
            weights,
            seed,
        )),
        "replay" => {
            let path = args.get("trace").context("--source replay needs --trace <file>")?;
            Box::new(TraceSource::from_path(path).map_err(anyhow::Error::msg)?)
        }
        other => bail!("unknown source `{other}`"),
    })
}

/// Shared serve/engine knobs for both the single-node and cluster paths.
fn serve_config(args: &cli::Args) -> Result<ServeConfig> {
    let seed = args.parse_u64("seed", 1).map_err(anyhow::Error::msg)?;
    let max_images = args.parse_u64("max-images", 4000).map_err(anyhow::Error::msg)?;
    Ok(ServeConfig {
        duration_s: args.parse_f64("duration", 120.0).map_err(anyhow::Error::msg)?,
        tenant_queue_cap: args.parse_usize("queue-cap", 64).map_err(anyhow::Error::msg)?,
        max_wait_s: args.parse_f64("max-wait", 30.0).map_err(anyhow::Error::msg)?,
        snapshot_every_s: args.parse_f64("snapshot-every", 10.0).map_err(anyhow::Error::msg)?,
        pressure_depth: args.parse_usize("pressure-depth", 48).map_err(anyhow::Error::msg)?,
        sim: SimConfig { warmup_s: 0.0, max_images, seed, ..SimConfig::default() },
    })
}

/// Write the final report JSON to `--out` (or stdout).
fn emit_report(args: &cli::Args, json: &Json) -> Result<()> {
    let pretty = json.to_string_pretty();
    match args.get("out") {
        Some(p) => {
            if let Some(parent) = std::path::Path::new(p).parent() {
                if !parent.as_os_str().is_empty() {
                    std::fs::create_dir_all(parent)?;
                }
            }
            std::fs::write(p, pretty + "\n")?;
            eprintln!("wrote report to {p}");
        }
        None => println!("{pretty}"),
    }
    Ok(())
}

fn cmd_serve(args: &cli::Args) -> Result<()> {
    if args.get("shards").is_some() {
        return cmd_serve_cluster(args);
    }
    let noi = noi_of(args)?;
    let arch = Arch::paper_heterogeneous(noi);
    let seed = args.parse_u64("seed", 1).map_err(anyhow::Error::msg)?;
    let max_images = args.parse_u64("max-images", 4000).map_err(anyhow::Error::msg)?;
    let source = serve_source(args)?;
    let cfg = serve_config(args)?;

    let replay = match args.get("record") {
        Some(p) => Some(Arc::new(Mutex::new(
            ReplayWriter::create(p).with_context(|| format!("create replay log {p}"))?,
        ))),
        None => None,
    };
    let live = !args.has("quiet");

    let report = match args.get_or("sched", "thermos") {
        "simba" => run_server(&arch, SimbaSched::new(arch.clone()), source, cfg, replay, live),
        "biglittle" | "big_little" => {
            run_server(&arch, BigLittleSched::new(arch.clone()), source, cfg, replay, live)
        }
        "thermos" | "thermos-mt" | "thermos_mt" => {
            // Per-tenant ω routing through the single MORL policy; --pref
            // only sets the fallback for jobs with no registered tenant.
            let zoo = ModelZoo::new();
            let encoder = StateEncoder::new(&arch, &zoo, max_images);
            let inner =
                ThermosSched::new(arch.clone(), encoder, native_ddt(args, seed)?, pref_of(args)?);
            run_server(&arch, TenantRouter::new(inner), source, cfg, replay, live)
        }
        other => bail!("unknown scheduler `{other}`"),
    };

    eprintln!("telemetry digest: {}", report.digest);
    emit_report(args, &report.json)
}

/// Sharded serving: `thermos serve --shards N` routes the stream over N
/// engine shards with a global power arbiter (see `thermos::cluster`).
fn cmd_serve_cluster(args: &cli::Args) -> Result<()> {
    let noi = noi_of(args)?;
    let shards = args.parse_usize("shards", 1).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(shards >= 1, "--shards must be at least 1");
    let seed = args.parse_u64("seed", 1).map_err(anyhow::Error::msg)?;
    let serve = serve_config(args)?;
    let duration_s = serve.duration_s;
    let source = serve_source(args)?;

    let theta = match args.get("params") {
        Some(_) => Some(native_ddt(args, seed)?.theta),
        None => None,
    };
    let sched = match args.get_or("sched", "thermos") {
        "simba" => ShardSchedSpec::Simba,
        "biglittle" | "big_little" => ShardSchedSpec::BigLittle,
        "thermos" | "thermos-mt" | "thermos_mt" => {
            ShardSchedSpec::Thermos { theta, fallback: pref_of(args)? }
        }
        other => bail!("unknown scheduler `{other}`"),
    };
    let autoscale = if args.has("autoscale") {
        Some(AutoscaleConfig {
            min_shards: args.parse_usize("autoscale-min", 1).map_err(anyhow::Error::msg)?,
            max_shards: args.parse_usize("autoscale-max", shards).map_err(anyhow::Error::msg)?,
            shard_capacity_jobs_s: args
                .parse_f64("shard-capacity", 2.0)
                .map_err(anyhow::Error::msg)?,
            ..AutoscaleConfig::default()
        })
    } else {
        None
    };
    let budget = args.parse_f64("budget", 0.0).map_err(anyhow::Error::msg)?;
    let epoch_s = args.parse_f64("epoch", 1.0).map_err(anyhow::Error::msg)?;
    anyhow::ensure!(
        !(args.get("faults").is_some() && args.get("chaos").is_some()),
        "--faults and --chaos are mutually exclusive"
    );
    let faults = match (args.get("faults"), args.get("chaos")) {
        (Some(path), _) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("read fault plan {path}"))?;
            Some(FaultPlan::from_json(&text)?)
        }
        (None, Some(_)) => {
            let chaos_seed = args.parse_u64("chaos", 0).map_err(anyhow::Error::msg)?;
            let epochs = ((duration_s / epoch_s).ceil() as usize).max(1);
            Some(FaultPlan::chaos(chaos_seed, shards, epochs))
        }
        (None, None) => None,
    };
    let spares = args.parse_usize("spares", 0).map_err(anyhow::Error::msg)?;
    // `--steal` is a boolean flag; `--steal=off|false|0` disables it so CI
    // matrices can toggle one token instead of editing the argv shape.
    let steal_on =
        args.has("steal") && !matches!(args.get("steal"), Some("off") | Some("false") | Some("0"));
    let steal = if steal_on {
        Some(StealConfig {
            seed: args.parse_u64("steal-seed", seed).map_err(anyhow::Error::msg)?,
            slack: args.parse_f64("steal-slack", 0.25).map_err(anyhow::Error::msg)?,
        })
    } else {
        None
    };
    let cfg = ClusterConfig {
        shards,
        epoch_s,
        duration_s,
        spares,
        steal,
        drain_max_s: args.parse_f64("drain-max", 30.0).map_err(anyhow::Error::msg)?,
        power_budget_w: (budget > 0.0).then_some(budget),
        coalesce: !args.has("no-coalesce"),
        max_batch_images: args.parse_u64("batch-images", 8000).map_err(anyhow::Error::msg)?,
        noi,
        serve,
        sched,
        autoscale,
        record_base: args.get("record").map(str::to_string),
        faults,
        ..ClusterConfig::default()
    };

    let report = run_cluster(cfg, source)?;
    if !args.has("quiet") {
        for snap in &report.snapshots {
            eprintln!("{}", snap.to_string_compact());
        }
    }
    eprintln!(
        "cluster digest: {}  (profile cache: {} hits / {} misses, {} entries)",
        report.digest, report.cache_hits, report.cache_misses, report.cache_entries
    );
    emit_report(args, &report.json)
}

/// Render a trained DDT policy (requires --params).
fn cmd_explain(args: &cli::Args) -> Result<()> {
    let path = args.get("params").map(str::to_string).unwrap_or_else(|| {
        format!("results/thermos_{}.params", args.get_or("noi", "mesh"))
    });
    let params = params_io::load(&path)?;
    let tl = thermos::sched::policy::ddt_theta_len(STATE_DIM, NUM_CLUSTERS);
    anyhow::ensure!(params.len() >= tl, "params file too short");
    let ddt = NativeDdt::new(STATE_DIM, NUM_CLUSTERS, params[..tl].to_vec());
    print!("{}", thermos::sched::explain::render(&ddt, 4));
    Ok(())
}

/// End-to-end smoke test: artifacts load, PJRT runs, native matches.
#[cfg(feature = "pjrt")]
fn cmd_smoke(args: &cli::Args) -> Result<()> {
    let mut runtime = runtime_of(args)?;
    println!("platform: {}", runtime.platform());
    println!("abi: state_dim={} theta_len={} phi_len={}", runtime.abi.state_dim,
        runtime.abi.theta_len, runtime.abi.phi_len);
    let mut rng = thermos::util::rng::Rng::new(3);
    let ddt = NativeDdt::init(STATE_DIM, NUM_CLUSTERS, &mut rng);
    let x: Vec<f32> = (0..STATE_DIM).map(|i| (i as f32 * 0.37).sin()).collect();
    let native = ddt.forward(&x);
    let art = runtime.artifact("ddt_policy")?;
    let out = art.run_f32(&[
        thermos::runtime::F32Tensor::vec(ddt.theta.clone()),
        thermos::runtime::F32Tensor::mat(x.clone(), 1, STATE_DIM),
    ])?;
    println!("native logits: {native:?}");
    println!("pjrt   logits: {:?}", out[0]);
    for (a, b) in native.iter().zip(&out[0]) {
        anyhow::ensure!((a - b).abs() < 1e-4, "native/pjrt mismatch: {a} vs {b}");
    }
    println!("smoke OK — native == artifact");
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_smoke(_args: &cli::Args) -> Result<()> {
    bail!("`smoke` needs the PJRT runtime: rebuild with `--features pjrt`")
}
