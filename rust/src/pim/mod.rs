//! PIM compute model — our CiMLoop [3] substitute (DESIGN.md §5).
//!
//! Given a neural layer (or a share of one) mapped onto a chiplet of some
//! PIM type, produces execution time, dynamic energy, and power. The
//! simulator composes these per-chiplet figures with the NoI communication
//! model and the thermal model. The constants live in
//! [`crate::arch::PimSpec::table3`]; this module implements the equations.

use crate::arch::PimSpec;

/// Weight-programming model: jobs stream weights from the I/O chiplets
/// into crossbars once per job (weight-stationary execution, §5.2).
#[derive(Clone, Debug)]
pub struct WeightLoadModel {
    /// Aggregate host→interposer bandwidth through the I/O chiplets (bit/s).
    pub io_bandwidth_bits_s: f64,
    /// Write energy per bit: ReRAM SET/RESET is far costlier than SRAM.
    pub reram_write_j_per_bit: f64,
    pub sram_write_j_per_bit: f64,
}

impl Default for WeightLoadModel {
    fn default() -> Self {
        WeightLoadModel {
            io_bandwidth_bits_s: 512.0e9, // 64 GB/s aggregate I/O
            reram_write_j_per_bit: 10.0e-12,
            sram_write_j_per_bit: 0.2e-12,
        }
    }
}

/// The analytic per-layer compute model.
#[derive(Clone, Debug, Default)]
pub struct ComputeModel {
    pub load: WeightLoadModel,
}

impl ComputeModel {
    /// Time for one chiplet of `spec` to execute `macs` MAC operations of
    /// one input frame.
    ///
    /// Crossbar-array MVM achieves its peak rate only when the mapped
    /// weight block fills enough crossbar columns; tiny shares still pay
    /// the input-streaming cycles. We model this with a utilization floor:
    /// a share using fraction `u` of the chiplet's crossbar capacity runs
    /// at `rate × max(u, u_floor)^0` — i.e. full rate, but with a fixed
    /// per-frame front-end latency `t_front` (input DAC/driver setup).
    pub fn mac_time_s(&self, spec: &PimSpec, macs: f64) -> f64 {
        const T_FRONT_S: f64 = 0.5e-6; // per-frame per-chiplet front-end
        if macs <= 0.0 {
            return 0.0;
        }
        macs / spec.rate_mac_s + T_FRONT_S
    }

    /// Dynamic energy for `macs` MAC operations on `spec`.
    pub fn mac_energy_j(&self, spec: &PimSpec, macs: f64) -> f64 {
        macs * spec.energy_per_mac_j
    }

    /// Dynamic power while a chiplet computes at a sustained frame rate
    /// (`frames_s`) with `macs_per_frame` of work (leakage included).
    pub fn active_power_w(&self, spec: &PimSpec, macs_per_frame: f64, frames_s: f64) -> f64 {
        self.mac_energy_j(spec, macs_per_frame) * frames_s + spec.leakage_w
    }

    /// Power while idle or throttled: leakage only — throttled PIM
    /// chiplets still retain weights (§4.1).
    pub fn idle_power_w(&self, spec: &PimSpec) -> f64 {
        spec.leakage_w
    }

    /// One-time weight-programming cost for `bits` of weights onto `spec`.
    /// Returns (time contribution at the shared I/O, energy).
    pub fn weight_load(&self, spec: &PimSpec, bits: f64) -> (f64, f64) {
        let t = bits / self.load.io_bandwidth_bits_s;
        let e_bit = if spec.pim.is_reram() {
            self.load.reram_write_j_per_bit
        } else {
            self.load.sram_write_j_per_bit
        };
        (t, bits * e_bit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{PimSpec, PimType};
    use crate::util::testkit::{check, forall};

    fn specs() -> [PimSpec; 4] {
        PimSpec::table3()
    }

    #[test]
    fn time_scales_linearly_in_macs() {
        let m = ComputeModel::default();
        let s = &specs()[0];
        let t1 = m.mac_time_s(s, 1e9);
        let t2 = m.mac_time_s(s, 2e9);
        // Slope is 1/rate; front-end latency is constant.
        let slope = (t2 - t1) / 1e9;
        assert!((slope - 1.0 / s.rate_mac_s).abs() / slope < 1e-9);
    }

    #[test]
    fn standard_is_fastest_adcless_most_efficient() {
        let m = ComputeModel::default();
        let ss = specs();
        let macs = 5e9;
        let times: Vec<f64> = ss.iter().map(|s| m.mac_time_s(s, macs)).collect();
        let energies: Vec<f64> = ss.iter().map(|s| m.mac_energy_j(s, macs)).collect();
        assert!(times[0] < times[1] && times[0] < times[2] && times[0] < times[3]);
        assert!(energies[3] < energies[0] && energies[3] < energies[1] && energies[3] < energies[2]);
    }

    #[test]
    fn power_includes_leakage() {
        let m = ComputeModel::default();
        let s = &specs()[1];
        assert_eq!(m.idle_power_w(s), s.leakage_w);
        let p = m.active_power_w(s, 1e7, 30.0);
        assert!(p > s.leakage_w);
        // 1e7 MACs/frame at 30 fps on shared-ADC: 1e7*0.65e-12*30 ≈ 0.2 mW dynamic
        assert!((p - (1e7 * s.energy_per_mac_j * 30.0 + s.leakage_w)).abs() < 1e-12);
    }

    #[test]
    fn reram_writes_cost_more() {
        let m = ComputeModel::default();
        let ss = specs();
        let (_, e_reram) = m.weight_load(&ss[PimType::Standard as usize], 1e6);
        let (_, e_sram) = m.weight_load(&ss[PimType::SharedAdc as usize], 1e6);
        assert!(e_reram > 10.0 * e_sram);
    }

    #[test]
    fn properties_nonnegative_monotone() {
        let m = ComputeModel::default();
        let ss = specs();
        forall(200, |rng| {
            let s = &ss[rng.below(4)];
            let a = rng.range_f64(0.0, 1e10);
            let b = rng.range_f64(0.0, 1e10);
            let (lo, hi) = if a < b { (a, b) } else { (b, a) };
            check(m.mac_time_s(s, lo) <= m.mac_time_s(s, hi), "time monotone")?;
            check(m.mac_energy_j(s, lo) <= m.mac_energy_j(s, hi), "energy monotone")?;
            check(m.mac_time_s(s, a) >= 0.0 && m.mac_energy_j(s, a) >= 0.0, "nonneg")
        });
    }
}
