//! Experiment harness: the machinery every §5 figure/table bench is built
//! from — scheduler factories, rate sweeps, seed averaging, and report
//! tables. Bench targets (`rust/benches/*.rs`, `harness = false`) call
//! into this module and print the paper-style rows.

pub mod report;

use crate::arch::Arch;
use crate::noi::NoiTopology;
use crate::runtime::params_io;
use crate::sched::policy::{ddt_theta_len, mlp_param_len, NativeDdt, NativeMlp};
use crate::sched::relmas::RelmasSched;
use crate::sched::state::{relmas_obs_dim, StateEncoder, NUM_CLUSTERS, STATE_DIM};
use crate::sched::thermos::{Preference, ThermosSched};
use crate::sched::{BigLittleSched, Scheduler, SimbaSched};
use crate::sim::{SimConfig, SimResult, Simulator};
use crate::util::pool::WorkPool;
use crate::util::rng::Rng;
use crate::util::stats::mean;
use crate::workload::ModelZoo;

/// Which scheduler to run (with its policy parameters where applicable).
#[derive(Clone)]
pub enum SchedKind {
    Simba,
    BigLittle,
    Thermos { theta: Vec<f32>, pref: Preference, label: &'static str },
    Relmas { actor: Vec<f32> },
}

impl SchedKind {
    pub fn label(&self) -> String {
        match self {
            SchedKind::Simba => "simba".into(),
            SchedKind::BigLittle => "big_little".into(),
            SchedKind::Thermos { label, .. } => format!("thermos.{label}"),
            SchedKind::Relmas { .. } => "relmas".into(),
        }
    }
}

/// Load the trained THERMOS θ for a NoI from `results/`, or fall back to a
/// seeded untrained policy (benches still run end-to-end without training;
/// the report marks the fallback).
pub fn load_thermos_theta(noi: NoiTopology) -> (Vec<f32>, bool) {
    let path = format!("results/thermos_{}.params", noi.name());
    match params_io::load(&path) {
        Ok(params) => (params[..ddt_theta_len(STATE_DIM, NUM_CLUSTERS)].to_vec(), true),
        Err(_) => {
            eprintln!("note: {path} not found — using untrained THERMOS policy");
            let mut rng = Rng::new(0xDD7);
            (NativeDdt::init(STATE_DIM, NUM_CLUSTERS, &mut rng).theta, false)
        }
    }
}

/// Load the trained RELMAS actor for a NoI (same fallback contract).
pub fn load_relmas_actor(noi: NoiTopology, n_chiplets: usize) -> (Vec<f32>, bool) {
    let dims = vec![relmas_obs_dim(n_chiplets), 128, 128, n_chiplets];
    let path = format!("results/relmas_{}.params", noi.name());
    match params_io::load(&path) {
        Ok(params) => (params[..mlp_param_len(&dims)].to_vec(), true),
        Err(_) => {
            eprintln!("note: {path} not found — using untrained RELMAS policy");
            let mut rng = Rng::new(0x5e1);
            (NativeMlp::init(dims, &mut rng).params, false)
        }
    }
}

/// The standard six-way comparison of §5.3: three baselines + the single
/// THERMOS policy under its three runtime preferences.
pub fn standard_contenders(noi: NoiTopology) -> Vec<SchedKind> {
    let arch = Arch::paper_heterogeneous(noi);
    let (theta, _) = load_thermos_theta(noi);
    let (actor, _) = load_relmas_actor(noi, arch.num_chiplets());
    vec![
        SchedKind::Simba,
        SchedKind::BigLittle,
        SchedKind::Relmas { actor },
        SchedKind::Thermos { theta: theta.clone(), pref: [1.0, 0.0], label: "exec_time" },
        SchedKind::Thermos { theta: theta.clone(), pref: [0.5, 0.5], label: "balanced" },
        SchedKind::Thermos { theta, pref: [0.0, 1.0], label: "energy" },
    ]
}

fn boxed_scheduler(arch: &Arch, cfg: &SimConfig, kind: &SchedKind) -> Box<dyn Scheduler> {
    let zoo = ModelZoo::new();
    let encoder = StateEncoder::new(arch, &zoo, cfg.max_images);
    match kind {
        SchedKind::Simba => Box::new(SimbaSched::new(arch.clone())),
        SchedKind::BigLittle => Box::new(BigLittleSched::new(arch.clone())),
        SchedKind::Thermos { theta, pref, .. } => Box::new(ThermosSched::new(
            arch.clone(),
            encoder,
            NativeDdt::new(STATE_DIM, NUM_CLUSTERS, theta.clone()),
            *pref,
        )),
        SchedKind::Relmas { actor } => {
            let n = arch.num_chiplets();
            let dims = vec![relmas_obs_dim(n), 128, 128, n];
            Box::new(RelmasSched::new(
                arch.clone(),
                encoder,
                NativeMlp::new(dims, actor.clone()),
            ))
        }
    }
}

impl Scheduler for Box<dyn Scheduler> {
    fn name(&self) -> &'static str {
        self.as_ref().name()
    }
    fn schedule(
        &mut self,
        job: &crate::workload::Job,
        snap: &crate::sched::SysSnapshot,
    ) -> Option<crate::sim::Mapping> {
        self.as_mut().schedule(job, snap)
    }
    fn on_job_completed(&mut self, job_id: u64) {
        self.as_mut().on_job_completed(job_id)
    }
}

/// Run one (scheduler, config) simulation.
pub fn run_one(noi: NoiTopology, kind: &SchedKind, cfg: SimConfig) -> SimResult {
    let arch = Arch::paper_heterogeneous(noi);
    let sched = boxed_scheduler(&arch, &cfg, kind);
    let (mut result, _) = Simulator::new(&arch, sched, cfg).run();
    result.scheduler = kind.label();
    result
}

/// Average a set of same-config runs (different seeds): paper reports the
/// average of ten random simulations (§5.1).
pub fn average(results: &[SimResult]) -> SimResult {
    assert!(!results.is_empty());
    let f = |g: fn(&SimResult) -> f64| mean(&results.iter().map(g).collect::<Vec<_>>());
    let mut out = results[0].clone();
    out.throughput_jobs_s = f(|r| r.throughput_jobs_s);
    out.mean_exec_s = f(|r| r.mean_exec_s);
    out.mean_e2e_s = f(|r| r.mean_e2e_s);
    out.mean_energy_j = f(|r| r.mean_energy_j);
    out.mean_edp = f(|r| r.mean_edp);
    out.violation_chiplet_s = f(|r| r.violation_chiplet_s);
    out.system_energy_j = f(|r| r.system_energy_j);
    out.max_temp_k = f(|r| r.max_temp_k);
    out.throttle_events =
        (results.iter().map(|r| r.throttle_events).sum::<u64>() as f64 / results.len() as f64) as u64;
    out
}

/// Seed-averaged run. Seeds execute on the global work pool; each run is
/// seeded exactly as the old serial loop was and results come back in
/// seed order, so the average is byte-identical at any `--threads`.
pub fn run_averaged(
    noi: NoiTopology,
    kind: &SchedKind,
    base_cfg: &SimConfig,
    seeds: &[u64],
) -> SimResult {
    let results = WorkPool::global().run(seeds.len(), |i| {
        let cfg = SimConfig { seed: seeds[i], ..base_cfg.clone() };
        run_one(noi, kind, cfg)
    });
    average(&results)
}

/// Full (scheduler × rate × seed) sweep on a work pool, averaged per cell.
///
/// The grid is flattened kind-major (kind, then rate, then seed — the same
/// nesting the serial bench loops used), every cell is seeded through
/// `cfg_of(rate, seed)` exactly as before, and the pool returns runs in
/// grid order. `out[ki][ri]` is the seed average for `kinds[ki]` at
/// `rates[ri]` — byte-identical for 1 and N threads.
pub fn sweep_averaged<F>(
    noi: NoiTopology,
    kinds: &[SchedKind],
    rates: &[f64],
    seeds: &[u64],
    pool: &WorkPool,
    cfg_of: F,
) -> Vec<Vec<SimResult>>
where
    F: Fn(f64, u64) -> SimConfig + Sync,
{
    let mut tasks: Vec<(usize, f64, u64)> = Vec::with_capacity(kinds.len() * rates.len() * seeds.len());
    for ki in 0..kinds.len() {
        for &rate in rates {
            for &seed in seeds {
                tasks.push((ki, rate, seed));
            }
        }
    }
    let runs = pool.map(&tasks, |_, &(ki, rate, seed)| run_one(noi, &kinds[ki], cfg_of(rate, seed)));
    let mut chunks = runs.chunks(seeds.len().max(1));
    let mut out: Vec<Vec<SimResult>> = Vec::with_capacity(kinds.len());
    for _ in kinds {
        let mut row = Vec::with_capacity(rates.len());
        for _ in rates {
            row.push(average(chunks.next().expect("task grid covers every (kind, rate) cell")));
        }
        out.push(row);
    }
    out
}

/// `sweep_averaged` with the standard experiment config and seed set, on
/// the globally configured pool. This is what the fig7/fig9/table5 bench
/// targets call.
pub fn sweep_standard(
    noi: NoiTopology,
    kinds: &[SchedKind],
    rates: &[f64],
) -> Vec<Vec<SimResult>> {
    let seeds = exp_seeds();
    sweep_averaged(noi, kinds, rates, &seeds, &WorkPool::global(), |rate, seed| {
        exp_config(rate, seed)
    })
}

/// Fast-mode switch for CI: THERMOS_EXP_FAST=1 shrinks windows and seeds.
pub fn fast_mode() -> bool {
    std::env::var("THERMOS_EXP_FAST").as_deref() == Ok("1")
}

/// Default experiment config (paper-scale unless fast mode).
pub fn exp_config(admit_rate: f64, seed: u64) -> SimConfig {
    if fast_mode() {
        SimConfig {
            admit_rate,
            warmup_s: 10.0,
            duration_s: 60.0,
            max_images: 2_000,
            mix_jobs: 120,
            seed,
            ..SimConfig::default()
        }
    } else {
        SimConfig {
            admit_rate,
            warmup_s: 60.0,
            duration_s: 240.0,
            // Image counts scaled so the admit-rate sweep spans the
            // under- to over-saturation regime the paper's Fig. 7 covers
            // on this simulator's service capacity.
            max_images: 2_000,
            mix_jobs: 500,
            seed,
            ..SimConfig::default()
        }
    }
}

/// Seeds for averaging (paper: 10 random simulations).
pub fn exp_seeds() -> Vec<u64> {
    if fast_mode() {
        vec![11, 22]
    } else {
        // Paper averages 10 random simulations; this single-core testbed
        // uses 4 (seed sensitivity is small — see EXPERIMENTS.md).
        (1..=4).map(|i| i * 1000 + 7).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contenders_cover_all_schedulers() {
        let ks = standard_contenders(NoiTopology::Mesh);
        assert_eq!(ks.len(), 6);
        let labels: Vec<String> = ks.iter().map(|k| k.label()).collect();
        assert!(labels.contains(&"simba".to_string()));
        assert!(labels.contains(&"thermos.energy".to_string()));
    }

    #[test]
    fn averaged_run_smoke() {
        let cfg = SimConfig {
            admit_rate: 1.0,
            warmup_s: 2.0,
            duration_s: 20.0,
            max_images: 300,
            mix_jobs: 30,
            seed: 1,
            ..SimConfig::default()
        };
        let r = run_averaged(NoiTopology::Mesh, &SchedKind::Simba, &cfg, &[1, 2]);
        assert!(r.throughput_jobs_s > 0.0);
        assert_eq!(r.scheduler, "simba");
    }

    #[test]
    fn sweep_matches_per_cell_run_averaged() {
        let base = SimConfig {
            warmup_s: 2.0,
            duration_s: 15.0,
            max_images: 300,
            mix_jobs: 25,
            ..SimConfig::default()
        };
        let cfg_of = |rate: f64, seed: u64| SimConfig { admit_rate: rate, seed, ..base.clone() };
        let kinds = [SchedKind::Simba, SchedKind::BigLittle];
        let rates = [1.0, 2.0];
        let seeds = [3u64, 4];
        let grid =
            sweep_averaged(NoiTopology::Mesh, &kinds, &rates, &seeds, &WorkPool::new(2), cfg_of);
        assert_eq!(grid.len(), kinds.len());
        assert_eq!(grid[0].len(), rates.len());
        for (ki, kind) in kinds.iter().enumerate() {
            for (ri, &rate) in rates.iter().enumerate() {
                let direct = average(
                    &seeds.iter().map(|&s| run_one(NoiTopology::Mesh, kind, cfg_of(rate, s))).collect::<Vec<_>>(),
                );
                assert_eq!(grid[ki][ri].throughput_jobs_s, direct.throughput_jobs_s);
                assert_eq!(grid[ki][ri].mean_energy_j, direct.mean_energy_j);
                assert_eq!(grid[ki][ri].scheduler, direct.scheduler);
            }
        }
    }
}
