//! Report helpers: aligned console tables (the rows the paper's tables
//! print) and CSV export under `results/`.

use crate::sim::SimResult;
use crate::util::json::Json;
use std::fmt::Write as _;

/// Schema tag stamped into every `results/BENCH_*.json`; bump when a
/// bench changes the meaning (not just the set) of its fields.
pub const BENCH_SCHEMA_VERSION: &str = "thermos-bench/v1";

/// Write a bench result as `results/BENCH_<name>.json`, prefixed with
/// the schema version and bench name so downstream tooling can reject
/// files it does not understand.
pub fn write_bench_json(name: &str, fields: Vec<(&str, Json)>) -> std::io::Result<String> {
    std::fs::create_dir_all("results")?;
    let path = format!("results/BENCH_{name}.json");
    let mut pairs: Vec<(&str, Json)> = vec![
        ("schema", Json::Str(BENCH_SCHEMA_VERSION.to_string())),
        ("bench", Json::Str(name.to_string())),
    ];
    pairs.extend(fields);
    std::fs::write(&path, Json::obj(pairs).to_string_pretty())?;
    Ok(path)
}

/// A simple aligned table builder.
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "{:<width$}  ", c, width = widths[i]);
            }
            out.push('\n');
        };
        line(&mut out, &self.headers);
        let total: usize = widths.iter().sum::<usize>() + 2 * widths.len();
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    /// Write as CSV to `results/<name>.csv`.
    pub fn write_csv(&self, name: &str) -> std::io::Result<String> {
        std::fs::create_dir_all("results")?;
        let path = format!("results/{name}.csv");
        let mut s = self.headers.join(",");
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.join(","));
            s.push('\n');
        }
        std::fs::write(&path, s)?;
        Ok(path)
    }
}

pub fn fmt(v: f64, digits: usize) -> String {
    format!("{v:.digits$}")
}

/// Standard metric row for a SimResult.
pub fn result_cells(rate: f64, r: &SimResult) -> Vec<String> {
    vec![
        r.scheduler.clone(),
        fmt(rate, 2),
        fmt(r.throughput_jobs_s, 3),
        fmt(r.mean_exec_s, 3),
        fmt(r.mean_e2e_s, 3),
        fmt(r.mean_energy_j, 4),
        fmt(r.mean_edp, 4),
        fmt(r.max_temp_k, 1),
        r.throttle_events.to_string(),
    ]
}

pub const RESULT_HEADERS: [&str; 9] = [
    "scheduler", "admit_rate", "throughput", "exec_s", "e2e_s", "energy_j", "edp", "max_temp_k",
    "throttles",
];

/// Percentage improvement of `ours` vs `base` where smaller is better
/// (the paper's Table 5 convention: (base − ours) / ours × 100).
pub fn pct_improvement(base: f64, ours: f64) -> f64 {
    (base - ours) / ours * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "bcd"]);
        t.row(vec!["xx".into(), "1".into()]);
        t.row(vec!["y".into(), "23456".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("a "));
        assert!(lines[2].starts_with("xx"));
    }

    #[test]
    fn bench_json_is_schema_versioned() {
        let path = write_bench_json("_schema_selftest", vec![("x", Json::Num(1.0))])
            .expect("write bench json");
        let text = std::fs::read_to_string(&path).expect("read bench json back");
        let j = Json::parse(&text).expect("bench json parses");
        assert_eq!(j.get("schema").as_str(), Some(BENCH_SCHEMA_VERSION));
        assert_eq!(j.get("bench").as_str(), Some("_schema_selftest"));
        assert_eq!(j.get("x").as_f64(), Some(1.0));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn improvement_math() {
        // base 2x ours => 100% improvement.
        assert!((pct_improvement(2.0, 1.0) - 100.0).abs() < 1e-12);
        assert!(pct_improvement(1.0, 2.0) < 0.0);
    }
}
