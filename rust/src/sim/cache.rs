//! Shared memoization of [`ExecProfile`] computation.
//!
//! `ExecProfile::compute` is deterministic in `(model, mapping)` — the
//! architecture and compute model are fixed for a run — so recurring
//! models mapped onto the same chiplet set produce byte-identical
//! profiles. The cache keys on an FNV-1a fingerprint of the model name
//! plus every `(chiplet, bits)` part of the mapping, and is shared
//! read-mostly across cluster shards behind an `RwLock` (all shards of a
//! cluster instantiate the same `Arch`, so profiles are interchangeable).
//!
//! Hit/miss counters are atomics whose split between shards depends on
//! thread interleaving; they are surfaced for observability but MUST be
//! kept out of any digested report (the cached profiles themselves are
//! deterministic, so simulation results are unaffected).

use super::mapping::{ExecProfile, Mapping};
use crate::arch::Arch;
use crate::pim::ComputeModel;
use crate::util::stats::Fnv64;
use crate::workload::Dcg;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

struct CacheInner {
    map: RwLock<HashMap<u64, Arc<ExecProfile>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

/// Cheaply clonable handle to a shared profile memo table.
#[derive(Clone)]
pub struct ProfileCache {
    inner: Arc<CacheInner>,
}

impl ProfileCache {
    pub fn new() -> ProfileCache {
        ProfileCache {
            inner: Arc::new(CacheInner {
                map: RwLock::new(HashMap::new()),
                hits: AtomicU64::new(0),
                misses: AtomicU64::new(0),
            }),
        }
    }

    /// Fingerprint of a (model, mapping) pair: the model name and the
    /// exact `(chiplet, bits)` split of every layer.
    pub fn key(dcg: &Dcg, mapping: &Mapping) -> u64 {
        let mut h = Fnv64::new();
        h.write(dcg.model.name().as_bytes());
        for la in &mapping.layers {
            h.write_u64(u64::MAX); // layer delimiter
            for &(c, b) in &la.parts {
                h.write_u64(c as u64);
                h.write_u64(b);
            }
        }
        h.finish()
    }

    /// Return the memoized profile for this (model, mapping) pair, or
    /// compute and insert it. Racing inserts of the same key are benign:
    /// both sides compute identical profiles. The engine keeps the
    /// returned `Arc` in its `ActiveJob` directly — a cache hit costs a
    /// refcount bump, never a deep clone of the per-stage vectors.
    pub fn get_or_compute(
        &self,
        arch: &Arch,
        cm: &ComputeModel,
        dcg: &Dcg,
        mapping: &Mapping,
    ) -> Arc<ExecProfile> {
        let key = Self::key(dcg, mapping);
        if let Some(p) = self.inner.map.read().unwrap().get(&key) {
            self.inner.hits.fetch_add(1, Ordering::Relaxed);
            return p.clone();
        }
        self.inner.misses.fetch_add(1, Ordering::Relaxed);
        let p = Arc::new(ExecProfile::compute(arch, cm, dcg, mapping));
        self.inner.map.write().unwrap().entry(key).or_insert_with(|| p.clone());
        p
    }

    /// (hits, misses) — observability only; the split is
    /// thread-interleaving-dependent, keep it out of digested reports.
    pub fn stats(&self) -> (u64, u64) {
        (
            self.inner.hits.load(Ordering::Relaxed),
            self.inner.misses.load(Ordering::Relaxed),
        )
    }

    pub fn len(&self) -> usize {
        self.inner.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for ProfileCache {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noi::NoiTopology;
    use crate::workload::{DnnModel, ModelZoo};

    fn mapping_all_on(c: usize, dcg: &Dcg) -> Mapping {
        Mapping {
            layers: dcg
                .layers
                .iter()
                .map(|l| super::super::mapping::LayerAssignment {
                    parts: vec![(c, l.weight_bits)],
                })
                .collect(),
        }
    }

    #[test]
    fn cache_hits_on_repeat_and_distinguishes_mappings() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let cm = ComputeModel::default();
        let zoo = ModelZoo::new();
        let dcg = zoo.dcg(DnnModel::ResNet18);
        let m0 = mapping_all_on(0, &dcg);
        let m1 = mapping_all_on(1, &dcg);
        let cache = ProfileCache::new();

        let a = cache.get_or_compute(&arch, &cm, &dcg, &m0);
        let b = cache.get_or_compute(&arch, &cm, &dcg, &m0);
        assert_eq!(cache.stats(), (1, 1));
        assert_eq!(cache.len(), 1);
        assert_eq!(a.frame_latency_s, b.frame_latency_s);

        let c = cache.get_or_compute(&arch, &cm, &dcg, &m1);
        assert_eq!(cache.stats(), (1, 2));
        assert_eq!(cache.len(), 2);
        // Direct computation must agree with the cached value.
        let direct = ExecProfile::compute(&arch, &cm, &dcg, &m1);
        assert_eq!(c.frame_latency_s, direct.frame_latency_s);
        assert_eq!(c.frame_energy_j, direct.frame_energy_j);
    }

    #[test]
    fn key_is_mapping_sensitive() {
        let zoo = ModelZoo::new();
        let dcg = zoo.dcg(DnnModel::ResNet18);
        let m0 = mapping_all_on(0, &dcg);
        let m1 = mapping_all_on(1, &dcg);
        assert_eq!(ProfileCache::key(&dcg, &m0), ProfileCache::key(&dcg, &m0));
        assert_ne!(ProfileCache::key(&dcg, &m0), ProfileCache::key(&dcg, &m1));
        let other = zoo.dcg(DnnModel::MobileNetV3Large);
        let mo = mapping_all_on(0, &other);
        assert_ne!(ProfileCache::key(&dcg, &m0), ProfileCache::key(&other, &mo));
    }
}
