//! Mappings (scheduling policies Ψ for one job) and the deterministic
//! execution profile derived from a mapping: per-frame stage times,
//! pipeline bottleneck, and energy — the quantities both the simulator
//! and the primary reward (§4.3.3) are computed from.

use crate::arch::Arch;
use crate::pim::ComputeModel;
use crate::workload::Dcg;

/// Weight placement of one neural layer: `(chiplet id, weight bits)`
/// parts. Σ parts == the layer's `weight_bits` for a complete assignment.
#[derive(Clone, Debug, Default)]
pub struct LayerAssignment {
    pub parts: Vec<(usize, u64)>,
}

impl LayerAssignment {
    pub fn total_bits(&self) -> u64 {
        self.parts.iter().map(|&(_, b)| b).sum()
    }
}

/// Scheduling decision for an entire job (Ψ = ⋃ ψ_i, Algorithm 1 line 13).
#[derive(Clone, Debug, Default)]
pub struct Mapping {
    pub layers: Vec<LayerAssignment>,
}

impl Mapping {
    /// Bits placed per chiplet (for memory commit/release).
    pub fn bits_per_chiplet(&self, n_chiplets: usize) -> Vec<u64> {
        let mut v = vec![0u64; n_chiplets];
        for la in &self.layers {
            for &(c, b) in &la.parts {
                v[c] += b;
            }
        }
        v
    }

    /// Distinct chiplets used.
    pub fn chiplets_used(&self) -> Vec<usize> {
        let mut used: Vec<usize> = self
            .layers
            .iter()
            .flat_map(|la| la.parts.iter().map(|&(c, _)| c))
            .collect();
        used.sort_unstable();
        used.dedup();
        used
    }
}

/// Per-layer deterministic execution figures for one frame.
#[derive(Clone, Debug)]
pub struct StageProfile {
    /// Compute time of the slowest part (parts run in parallel).
    pub compute_s: f64,
    /// NoI transfer time of the layer's input activations.
    pub comm_s: f64,
    /// Dynamic compute energy of all parts.
    pub compute_j: f64,
    /// NoI transfer energy of the input activations.
    pub comm_j: f64,
}

impl StageProfile {
    pub fn stage_s(&self) -> f64 {
        self.compute_s + self.comm_s
    }
}

/// Deterministic (no-throttle) execution profile of a mapped job —
/// weight-stationary pipeline over the stream of frames (§3.3).
#[derive(Clone, Debug)]
pub struct ExecProfile {
    pub stages: Vec<StageProfile>,
    /// Pipeline fill latency: Σ stage times (s/frame).
    pub frame_latency_s: f64,
    /// Pipeline bottleneck: max stage time — steady-state seconds/frame.
    pub bottleneck_s: f64,
    /// Dynamic energy (compute + comm) per frame (J).
    pub frame_energy_j: f64,
    /// One-time weight programming: time at the shared I/O and energy.
    pub load_time_s: f64,
    pub load_energy_j: f64,
    /// Per-chiplet MACs per frame (for runtime power computation).
    pub macs_per_chiplet_frame: Vec<f64>,
}

impl ExecProfile {
    /// Ideal execution time for `frames` inputs: weight load + pipeline
    /// fill + steady-state streaming.
    pub fn ideal_exec_s(&self, frames: u64) -> f64 {
        if frames == 0 {
            return self.load_time_s;
        }
        self.load_time_s + self.frame_latency_s + (frames - 1) as f64 * self.bottleneck_s
    }

    /// Ideal dynamic energy for `frames` inputs (leakage is accounted at
    /// runtime because it depends on wall-clock residency).
    pub fn ideal_dynamic_j(&self, frames: u64) -> f64 {
        self.load_energy_j + frames as f64 * self.frame_energy_j
    }

    /// Build the profile for `dcg` under `mapping` on `arch`.
    ///
    /// Communication model: the activations into layer i (volume
    /// `dcg.in_bits(i)`) travel from the producer parts to the consumer
    /// parts; cost uses the share-weighted mean hop count
    /// `h̄ = Σ_s Σ_d w_s·w_d·hops(s,d)` — the same weighted-distance notion
    /// the proximity algorithm (§4.4) minimizes.
    pub fn compute(arch: &Arch, cm: &ComputeModel, dcg: &Dcg, mapping: &Mapping) -> ExecProfile {
        assert_eq!(mapping.layers.len(), dcg.num_layers(), "mapping must cover all layers");
        let link = &arch.topology.link;
        let mut stages = Vec::with_capacity(dcg.num_layers());
        let mut macs_per_chiplet = vec![0.0f64; arch.num_chiplets()];
        let mut load_time_s = 0.0;
        let mut load_energy_j = 0.0;

        for (i, layer) in dcg.layers.iter().enumerate() {
            let parts = &mapping.layers[i].parts;
            debug_assert!(!parts.is_empty(), "layer {i} unassigned");
            let total_bits = mapping.layers[i].total_bits().max(1) as f64;

            // Compute: parts execute in parallel; MACs split ∝ weight share.
            let mut compute_s: f64 = 0.0;
            let mut compute_j = 0.0;
            for &(c, bits) in parts {
                let share = bits as f64 / total_bits;
                let macs = layer.macs as f64 * share;
                let spec = arch.spec(c);
                compute_s = compute_s.max(cm.mac_time_s(spec, macs));
                compute_j += cm.mac_energy_j(spec, macs);
                macs_per_chiplet[c] += macs;
                let (lt, le) = cm.weight_load(spec, bits as f64);
                load_time_s += lt;
                load_energy_j += le;
            }

            // Communication: share-weighted mean hops from producers.
            let in_bits = dcg.in_bits(i) as f64;
            let mean_hops = if i == 0 {
                // From the I/O boundary: approximate with distance from
                // chiplet 0's corner — one traversal of the mean position.
                let h: f64 = parts
                    .iter()
                    .map(|&(c, b)| {
                        arch.hops(0, c) as f64 * b as f64 / total_bits
                    })
                    .sum();
                h
            } else {
                let prev = &mapping.layers[i - 1].parts;
                let prev_total = mapping.layers[i - 1].total_bits().max(1) as f64;
                let mut h = 0.0;
                for &(s, sb) in prev {
                    for &(d, db) in parts {
                        h += (sb as f64 / prev_total)
                            * (db as f64 / total_bits)
                            * arch.hops(s, d) as f64;
                    }
                }
                h
            };
            let comm_s = link.transfer_time_s(in_bits, mean_hops.ceil() as u32);
            let comm_j = in_bits * mean_hops * link.energy_per_bit_hop_j;
            stages.push(StageProfile { compute_s, comm_s, compute_j, comm_j });
        }

        let frame_latency_s = stages.iter().map(|s| s.stage_s()).sum();
        let bottleneck_s =
            stages.iter().map(|s| s.stage_s()).fold(0.0f64, f64::max);
        let frame_energy_j =
            stages.iter().map(|s| s.compute_j + s.comm_j).sum();
        ExecProfile {
            stages,
            frame_latency_s,
            bottleneck_s,
            frame_energy_j,
            load_time_s,
            load_energy_j,
            macs_per_chiplet_frame: macs_per_chiplet,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Arch;
    use crate::noi::NoiTopology;
    use crate::workload::{DnnModel, ModelZoo};

    fn setup() -> (Arch, ComputeModel, Dcg) {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let zoo = ModelZoo::new();
        (arch, ComputeModel::default(), zoo.dcg(DnnModel::ResNet50))
    }

    /// Everything on one fast chiplet type vs spread across one slow type.
    fn single_cluster_mapping(arch: &Arch, dcg: &Dcg, cluster: usize) -> Mapping {
        // Fill chiplets of `cluster` round-robin, capacity-bounded.
        let ids = &arch.clusters[cluster];
        let cap = arch.specs[cluster].mem_bits;
        assert!(
            dcg.total_weight_bits() <= cap * ids.len() as u64,
            "model does not fit cluster {cluster}"
        );
        let mut free: Vec<u64> = vec![cap; ids.len()];
        let mut layers = Vec::new();
        let mut k = 0usize;
        for l in &dcg.layers {
            let mut need = l.weight_bits;
            let mut parts = Vec::new();
            while need > 0 {
                let idx = k % ids.len();
                if free[idx] == 0 {
                    k += 1;
                    continue;
                }
                let take = need.min(free[idx]);
                parts.push((ids[idx], take));
                free[idx] -= take;
                need -= take;
                if free[idx] == 0 {
                    k += 1;
                }
            }
            layers.push(LayerAssignment { parts });
        }
        Mapping { layers }
    }

    #[test]
    fn profile_pipeline_invariants() {
        let (arch, cm, dcg) = setup();
        let mapping = single_cluster_mapping(&arch, &dcg, 1); // shared-ADC fits AlexNet
        let p = ExecProfile::compute(&arch, &cm, &dcg, &mapping);
        assert_eq!(p.stages.len(), dcg.num_layers());
        assert!(p.bottleneck_s > 0.0);
        assert!(p.frame_latency_s >= p.bottleneck_s);
        let sum: f64 = p.stages.iter().map(|s| s.stage_s()).sum();
        assert!((p.frame_latency_s - sum).abs() < 1e-12);
        // Exec time grows linearly with frames at the bottleneck rate.
        let t100 = p.ideal_exec_s(100);
        let t200 = p.ideal_exec_s(200);
        assert!(((t200 - t100) - 100.0 * p.bottleneck_s).abs() < 1e-9);
    }

    #[test]
    fn standard_cluster_faster_but_hungrier_than_shared_adc() {
        let (arch, cm, _) = setup();
        let zoo = ModelZoo::new();
        // MobileNet fits both the standard and shared-ADC clusters whole.
        let dcg = zoo.dcg(DnnModel::MobileNetV3Large);
        let fast = single_cluster_mapping(&arch, &dcg, 0);
        let eff = single_cluster_mapping(&arch, &dcg, 1);
        let pf = ExecProfile::compute(&arch, &cm, &dcg, &fast);
        let pe = ExecProfile::compute(&arch, &cm, &dcg, &eff);
        assert!(
            pf.frame_energy_j > pe.frame_energy_j,
            "standard {} J vs shared-adc {} J",
            pf.frame_energy_j,
            pe.frame_energy_j
        );
        // Compute-only bottleneck comparison (comm may differ):
        let cf: f64 = pf.stages.iter().map(|s| s.compute_s).fold(0.0, f64::max);
        let ce: f64 = pe.stages.iter().map(|s| s.compute_s).fold(0.0, f64::max);
        assert!(cf < ce, "standard compute {cf} vs shared-adc {ce}");
    }

    #[test]
    fn spreading_a_layer_reduces_compute_time() {
        let (arch, cm, dcg) = setup();
        // Layer fully on one chiplet vs split across two.
        let l0 = &dcg.layers[0];
        let one = Mapping {
            layers: std::iter::once(LayerAssignment { parts: vec![(0, l0.weight_bits)] })
                .chain(dcg.layers[1..].iter().map(|l| LayerAssignment {
                    parts: vec![(1, l.weight_bits)],
                }))
                .collect(),
        };
        let two = Mapping {
            layers: std::iter::once(LayerAssignment {
                parts: vec![(0, l0.weight_bits / 2), (2, l0.weight_bits - l0.weight_bits / 2)],
            })
            .chain(dcg.layers[1..].iter().map(|l| LayerAssignment {
                parts: vec![(1, l.weight_bits)],
            }))
            .collect(),
        };
        let p1 = ExecProfile::compute(&arch, &cm, &dcg, &one);
        let p2 = ExecProfile::compute(&arch, &cm, &dcg, &two);
        assert!(p2.stages[0].compute_s < p1.stages[0].compute_s);
    }

    #[test]
    fn distant_consumer_costs_more_comm() {
        let (arch, cm, dcg) = setup();
        let base: Vec<LayerAssignment> = dcg
            .layers
            .iter()
            .map(|l| LayerAssignment { parts: vec![(0, l.weight_bits)] })
            .collect();
        let mut near = base.clone();
        near[1] = LayerAssignment { parts: vec![(1, dcg.layers[1].weight_bits)] };
        let mut far = base.clone();
        let far_id = arch.num_chiplets() - 1;
        far[1] = LayerAssignment { parts: vec![(far_id, dcg.layers[1].weight_bits)] };
        let pn = ExecProfile::compute(&arch, &cm, &dcg, &Mapping { layers: near });
        let pf = ExecProfile::compute(&arch, &cm, &dcg, &Mapping { layers: far });
        assert!(pf.stages[1].comm_s > pn.stages[1].comm_s);
        assert!(pf.stages[1].comm_j > pn.stages[1].comm_j);
    }

    #[test]
    fn macs_accounting_conserved() {
        let (arch, cm, dcg) = setup();
        let mapping = single_cluster_mapping(&arch, &dcg, 1);
        let p = ExecProfile::compute(&arch, &cm, &dcg, &mapping);
        let total: f64 = p.macs_per_chiplet_frame.iter().sum();
        let expect = dcg.total_macs() as f64;
        assert!((total - expect).abs() / expect < 1e-9);
    }
}
