//! Simulation metrics: per-job statistics and run-level aggregates —
//! the quantities every figure/table of §5 is built from.

use crate::util::json::Json;
use crate::util::stats::mean;
use crate::workload::DnnModel;

/// Per-completed-job record.
#[derive(Clone, Debug)]
pub struct JobStats {
    pub id: u64,
    pub model: DnnModel,
    pub images: u64,
    /// Host arrival time (s).
    pub arrival_s: f64,
    /// Time the scheduler mapped the job (execution start).
    pub mapped_s: f64,
    pub completed_s: f64,
    /// Execution time: mapped → completed (§5.1 definition).
    pub exec_s: f64,
    /// End-to-end latency: arrival → completed (includes queue wait).
    pub e2e_s: f64,
    /// Measured energy: dynamic (compute + comm + weight load) plus the
    /// job's attributed share of leakage over its residency.
    pub energy_j: f64,
    /// Deterministic (no-throttle) execution time — primary reward basis.
    pub ideal_exec_s: f64,
    /// Deterministic dynamic energy — primary reward basis.
    pub ideal_energy_j: f64,
    /// Throttle-induced stall time — secondary reward basis (§4.3.3).
    pub stall_s: f64,
    /// Extra leakage burned while stalled — secondary reward basis.
    pub stall_leak_j: f64,
}

impl JobStats {
    pub fn edp(&self) -> f64 {
        self.exec_s * self.energy_j
    }
}

/// Aggregates over one simulation run.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub scheduler: String,
    /// Jobs completed inside the measurement window.
    pub jobs: Vec<JobStats>,
    /// Achieved throughput: completed jobs / measurement window (DNNs/s).
    pub throughput_jobs_s: f64,
    pub mean_exec_s: f64,
    pub mean_e2e_s: f64,
    pub mean_energy_j: f64,
    /// Mean per-job EDP (J·s).
    pub mean_edp: f64,
    /// Chiplet-seconds spent above T_max during the run.
    pub violation_chiplet_s: f64,
    /// Number of throttle events latched.
    pub throttle_events: u64,
    pub max_temp_k: f64,
    /// Whole-system energy over the measurement window (J).
    pub system_energy_j: f64,
    pub sim_time_s: f64,
    pub host_stalls: u64,
    /// Jobs completed in total (including warm-up).
    pub completed_total: u64,
    /// Optional time trace: (t, per-cluster max temp, queue length,
    /// active jobs).
    pub trace: Vec<TracePoint>,
}

#[derive(Clone, Debug)]
pub struct TracePoint {
    pub t_s: f64,
    pub cluster_max_temp_k: [f64; 4],
    pub queue_len: usize,
    pub active_jobs: usize,
}

impl SimResult {
    pub fn from_jobs(
        scheduler: String,
        jobs: Vec<JobStats>,
        window_s: f64,
    ) -> SimResult {
        let throughput = jobs.len() as f64 / window_s.max(1e-9);
        let exec: Vec<f64> = jobs.iter().map(|j| j.exec_s).collect();
        let e2e: Vec<f64> = jobs.iter().map(|j| j.e2e_s).collect();
        let energy: Vec<f64> = jobs.iter().map(|j| j.energy_j).collect();
        let edp: Vec<f64> = jobs.iter().map(|j| j.edp()).collect();
        SimResult {
            scheduler,
            throughput_jobs_s: throughput,
            mean_exec_s: mean(&exec),
            mean_e2e_s: mean(&e2e),
            mean_energy_j: mean(&energy),
            mean_edp: mean(&edp),
            jobs,
            violation_chiplet_s: 0.0,
            throttle_events: 0,
            max_temp_k: 0.0,
            system_energy_j: 0.0,
            sim_time_s: 0.0,
            host_stalls: 0,
            completed_total: 0,
            trace: Vec::new(),
        }
    }

    /// Compact JSON for results/ files.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("scheduler", Json::Str(self.scheduler.clone())),
            ("throughput_jobs_s", Json::Num(self.throughput_jobs_s)),
            ("mean_exec_s", Json::Num(self.mean_exec_s)),
            ("mean_e2e_s", Json::Num(self.mean_e2e_s)),
            ("mean_energy_j", Json::Num(self.mean_energy_j)),
            ("mean_edp", Json::Num(self.mean_edp)),
            ("violation_chiplet_s", Json::Num(self.violation_chiplet_s)),
            ("throttle_events", Json::Num(self.throttle_events as f64)),
            ("max_temp_k", Json::Num(self.max_temp_k)),
            ("system_energy_j", Json::Num(self.system_energy_j)),
            ("completed", Json::Num(self.jobs.len() as f64)),
            ("host_stalls", Json::Num(self.host_stalls as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn js(exec: f64, energy: f64) -> JobStats {
        JobStats {
            id: 0,
            model: DnnModel::AlexNet,
            images: 10,
            arrival_s: 0.0,
            mapped_s: 1.0,
            completed_s: 1.0 + exec,
            exec_s: exec,
            e2e_s: 1.0 + exec,
            energy_j: energy,
            ideal_exec_s: exec,
            ideal_energy_j: energy,
            stall_s: 0.0,
            stall_leak_j: 0.0,
        }
    }

    #[test]
    fn aggregates() {
        let r = SimResult::from_jobs("x".into(), vec![js(1.0, 2.0), js(3.0, 4.0)], 10.0);
        assert!((r.throughput_jobs_s - 0.2).abs() < 1e-12);
        assert!((r.mean_exec_s - 2.0).abs() < 1e-12);
        assert!((r.mean_energy_j - 3.0).abs() < 1e-12);
        assert!((r.mean_edp - (2.0 + 12.0) / 2.0).abs() < 1e-12);
        let j = r.to_json();
        assert_eq!(j.get("completed").as_usize(), Some(2));
    }
}
