//! Streaming multi-workload simulator (§5.1, Fig. 5).
//!
//! The host dispatches DL jobs at a Poisson admit rate into a FIFO queue
//! (depth 20); the scheduler maps each queue-head job onto the chiplets
//! when memory suffices; mapped jobs execute as weight-stationary
//! pipelines over their image streams while the RC thermal model advances
//! at 100 ms and throttles chiplets that violate Eq. 2. Metrics are
//! collected after a warm-up period.

pub mod cache;
pub mod engine;
pub mod mapping;
pub mod metrics;

pub use cache::ProfileCache;
pub use engine::{SimConfig, Simulator};
pub use mapping::{ExecProfile, LayerAssignment, Mapping};
pub use metrics::{JobStats, SimResult};
