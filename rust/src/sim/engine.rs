//! The time-stepped simulation engine (Fig. 5): host → FIFO queue →
//! scheduler → multi-chiplet PIM execution with thermal feedback.
//!
//! The engine advances at the thermal sampling interval (100 ms) with
//! exact sub-step handling of job phase changes (weight-load completion,
//! job completion). Workloads execute as pipelines whose deterministic
//! profile ([`ExecProfile`]) was computed at mapping time; at runtime only
//! throttle stalls perturb that profile — exactly the split the paper's
//! primary/secondary reward design (§4.3.3) relies on.

use super::cache::ProfileCache;
use super::mapping::{ExecProfile, Mapping};
use super::metrics::{JobStats, SimResult, TracePoint};
use crate::arch::Arch;
use crate::pim::ComputeModel;
use crate::sched::{Scheduler, SysSnapshot};
use crate::thermal::DssModel;
use crate::util::rng::Rng;
use crate::workload::{Job, JobQueue, ModelZoo, TrafficGen, WorkloadMix};
use std::sync::Arc;

#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Host admit rate λ (jobs/s).
    pub admit_rate: f64,
    /// Warm-up before measurement (paper: 60 s).
    pub warmup_s: f64,
    /// Measurement window length.
    pub duration_s: f64,
    /// FIFO depth (Table 4: 20).
    pub queue_capacity: usize,
    /// Size of the random workload mix (paper: 500).
    pub mix_jobs: usize,
    /// Max images per job (paper: 20 000).
    pub max_images: u64,
    pub seed: u64,
    /// Enforce Eq. 2 throttling. Disabled for the §5.3 "unconstrained"
    /// comparison (temperatures are still tracked).
    pub thermal_constraint: bool,
    /// Throttle release hysteresis (K).
    pub hysteresis_k: f64,
    /// Record a time trace (cluster temps, queue depth).
    pub record_trace: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            admit_rate: 2.0,
            warmup_s: 60.0,
            duration_s: 240.0,
            queue_capacity: 20,
            mix_jobs: 500,
            max_images: 20_000,
            seed: 1,
            thermal_constraint: true,
            hysteresis_k: 2.0,
            record_trace: false,
        }
    }
}

impl SimConfig {
    /// CI-scale configuration: small image counts keep runs fast while the
    /// rate/service ratios stay in the paper's operating regime.
    pub fn quick(admit_rate: f64, seed: u64) -> SimConfig {
        SimConfig {
            admit_rate,
            warmup_s: 20.0,
            duration_s: 120.0,
            max_images: 4_000,
            seed,
            ..SimConfig::default()
        }
    }
}

/// Eq. 2 throttle latch with hysteresis: a chiplet throttles when its
/// temperature crosses `t_max`, stays throttled inside the hysteresis band,
/// and releases only below `t_max − hysteresis_k`. Returns the new latch
/// state and whether this update produced a *new* throttle event.
pub fn throttle_latch(latched: bool, t: f64, t_max: f64, hysteresis_k: f64) -> (bool, bool) {
    if !latched && t > t_max {
        (true, true)
    } else if latched && t < t_max - hysteresis_k {
        (false, false)
    } else {
        (latched, false)
    }
}

/// Execution phases of a mapped job. The profile is shared (`Arc`) with
/// the [`ProfileCache`] — mapping a recurring model is a pointer bump, not
/// a deep clone of its per-stage vectors.
struct ActiveJob {
    job: Job,
    profile: Arc<ExecProfile>,
    bits_per_chiplet: Vec<u64>,
    chiplets: Vec<usize>,
    /// Per-chiplet dynamic compute power while streaming (W).
    dyn_power_w: Vec<(usize, f64)>,
    mapped_s: f64,
    load_remaining_s: f64,
    run_total_s: f64,
    run_remaining_s: f64,
    /// Total dynamic energy (incl. comm + load) to attribute over the run.
    dyn_total_j: f64,
    energy_j: f64,
    stall_s: f64,
    stall_leak_j: f64,
}

/// The simulator. Owns system state; generic over the scheduler.
pub struct Simulator<'a, S: Scheduler> {
    pub arch: &'a Arch,
    pub cm: ComputeModel,
    pub sched: S,
    cfg: SimConfig,
    thermal: DssModel,
    free_bits: Vec<u64>,
    throttled: Vec<bool>,
    /// Chiplets forced offline by fault injection (thermal trip): power-
    /// gated, masked out of scheduling, and stalling any job mapped there.
    offline: Vec<bool>,
    temps: Vec<f64>,
    queue: JobQueue,
    backlog: std::collections::VecDeque<Job>,
    /// Internal Poisson source; `None` when the simulator is driven
    /// open-loop by an external ingest source via [`Simulator::inject_job`].
    traffic: Option<TrafficGen>,
    active: Vec<ActiveJob>,
    now: f64,
    completed: Vec<JobStats>,
    violation_chiplet_s: f64,
    throttle_events: u64,
    max_temp_k: f64,
    system_energy_j: f64,
    trace: Vec<TracePoint>,
    /// Package power cap (W). When the previous step's total power
    /// exceeded it, `map_jobs` declines to map new work until power falls
    /// back under the cap (the cluster arbiter's admission-side lever).
    power_cap_w: Option<f64>,
    /// Total package power of the most recent step (W).
    last_power_w: f64,
    /// Whether the cap gated mapping during the most recent step.
    cap_gated: bool,
    /// Steps on which queued work was held back by the power cap.
    cap_gated_steps: u64,
    /// Optional shared (model, mapping) → profile memo table.
    profile_cache: Option<ProfileCache>,
    /// Persistent scheduler-snapshot scratch, refilled in place each
    /// mapping attempt (`Option` so `map_jobs` can detach it from `self`
    /// while the scheduler borrows it).
    snap_scratch: Option<SysSnapshot>,
    /// Persistent per-chiplet step-power buffer (the steady-state step
    /// loop performs no heap allocation).
    power_scratch: Vec<f64>,
    /// Persistent finished-job index scratch for `progress`.
    finished_scratch: Vec<usize>,
    /// Callback invoked when a job is mapped: (job, ideal profile).
    pub on_mapped: Option<Box<dyn FnMut(&Job, &ExecProfile) + 'a>>,
    /// Callback on completion: full stats.
    pub on_completed: Option<Box<dyn FnMut(&JobStats) + 'a>>,
}

impl<'a, S: Scheduler> Simulator<'a, S> {
    pub fn new(arch: &'a Arch, sched: S, cfg: SimConfig) -> Simulator<'a, S> {
        let mut rng = Rng::new(cfg.seed);
        let zoo = ModelZoo::new();
        let mix = WorkloadMix::random(&mut rng, cfg.mix_jobs, cfg.max_images);
        let traffic = TrafficGen::new(mix, zoo, cfg.admit_rate, rng.split());
        Self::build(arch, sched, cfg, Some(traffic))
    }

    /// An open-loop simulator: no internal traffic source — arrivals are
    /// injected per step by the caller (the `serve` subsystem) through
    /// [`Simulator::inject_job`].
    pub fn open_loop(arch: &'a Arch, sched: S, cfg: SimConfig) -> Simulator<'a, S> {
        Self::build(arch, sched, cfg, None)
    }

    fn build(
        arch: &'a Arch,
        sched: S,
        cfg: SimConfig,
        traffic: Option<TrafficGen>,
    ) -> Simulator<'a, S> {
        let thermal = DssModel::from_arch(arch);
        Simulator {
            arch,
            cm: ComputeModel::default(),
            sched,
            thermal,
            free_bits: arch
                .chiplets
                .iter()
                .map(|c| arch.specs[c.pim as usize].mem_bits)
                .collect(),
            throttled: vec![false; arch.num_chiplets()],
            offline: vec![false; arch.num_chiplets()],
            temps: vec![arch.t_ambient; arch.num_chiplets()],
            queue: JobQueue::new(cfg.queue_capacity),
            backlog: Default::default(),
            traffic,
            active: Vec::new(),
            now: 0.0,
            completed: Vec::new(),
            violation_chiplet_s: 0.0,
            throttle_events: 0,
            max_temp_k: arch.t_ambient,
            system_energy_j: 0.0,
            trace: Vec::new(),
            power_cap_w: None,
            last_power_w: 0.0,
            cap_gated: false,
            cap_gated_steps: 0,
            profile_cache: None,
            snap_scratch: Some(SysSnapshot::fresh(arch)),
            power_scratch: vec![0.0; arch.num_chiplets()],
            finished_scratch: Vec::new(),
            cfg,
            on_mapped: None,
            on_completed: None,
        }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Thermal sampling interval — the step size of [`Simulator::step`].
    pub fn dt_s(&self) -> f64 {
        self.thermal.params.dt_s
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Remaining FIFO slots an external driver can fill without pushing
    /// jobs into the (silent) backlog.
    pub fn queue_room(&self) -> usize {
        self.cfg.queue_capacity.saturating_sub(self.queue.len() + self.backlog.len())
    }

    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    /// No queued, backlogged, or running work.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.backlog.is_empty() && self.active.is_empty()
    }

    pub fn temps(&self) -> &[f64] {
        &self.temps
    }

    pub fn throttled(&self) -> &[bool] {
        &self.throttled
    }

    pub fn throttle_events(&self) -> u64 {
        self.throttle_events
    }

    pub fn max_temp_k(&self) -> f64 {
        self.max_temp_k
    }

    pub fn system_energy_j(&self) -> f64 {
        self.system_energy_j
    }

    pub fn host_stalls(&self) -> u64 {
        self.queue.host_stalls
    }

    /// Set (or clear) the package power cap enforced at mapping time.
    pub fn set_power_cap_w(&mut self, cap: Option<f64>) {
        self.power_cap_w = cap;
    }

    /// Total package power of the most recent step (W).
    pub fn power_w(&self) -> f64 {
        self.last_power_w
    }

    /// Whether the power cap gated mapping on the most recent step.
    pub fn cap_gated(&self) -> bool {
        self.cap_gated
    }

    pub fn cap_gated_steps(&self) -> u64 {
        self.cap_gated_steps
    }

    /// Thermal or power pressure: any throttled or tripped-offline chiplet,
    /// or the power cap currently gating admission. The serve layer
    /// consults this for SLO-ordered load shedding.
    pub fn under_pressure(&self) -> bool {
        self.cap_gated
            || self.throttled.iter().any(|&t| t)
            || self.offline.iter().any(|&o| o)
    }

    /// Force a chiplet offline (fault injection: thermal trip) or bring it
    /// back. Offline chiplets are power-gated (no leakage), advertise zero
    /// free memory to the scheduler, and stall any job mapped onto them —
    /// resident weights survive (the PIM arrays are non-volatile), so work
    /// resumes when the chiplet returns.
    pub fn set_chiplet_offline(&mut self, chiplet: usize, off: bool) {
        if chiplet < self.offline.len() {
            self.offline[chiplet] = off;
        }
    }

    /// Chiplets currently forced offline.
    pub fn offline(&self) -> &[bool] {
        &self.offline
    }

    /// Freeze-then-catch-up after a supervisor-detected hang: the engine
    /// made no progress for `gap_s` of cluster time. The clock jumps
    /// forward and every active job books the gap as stall time, so
    /// completion stamps (`mapped + load + run + stall`) stay consistent
    /// with cluster time while no compute or energy accrues.
    pub fn stall_all(&mut self, gap_s: f64) {
        if gap_s <= 0.0 {
            return;
        }
        self.now += gap_s;
        for a in self.active.iter_mut() {
            a.stall_s += gap_s;
        }
    }

    /// Fast-forward the clock to `t_s` (shard restart from checkpoint: the
    /// rebuilt engine must rejoin cluster time, not resume behind it).
    /// Never moves the clock backwards.
    pub fn set_clock_s(&mut self, t_s: f64) {
        if t_s > self.now {
            self.now = t_s;
        }
    }

    /// Share an [`ExecProfile`] memo table (e.g. across cluster shards).
    pub fn set_profile_cache(&mut self, cache: ProfileCache) {
        self.profile_cache = Some(cache);
    }

    /// Inject an externally-generated job (open-loop mode). The job lands
    /// in the backlog and is admitted to the FIFO on the next step; callers
    /// that want explicit backpressure should check [`Simulator::queue_room`]
    /// first.
    pub fn inject_job(&mut self, job: Job) {
        self.backlog.push_back(job);
    }

    /// Refill the scheduler snapshot in place from current system state —
    /// the per-mapping-attempt path allocates nothing.
    fn fill_snapshot(&self, snap: &mut SysSnapshot) {
        snap.free_bits.copy_from_slice(&self.free_bits);
        snap.temps.copy_from_slice(&self.temps);
        snap.throttled.copy_from_slice(&self.throttled);
        // Offline chiplets are invisible capacity: no free memory and
        // permanently "throttled" from the scheduler's point of view.
        for (c, &off) in self.offline.iter().enumerate() {
            if off {
                snap.free_bits[c] = 0;
                snap.throttled[c] = true;
            }
        }
    }

    /// Admit host arrivals; host stalls (backlog) when the FIFO is full.
    fn admit(&mut self) {
        if let Some(traffic) = self.traffic.as_mut() {
            for job in traffic.arrivals_until(self.now) {
                self.backlog.push_back(job);
            }
        }
        while let Some(job) = self.backlog.pop_front() {
            match self.queue.push(job) {
                Ok(()) => {}
                Err(job) => {
                    self.backlog.push_front(job);
                    break;
                }
            }
        }
    }

    /// Map queue-head jobs while the scheduler accepts them (Fig. 5:
    /// "models are mapped continuously until the queue is empty or there
    /// are insufficient resources").
    fn map_jobs(&mut self) {
        // Power-cap admission gate: while the previous step's package
        // power exceeds the arbiter-assigned cap, hold queued work back
        // (running jobs are never interrupted — the cap acts on admission,
        // the thermal throttle latch on execution).
        if let Some(cap) = self.power_cap_w {
            self.cap_gated = self.last_power_w > cap;
            if self.cap_gated {
                if !self.queue.is_empty() {
                    self.cap_gated_steps += 1;
                }
                return;
            }
        } else {
            self.cap_gated = false;
        }
        let mut snap = self.snap_scratch.take().expect("snapshot scratch present");
        while let Some(head) = self.queue.front() {
            self.fill_snapshot(&mut snap);
            let Some(mapping) = self.sched.schedule(head, &snap) else { break };
            let job = self.queue.pop().unwrap();
            self.commit(job, mapping);
        }
        self.snap_scratch = Some(snap);
    }

    fn commit(&mut self, job: Job, mapping: Mapping) {
        // Validate + commit memory.
        let bits = mapping.bits_per_chiplet(self.arch.num_chiplets());
        for (c, &b) in bits.iter().enumerate() {
            assert!(
                b <= self.free_bits[c],
                "scheduler overcommitted chiplet {c}: {b} > {}",
                self.free_bits[c]
            );
            self.free_bits[c] -= b;
        }
        let total_assigned: u64 = bits.iter().sum();
        assert_eq!(total_assigned, job.dcg.total_weight_bits(), "incomplete mapping committed");

        let profile = match &self.profile_cache {
            Some(cache) => cache.get_or_compute(self.arch, &self.cm, &job.dcg, &mapping),
            None => Arc::new(ExecProfile::compute(self.arch, &self.cm, &job.dcg, &mapping)),
        };
        if let Some(cb) = self.on_mapped.as_mut() {
            cb(&job, &profile);
        }
        let run_total_s = profile.frame_latency_s
            + (job.images.saturating_sub(1)) as f64 * profile.bottleneck_s;
        let dyn_total_j = profile.load_energy_j + job.images as f64 * profile.frame_energy_j;
        let chiplets = mapping.chiplets_used();
        let dyn_power_w: Vec<(usize, f64)> = chiplets
            .iter()
            .map(|&c| {
                let e_frame =
                    profile.macs_per_chiplet_frame[c] * self.arch.spec(c).energy_per_mac_j;
                (c, e_frame * (job.images as f64 / run_total_s.max(1e-12)))
            })
            .collect();
        self.active.push(ActiveJob {
            mapped_s: self.now,
            load_remaining_s: profile.load_time_s,
            run_total_s,
            run_remaining_s: run_total_s,
            dyn_total_j,
            energy_j: 0.0,
            stall_s: 0.0,
            stall_leak_j: 0.0,
            bits_per_chiplet: bits,
            chiplets,
            profile,
            job,
            dyn_power_w,
        });
    }

    /// Advance all active jobs by `dt`, with exact sub-step phase changes.
    /// Per-chiplet dynamic power averaged over the step is accumulated into
    /// the persistent `power_scratch` buffer; the steady path allocates
    /// nothing (`finished_scratch` keeps its capacity across steps).
    fn progress(&mut self, dt: f64) {
        for p in self.power_scratch.iter_mut() {
            *p = 0.0;
        }
        let mut finished = std::mem::take(&mut self.finished_scratch);
        finished.clear();
        let power = &mut self.power_scratch;

        for (ai, a) in self.active.iter_mut().enumerate() {
            let mut left = dt;
            // Weight-load phase (streams from I/O; negligible compute power).
            if a.load_remaining_s > 0.0 {
                let used = a.load_remaining_s.min(left);
                a.load_remaining_s -= used;
                left -= used;
                if a.load_remaining_s <= 0.0 {
                    a.energy_j += a.profile.load_energy_j;
                }
            }
            if left <= 0.0 {
                continue;
            }
            // Streaming phase.
            let stalled = a.chiplets.iter().any(|&c| self.throttled[c] || self.offline[c]);
            if stalled {
                a.stall_s += left;
                let leak: f64 = a
                    .chiplets
                    .iter()
                    .filter(|&&c| !self.offline[c])
                    .map(|&c| {
                        let spec = self.arch.spec(c);
                        let share =
                            a.bits_per_chiplet[c] as f64 / spec.mem_bits as f64;
                        spec.leakage_w * share
                    })
                    .sum();
                a.stall_leak_j += leak * left;
            } else {
                let used = a.run_remaining_s.min(left);
                a.run_remaining_s -= used;
                // Dynamic energy ∝ progress; power attribution for thermal.
                let frac = used / a.run_total_s.max(1e-12);
                a.energy_j +=
                    (a.dyn_total_j - a.profile.load_energy_j) * frac;
                for &(c, p) in &a.dyn_power_w {
                    power[c] += p * (used / dt);
                }
                if a.run_remaining_s <= 1e-12 {
                    finished.push(ai);
                }
            }
        }

        // Leakage: every powered chiplet leaks (retention); offline
        // chiplets are power-gated.
        for (c, p) in power.iter_mut().enumerate() {
            if !self.offline[c] {
                *p += self.arch.spec(c).leakage_w;
            }
        }

        // Attribute leakage energy to jobs by resident-bits share (rest is
        // system overhead).
        for a in self.active.iter_mut() {
            let leak: f64 = a
                .chiplets
                .iter()
                .filter(|&&c| !self.offline[c])
                .map(|&c| {
                    let spec = self.arch.spec(c);
                    spec.leakage_w * (a.bits_per_chiplet[c] as f64 / spec.mem_bits as f64)
                })
                .sum();
            a.energy_j += leak * dt;
        }

        // Complete finished jobs (reverse order keeps indices valid).
        for &ai in finished.iter().rev() {
            let a = self.active.swap_remove(ai);
            // Exact sub-step completion time: the job occupied the system
            // for exactly its weight-load time, its deterministic run time,
            // and whatever throttle stalls it accumulated — stamping the
            // step boundary instead would bias latency percentiles by up
            // to dt (100 ms).
            let completed_s = a.mapped_s + a.profile.load_time_s + a.run_total_s + a.stall_s;
            for (c, &b) in a.bits_per_chiplet.iter().enumerate() {
                self.free_bits[c] += b;
            }
            let stats = JobStats {
                id: a.job.id,
                model: a.job.dcg.model,
                images: a.job.images,
                arrival_s: a.job.arrival_s,
                mapped_s: a.mapped_s,
                completed_s,
                exec_s: completed_s - a.mapped_s,
                e2e_s: completed_s - a.job.arrival_s,
                energy_j: a.energy_j,
                ideal_exec_s: a.profile.ideal_exec_s(a.job.images),
                ideal_energy_j: a.profile.ideal_dynamic_j(a.job.images),
                stall_s: a.stall_s,
                stall_leak_j: a.stall_leak_j,
            };
            self.sched.on_job_completed(stats.id);
            if let Some(cb) = self.on_completed.as_mut() {
                cb(&stats);
            }
            self.completed.push(stats);
        }
        self.finished_scratch = finished;
    }

    fn thermal_update(&mut self, dt: f64) {
        self.thermal.step(&self.power_scratch);
        self.thermal.write_die_temps(&mut self.temps);
        for c in 0..self.arch.num_chiplets() {
            let t = self.temps[c];
            self.max_temp_k = self.max_temp_k.max(t);
            let tmax = self.arch.spec(c).t_max_k;
            if t > tmax {
                self.violation_chiplet_s += dt;
            }
            if self.cfg.thermal_constraint {
                let (latched, new_event) =
                    throttle_latch(self.throttled[c], t, tmax, self.cfg.hysteresis_k);
                self.throttled[c] = latched;
                if new_event {
                    self.throttle_events += 1;
                }
            }
        }
    }

    /// One 100 ms step.
    pub fn step(&mut self) {
        let dt = self.thermal.params.dt_s;
        self.now += dt;
        self.admit();
        self.map_jobs();
        self.progress(dt);
        self.last_power_w = self.power_scratch.iter().sum::<f64>();
        self.system_energy_j += self.last_power_w * dt;
        self.thermal_update(dt);
        if self.cfg.record_trace {
            let mut cl_max = [f64::MIN; 4];
            for (c, &t) in self.temps.iter().enumerate() {
                let cl = self.arch.chiplets[c].pim as usize;
                cl_max[cl] = cl_max[cl].max(t);
            }
            self.trace.push(TracePoint {
                t_s: self.now,
                cluster_max_temp_k: cl_max,
                queue_len: self.queue.len(),
                active_jobs: self.active.len(),
            });
        }
    }

    /// Run until the (limited) traffic stream is drained — every admitted
    /// job completed — or `max_s` is reached. Used by training episodes.
    pub fn run_drain(mut self, max_s: f64) -> (SimResult, S) {
        loop {
            self.step();
            let drained =
                self.traffic.as_ref().and_then(|t| t.peek_arrival()).is_none() && self.is_idle();
            if drained || self.now >= max_s {
                break;
            }
        }
        let jobs = std::mem::take(&mut self.completed);
        let window = self.now;
        let mut result = SimResult::from_jobs(self.sched.name().to_string(), jobs, window);
        result.violation_chiplet_s = self.violation_chiplet_s;
        result.throttle_events = self.throttle_events;
        result.max_temp_k = self.max_temp_k;
        result.system_energy_j = self.system_energy_j;
        result.sim_time_s = self.now;
        result.host_stalls = self.queue.host_stalls;
        result.completed_total = result.jobs.len() as u64;
        (result, self.sched)
    }

    /// Cap the traffic stream at `n` jobs (training episodes). No-op in
    /// open-loop mode.
    pub fn limit_jobs(&mut self, n: usize) {
        if let Some(traffic) = self.traffic.as_mut() {
            traffic.set_limit(n);
        }
    }

    /// Run warm-up + measurement; aggregate stats over the window.
    pub fn run(mut self) -> (SimResult, S) {
        let dt = self.thermal.params.dt_s;
        let total = self.cfg.warmup_s + self.cfg.duration_s;
        let steps = (total / dt).ceil() as usize;
        // Reset energy at warm-up boundary.
        let warmup_steps = (self.cfg.warmup_s / dt).ceil() as usize;
        for s in 0..steps {
            if s == warmup_steps {
                self.system_energy_j = 0.0;
            }
            self.step();
        }
        let completed_total = self.completed.len() as u64;
        let window_jobs: Vec<JobStats> = self
            .completed
            .iter()
            .filter(|j| j.completed_s > self.cfg.warmup_s)
            .cloned()
            .collect();
        let mut result = SimResult::from_jobs(
            self.sched.name().to_string(),
            window_jobs,
            self.cfg.duration_s,
        );
        result.violation_chiplet_s = self.violation_chiplet_s;
        result.throttle_events = self.throttle_events;
        result.max_temp_k = self.max_temp_k;
        result.system_energy_j = self.system_energy_j;
        result.sim_time_s = self.now;
        result.host_stalls = self.queue.host_stalls;
        result.completed_total = completed_total;
        result.trace = std::mem::take(&mut self.trace);
        (result, self.sched)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noi::NoiTopology;
    use crate::sched::SimbaSched;

    fn quick_cfg(rate: f64) -> SimConfig {
        SimConfig {
            admit_rate: rate,
            warmup_s: 5.0,
            duration_s: 30.0,
            max_images: 500,
            mix_jobs: 50,
            seed: 42,
            ..SimConfig::default()
        }
    }

    #[test]
    fn simba_completes_jobs_at_low_rate() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let sched = SimbaSched::new(arch.clone());
        let sim = Simulator::new(&arch, sched, quick_cfg(1.0));
        let (r, _) = sim.run();
        assert!(!r.jobs.is_empty(), "no jobs completed");
        assert!(r.throughput_jobs_s > 0.2, "throughput {}", r.throughput_jobs_s);
        for j in &r.jobs {
            assert!(j.exec_s > 0.0);
            assert!(j.e2e_s >= j.exec_s - 1e-9);
            assert!(j.energy_j > 0.0);
            assert!(j.ideal_exec_s > 0.0);
            assert!(j.exec_s >= j.ideal_exec_s * 0.5, "exec_s vs ideal sanity");
        }
        assert!(r.system_energy_j > 0.0);
        assert!(r.max_temp_k >= 300.0);
    }

    #[test]
    fn throughput_saturates_with_rate() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let lo = Simulator::new(&arch, SimbaSched::new(arch.clone()), quick_cfg(0.5))
            .run()
            .0
            .throughput_jobs_s;
        let hi = Simulator::new(&arch, SimbaSched::new(arch.clone()), quick_cfg(8.0))
            .run()
            .0
            .throughput_jobs_s;
        assert!(hi >= lo, "throughput should not fall with admit rate: {lo} vs {hi}");
        // At 8 jobs/s the system must be saturated well below the admit rate.
        assert!(hi < 8.0, "saturation expected, got {hi}");
    }

    #[test]
    fn memory_is_conserved() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let sched = SimbaSched::new(arch.clone());
        let mut sim = Simulator::new(&arch, sched, quick_cfg(2.0));
        let total = arch.total_memory_bits();
        for _ in 0..600 {
            sim.step();
            let free: u64 = sim.free_bits.iter().sum();
            let used: u64 = sim
                .active
                .iter()
                .map(|a| a.bits_per_chiplet.iter().sum::<u64>())
                .sum();
            assert_eq!(free + used, total, "memory leak at t={}", sim.now());
        }
    }

    #[test]
    fn e2e_latency_includes_queue_wait() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let sched = SimbaSched::new(arch.clone());
        let sim = Simulator::new(&arch, sched, quick_cfg(6.0));
        let (r, _) = sim.run();
        // Under heavy load, some jobs must wait: e2e > exec for someone.
        assert!(
            r.jobs.iter().any(|j| j.e2e_s > j.exec_s + 0.2),
            "expected queueing delay at high load"
        );
    }

    #[test]
    fn throttle_latch_engages_on_crossing_t_max() {
        let (latched, event) = throttle_latch(false, 358.2, 358.0, 2.0);
        assert!(latched);
        assert!(event, "crossing t_max must count as a throttle event");
    }

    #[test]
    fn throttle_latch_holds_inside_hysteresis_band() {
        // Anywhere in [t_max − k, t_max] the latch must not release …
        for &t in &[356.0, 356.5, 357.9, 358.0] {
            let (latched, event) = throttle_latch(true, t, 358.0, 2.0);
            assert!(latched, "must stay throttled at {t} K");
            assert!(!event, "no new event while already latched");
        }
        // … and an unlatched chiplet in the band must stay unlatched.
        let (latched, event) = throttle_latch(false, 357.0, 358.0, 2.0);
        assert!(!latched);
        assert!(!event);
    }

    #[test]
    fn throttle_latch_releases_below_band() {
        let (latched, event) = throttle_latch(true, 355.9, 358.0, 2.0);
        assert!(!latched, "must release below t_max − hysteresis");
        assert!(!event);
        // Steady state when cool and unlatched.
        let (latched, event) = throttle_latch(false, 320.0, 358.0, 2.0);
        assert!(!latched);
        assert!(!event);
    }

    #[test]
    fn completion_times_are_not_step_quantized() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let sched = SimbaSched::new(arch.clone());
        let (r, _) = Simulator::new(&arch, sched, quick_cfg(1.0)).run();
        assert!(!r.jobs.is_empty());
        let dt = 0.1;
        // Without throttling, exec time equals the deterministic profile
        // exactly (load + pipeline) rather than a step-boundary stamp.
        for j in r.jobs.iter().filter(|j| j.stall_s == 0.0) {
            assert!(
                (j.exec_s - j.ideal_exec_s).abs() < 1e-9,
                "job {}: exec {} vs ideal {}",
                j.id,
                j.exec_s,
                j.ideal_exec_s
            );
        }
        // And at least some completions land strictly inside a step.
        let off_grid = r.jobs.iter().any(|j| {
            let frac = (j.completed_s / dt).fract();
            frac > 0.01 && frac < 0.99
        });
        assert!(off_grid, "all completion times sit on the 100 ms grid");
    }

    #[test]
    fn open_loop_injection_drives_jobs_to_completion() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let sched = SimbaSched::new(arch.clone());
        let cfg = quick_cfg(1.0);
        let mut sim = Simulator::open_loop(&arch, sched, cfg);
        let zoo = ModelZoo::new();
        assert!(sim.is_idle());
        sim.inject_job(Job {
            id: 7,
            dcg: zoo.dcg(crate::workload::DnnModel::ResNet18),
            images: 200,
            arrival_s: 0.0,
        });
        assert_eq!(sim.queue_room(), 19, "injected job occupies one slot");
        let (r, _) = sim.run_drain(60.0);
        assert_eq!(r.jobs.len(), 1, "injected job must complete");
        assert_eq!(r.jobs[0].id, 7);
        assert!(r.jobs[0].exec_s > 0.0);
    }

    #[test]
    fn power_cap_gates_mapping_until_lifted() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let sched = SimbaSched::new(arch.clone());
        let cfg = quick_cfg(1.0);
        let mut sim = Simulator::open_loop(&arch, sched, cfg);
        // One idle step establishes a nonzero package power (leakage),
        // which an impossible 0 W cap then gates against.
        sim.step();
        assert!(sim.power_w() > 0.0, "leakage power expected");
        sim.set_power_cap_w(Some(0.0));
        let zoo = ModelZoo::new();
        sim.inject_job(Job {
            id: 1,
            dcg: zoo.dcg(crate::workload::DnnModel::ResNet18),
            images: 100,
            arrival_s: 0.0,
        });
        for _ in 0..20 {
            sim.step();
        }
        assert_eq!(sim.active_count(), 0, "cap must hold the job back");
        assert_eq!(sim.queue_len(), 1);
        assert!(sim.cap_gated());
        assert!(sim.cap_gated_steps() > 0);
        assert!(sim.under_pressure());
        // Lifting the cap lets the job map and finish.
        sim.set_power_cap_w(None);
        let (r, _) = sim.run_drain(120.0);
        assert_eq!(r.jobs.len(), 1);
    }

    #[test]
    fn offline_chiplets_block_mapping_until_restored() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let sched = SimbaSched::new(arch.clone());
        let mut sim = Simulator::open_loop(&arch, sched, quick_cfg(1.0));
        for c in 0..arch.num_chiplets() {
            sim.set_chiplet_offline(c, true);
        }
        let zoo = ModelZoo::new();
        sim.inject_job(Job {
            id: 3,
            dcg: zoo.dcg(crate::workload::DnnModel::ResNet18),
            images: 100,
            arrival_s: 0.0,
        });
        for _ in 0..20 {
            sim.step();
        }
        assert_eq!(sim.active_count(), 0, "nothing can map on a dead fabric");
        assert_eq!(sim.queue_len(), 1);
        assert!(sim.under_pressure());
        // Power-gated fabric: package power is exactly zero (no leakage).
        assert_eq!(sim.power_w(), 0.0);
        for c in 0..arch.num_chiplets() {
            sim.set_chiplet_offline(c, false);
        }
        let (r, _) = sim.run_drain(120.0);
        assert_eq!(r.jobs.len(), 1, "job must complete once the fabric returns");
    }

    #[test]
    fn offline_chiplet_stalls_resident_jobs() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let sched = SimbaSched::new(arch.clone());
        let mut sim = Simulator::open_loop(&arch, sched, quick_cfg(1.0));
        let zoo = ModelZoo::new();
        sim.inject_job(Job {
            id: 9,
            dcg: zoo.dcg(crate::workload::DnnModel::ResNet18),
            images: 1000,
            arrival_s: 0.0,
        });
        // Reach the streaming phase (mapped and weights loaded) before
        // tripping, so every faulted step below is a pure stall.
        let mut guard = 0;
        while sim.active_count() == 0 || sim.active[0].load_remaining_s > 0.0 {
            sim.step();
            guard += 1;
            assert!(guard < 10_000, "job never reached the streaming phase");
        }
        let used: Vec<usize> = sim.active[0].chiplets.clone();
        assert!(!used.is_empty());
        for &c in &used {
            sim.set_chiplet_offline(c, true);
        }
        let stall_before = sim.active[0].stall_s;
        let run_before = sim.active[0].run_remaining_s;
        for _ in 0..10 {
            sim.step();
        }
        assert_eq!(sim.active_count(), 1, "job must not finish while tripped");
        assert!(sim.active[0].stall_s > stall_before, "trip must stall the job");
        assert!((sim.active[0].run_remaining_s - run_before).abs() < 1e-12);
        for &c in &used {
            sim.set_chiplet_offline(c, false);
        }
        let (r, _) = sim.run_drain(600.0);
        assert_eq!(r.jobs.len(), 1);
        assert!(r.jobs[0].stall_s >= 1.0 - 1e-9, "10 stalled steps ≥ 1 s of stall");
    }

    #[test]
    fn stall_all_books_hang_time_into_completions() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let sched = SimbaSched::new(arch.clone());
        let mut sim = Simulator::open_loop(&arch, sched, quick_cfg(1.0));
        let zoo = ModelZoo::new();
        sim.inject_job(Job {
            id: 4,
            dcg: zoo.dcg(crate::workload::DnnModel::ResNet18),
            images: 200,
            arrival_s: 0.0,
        });
        while sim.active_count() == 0 {
            sim.step();
        }
        let t0 = sim.now();
        sim.stall_all(5.0);
        assert!((sim.now() - (t0 + 5.0)).abs() < 1e-12);
        let (r, _) = sim.run_drain(120.0);
        assert_eq!(r.jobs.len(), 1);
        assert!(r.jobs[0].stall_s >= 5.0, "hang gap must be booked as stall");
        // Completion stamp is consistent with the shifted clock.
        assert!(r.jobs[0].completed_s >= t0 + 5.0);
    }

    #[test]
    fn set_clock_never_rewinds() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let sched = SimbaSched::new(arch.clone());
        let mut sim = Simulator::open_loop(&arch, sched, quick_cfg(1.0));
        sim.set_clock_s(42.0);
        assert_eq!(sim.now(), 42.0);
        sim.set_clock_s(10.0);
        assert_eq!(sim.now(), 42.0, "clock must be monotonic");
    }

    #[test]
    fn trace_recording() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let sched = SimbaSched::new(arch.clone());
        let mut cfg = quick_cfg(1.0);
        cfg.record_trace = true;
        cfg.warmup_s = 1.0;
        cfg.duration_s = 5.0;
        let (r, _) = Simulator::new(&arch, sched, cfg).run();
        assert_eq!(r.trace.len(), 60);
        for p in &r.trace {
            for cl in 0..4 {
                assert!(p.cluster_max_temp_k[cl] >= 299.0);
            }
        }
    }
}
