//! The time-stepped simulation engine (Fig. 5): host → FIFO queue →
//! scheduler → multi-chiplet PIM execution with thermal feedback.
//!
//! The engine advances at the thermal sampling interval (100 ms) with
//! exact sub-step handling of job phase changes (weight-load completion,
//! job completion). Workloads execute as pipelines whose deterministic
//! profile ([`ExecProfile`]) was computed at mapping time; at runtime only
//! throttle stalls perturb that profile — exactly the split the paper's
//! primary/secondary reward design (§4.3.3) relies on.

use super::mapping::{ExecProfile, Mapping};
use super::metrics::{JobStats, SimResult, TracePoint};
use crate::arch::Arch;
use crate::pim::ComputeModel;
use crate::sched::{Scheduler, SysSnapshot};
use crate::thermal::DssModel;
use crate::util::rng::Rng;
use crate::workload::{Job, JobQueue, ModelZoo, TrafficGen, WorkloadMix};

#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Host admit rate λ (jobs/s).
    pub admit_rate: f64,
    /// Warm-up before measurement (paper: 60 s).
    pub warmup_s: f64,
    /// Measurement window length.
    pub duration_s: f64,
    /// FIFO depth (Table 4: 20).
    pub queue_capacity: usize,
    /// Size of the random workload mix (paper: 500).
    pub mix_jobs: usize,
    /// Max images per job (paper: 20 000).
    pub max_images: u64,
    pub seed: u64,
    /// Enforce Eq. 2 throttling. Disabled for the §5.3 "unconstrained"
    /// comparison (temperatures are still tracked).
    pub thermal_constraint: bool,
    /// Throttle release hysteresis (K).
    pub hysteresis_k: f64,
    /// Record a time trace (cluster temps, queue depth).
    pub record_trace: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            admit_rate: 2.0,
            warmup_s: 60.0,
            duration_s: 240.0,
            queue_capacity: 20,
            mix_jobs: 500,
            max_images: 20_000,
            seed: 1,
            thermal_constraint: true,
            hysteresis_k: 2.0,
            record_trace: false,
        }
    }
}

impl SimConfig {
    /// CI-scale configuration: small image counts keep runs fast while the
    /// rate/service ratios stay in the paper's operating regime.
    pub fn quick(admit_rate: f64, seed: u64) -> SimConfig {
        SimConfig {
            admit_rate,
            warmup_s: 20.0,
            duration_s: 120.0,
            max_images: 4_000,
            seed,
            ..SimConfig::default()
        }
    }
}

/// Execution phases of a mapped job.
struct ActiveJob {
    job: Job,
    profile: ExecProfile,
    bits_per_chiplet: Vec<u64>,
    chiplets: Vec<usize>,
    /// Per-chiplet dynamic compute power while streaming (W).
    dyn_power_w: Vec<(usize, f64)>,
    mapped_s: f64,
    load_remaining_s: f64,
    run_total_s: f64,
    run_remaining_s: f64,
    /// Total dynamic energy (incl. comm + load) to attribute over the run.
    dyn_total_j: f64,
    energy_j: f64,
    stall_s: f64,
    stall_leak_j: f64,
}

/// The simulator. Owns system state; generic over the scheduler.
pub struct Simulator<'a, S: Scheduler> {
    pub arch: &'a Arch,
    pub cm: ComputeModel,
    pub sched: S,
    cfg: SimConfig,
    thermal: DssModel,
    free_bits: Vec<u64>,
    throttled: Vec<bool>,
    temps: Vec<f64>,
    queue: JobQueue,
    backlog: std::collections::VecDeque<Job>,
    traffic: TrafficGen,
    active: Vec<ActiveJob>,
    now: f64,
    completed: Vec<JobStats>,
    violation_chiplet_s: f64,
    throttle_events: u64,
    max_temp_k: f64,
    system_energy_j: f64,
    trace: Vec<TracePoint>,
    /// Callback invoked when a job is mapped: (job, ideal profile).
    pub on_mapped: Option<Box<dyn FnMut(&Job, &ExecProfile) + 'a>>,
    /// Callback on completion: full stats.
    pub on_completed: Option<Box<dyn FnMut(&JobStats) + 'a>>,
}

impl<'a, S: Scheduler> Simulator<'a, S> {
    pub fn new(arch: &'a Arch, sched: S, cfg: SimConfig) -> Simulator<'a, S> {
        let mut rng = Rng::new(cfg.seed);
        let zoo = ModelZoo::new();
        let mix = WorkloadMix::random(&mut rng, cfg.mix_jobs, cfg.max_images);
        let traffic = TrafficGen::new(mix, zoo, cfg.admit_rate, rng.split());
        let thermal = DssModel::from_arch(arch);
        Simulator {
            arch,
            cm: ComputeModel::default(),
            sched,
            thermal,
            free_bits: arch
                .chiplets
                .iter()
                .map(|c| arch.specs[c.pim as usize].mem_bits)
                .collect(),
            throttled: vec![false; arch.num_chiplets()],
            temps: vec![arch.t_ambient; arch.num_chiplets()],
            queue: JobQueue::new(cfg.queue_capacity),
            backlog: Default::default(),
            traffic,
            active: Vec::new(),
            now: 0.0,
            completed: Vec::new(),
            violation_chiplet_s: 0.0,
            throttle_events: 0,
            max_temp_k: arch.t_ambient,
            system_energy_j: 0.0,
            trace: Vec::new(),
            cfg,
            on_mapped: None,
            on_completed: None,
        }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    fn snapshot(&self) -> SysSnapshot {
        SysSnapshot {
            free_bits: self.free_bits.clone(),
            temps: self.temps.clone(),
            throttled: self.throttled.clone(),
        }
    }

    /// Admit host arrivals; host stalls (backlog) when the FIFO is full.
    fn admit(&mut self) {
        for job in self.traffic.arrivals_until(self.now) {
            self.backlog.push_back(job);
        }
        while let Some(job) = self.backlog.pop_front() {
            match self.queue.push(job) {
                Ok(()) => {}
                Err(job) => {
                    self.backlog.push_front(job);
                    break;
                }
            }
        }
    }

    /// Map queue-head jobs while the scheduler accepts them (Fig. 5:
    /// "models are mapped continuously until the queue is empty or there
    /// are insufficient resources").
    fn map_jobs(&mut self) {
        while let Some(head) = self.queue.front() {
            let snap = self.snapshot();
            let Some(mapping) = self.sched.schedule(head, &snap) else { break };
            let job = self.queue.pop().unwrap();
            self.commit(job, mapping);
        }
    }

    fn commit(&mut self, job: Job, mapping: Mapping) {
        // Validate + commit memory.
        let bits = mapping.bits_per_chiplet(self.arch.num_chiplets());
        for (c, &b) in bits.iter().enumerate() {
            assert!(
                b <= self.free_bits[c],
                "scheduler overcommitted chiplet {c}: {b} > {}",
                self.free_bits[c]
            );
            self.free_bits[c] -= b;
        }
        let total_assigned: u64 = bits.iter().sum();
        assert_eq!(total_assigned, job.dcg.total_weight_bits(), "incomplete mapping committed");

        let profile = ExecProfile::compute(self.arch, &self.cm, &job.dcg, &mapping);
        if let Some(cb) = self.on_mapped.as_mut() {
            cb(&job, &profile);
        }
        let run_total_s = profile.frame_latency_s
            + (job.images.saturating_sub(1)) as f64 * profile.bottleneck_s;
        let dyn_total_j = profile.load_energy_j + job.images as f64 * profile.frame_energy_j;
        let chiplets = mapping.chiplets_used();
        let dyn_power_w: Vec<(usize, f64)> = chiplets
            .iter()
            .map(|&c| {
                let e_frame =
                    profile.macs_per_chiplet_frame[c] * self.arch.spec(c).energy_per_mac_j;
                (c, e_frame * (job.images as f64 / run_total_s.max(1e-12)))
            })
            .collect();
        self.active.push(ActiveJob {
            mapped_s: self.now,
            load_remaining_s: profile.load_time_s,
            run_total_s,
            run_remaining_s: run_total_s,
            dyn_total_j,
            energy_j: 0.0,
            stall_s: 0.0,
            stall_leak_j: 0.0,
            bits_per_chiplet: bits,
            chiplets,
            profile,
            job,
            dyn_power_w,
        });
    }

    /// Advance all active jobs by `dt`, with exact sub-step phase changes.
    /// Returns per-chiplet dynamic power averaged over the step.
    fn progress(&mut self, dt: f64) -> Vec<f64> {
        let n = self.arch.num_chiplets();
        let mut power = vec![0.0f64; n];
        let mut finished: Vec<usize> = Vec::new();

        for (ai, a) in self.active.iter_mut().enumerate() {
            let mut left = dt;
            // Weight-load phase (streams from I/O; negligible compute power).
            if a.load_remaining_s > 0.0 {
                let used = a.load_remaining_s.min(left);
                a.load_remaining_s -= used;
                left -= used;
                if a.load_remaining_s <= 0.0 {
                    a.energy_j += a.profile.load_energy_j;
                }
            }
            if left <= 0.0 {
                continue;
            }
            // Streaming phase.
            let stalled = a.chiplets.iter().any(|&c| self.throttled[c]);
            if stalled {
                a.stall_s += left;
                let leak: f64 = a
                    .chiplets
                    .iter()
                    .map(|&c| {
                        let spec = self.arch.spec(c);
                        let share =
                            a.bits_per_chiplet[c] as f64 / spec.mem_bits as f64;
                        spec.leakage_w * share
                    })
                    .sum();
                a.stall_leak_j += leak * left;
            } else {
                let used = a.run_remaining_s.min(left);
                a.run_remaining_s -= used;
                // Dynamic energy ∝ progress; power attribution for thermal.
                let frac = used / a.run_total_s.max(1e-12);
                a.energy_j +=
                    (a.dyn_total_j - a.profile.load_energy_j) * frac;
                for &(c, p) in &a.dyn_power_w {
                    power[c] += p * (used / dt);
                }
                if a.run_remaining_s <= 1e-12 {
                    finished.push(ai);
                }
            }
        }

        // Leakage: every chiplet leaks whenever powered (retention).
        for (c, p) in power.iter_mut().enumerate() {
            *p += self.arch.spec(c).leakage_w;
        }

        // Attribute leakage energy to jobs by resident-bits share (rest is
        // system overhead).
        for a in self.active.iter_mut() {
            let leak: f64 = a
                .chiplets
                .iter()
                .map(|&c| {
                    let spec = self.arch.spec(c);
                    spec.leakage_w * (a.bits_per_chiplet[c] as f64 / spec.mem_bits as f64)
                })
                .sum();
            a.energy_j += leak * dt;
        }

        // Complete finished jobs (reverse order keeps indices valid).
        for &ai in finished.iter().rev() {
            let a = self.active.swap_remove(ai);
            // Exact completion time within the step: remaining run time was
            // consumed somewhere inside [now, now+dt]; approximate with the
            // step end minus the unused remainder (sub-dt accuracy is
            // dominated by dt = 100 ms anyway).
            let completed_s = self.now + dt;
            for (c, &b) in a.bits_per_chiplet.iter().enumerate() {
                self.free_bits[c] += b;
            }
            let stats = JobStats {
                id: a.job.id,
                model: a.job.dcg.model,
                images: a.job.images,
                arrival_s: a.job.arrival_s,
                mapped_s: a.mapped_s,
                completed_s,
                exec_s: completed_s - a.mapped_s,
                e2e_s: completed_s - a.job.arrival_s,
                energy_j: a.energy_j,
                ideal_exec_s: a.profile.ideal_exec_s(a.job.images),
                ideal_energy_j: a.profile.ideal_dynamic_j(a.job.images),
                stall_s: a.stall_s,
                stall_leak_j: a.stall_leak_j,
            };
            self.sched.on_job_completed(stats.id);
            if let Some(cb) = self.on_completed.as_mut() {
                cb(&stats);
            }
            self.completed.push(stats);
        }
        power
    }

    fn thermal_update(&mut self, power: &[f64], dt: f64) {
        self.thermal.step(power);
        for c in 0..self.arch.num_chiplets() {
            let t = self.thermal.temp(c);
            self.temps[c] = t;
            self.max_temp_k = self.max_temp_k.max(t);
            let tmax = self.arch.spec(c).t_max_k;
            if t > tmax {
                self.violation_chiplet_s += dt;
            }
            if self.cfg.thermal_constraint {
                if !self.throttled[c] && t > tmax {
                    self.throttled[c] = true;
                    self.throttle_events += 1;
                } else if self.throttled[c] && t < tmax - self.cfg.hysteresis_k {
                    self.throttled[c] = false;
                }
            }
        }
    }

    /// One 100 ms step.
    pub fn step(&mut self) {
        let dt = self.thermal.params.dt_s;
        self.now += dt;
        self.admit();
        self.map_jobs();
        let power = self.progress(dt);
        self.system_energy_j += power.iter().sum::<f64>() * dt;
        self.thermal_update(&power, dt);
        if self.cfg.record_trace {
            let mut cl_max = [f64::MIN; 4];
            for (c, &t) in self.temps.iter().enumerate() {
                let cl = self.arch.chiplets[c].pim as usize;
                cl_max[cl] = cl_max[cl].max(t);
            }
            self.trace.push(TracePoint {
                t_s: self.now,
                cluster_max_temp_k: cl_max,
                queue_len: self.queue.len(),
                active_jobs: self.active.len(),
            });
        }
    }

    /// Run until the (limited) traffic stream is drained — every admitted
    /// job completed — or `max_s` is reached. Used by training episodes.
    pub fn run_drain(mut self, max_s: f64) -> (SimResult, S) {
        loop {
            self.step();
            let drained = self.traffic.peek_arrival().is_none()
                && self.queue.is_empty()
                && self.backlog.is_empty()
                && self.active.is_empty();
            if drained || self.now >= max_s {
                break;
            }
        }
        let jobs = std::mem::take(&mut self.completed);
        let window = self.now;
        let mut result = SimResult::from_jobs(self.sched.name().to_string(), jobs, window);
        result.violation_chiplet_s = self.violation_chiplet_s;
        result.throttle_events = self.throttle_events;
        result.max_temp_k = self.max_temp_k;
        result.system_energy_j = self.system_energy_j;
        result.sim_time_s = self.now;
        result.host_stalls = self.queue.host_stalls;
        result.completed_total = result.jobs.len() as u64;
        (result, self.sched)
    }

    /// Cap the traffic stream at `n` jobs (training episodes).
    pub fn limit_jobs(&mut self, n: usize) {
        let t = self.traffic.clone().with_limit(n);
        self.traffic = t;
    }

    /// Run warm-up + measurement; aggregate stats over the window.
    pub fn run(mut self) -> (SimResult, S) {
        let dt = self.thermal.params.dt_s;
        let total = self.cfg.warmup_s + self.cfg.duration_s;
        let steps = (total / dt).ceil() as usize;
        // Reset energy at warm-up boundary.
        let warmup_steps = (self.cfg.warmup_s / dt).ceil() as usize;
        for s in 0..steps {
            if s == warmup_steps {
                self.system_energy_j = 0.0;
            }
            self.step();
        }
        let completed_total = self.completed.len() as u64;
        let window_jobs: Vec<JobStats> = self
            .completed
            .iter()
            .filter(|j| j.completed_s > self.cfg.warmup_s)
            .cloned()
            .collect();
        let mut result = SimResult::from_jobs(
            self.sched.name().to_string(),
            window_jobs,
            self.cfg.duration_s,
        );
        result.violation_chiplet_s = self.violation_chiplet_s;
        result.throttle_events = self.throttle_events;
        result.max_temp_k = self.max_temp_k;
        result.system_energy_j = self.system_energy_j;
        result.sim_time_s = self.now;
        result.host_stalls = self.queue.host_stalls;
        result.completed_total = completed_total;
        result.trace = std::mem::take(&mut self.trace);
        (result, self.sched)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noi::NoiTopology;
    use crate::sched::SimbaSched;

    fn quick_cfg(rate: f64) -> SimConfig {
        SimConfig {
            admit_rate: rate,
            warmup_s: 5.0,
            duration_s: 30.0,
            max_images: 500,
            mix_jobs: 50,
            seed: 42,
            ..SimConfig::default()
        }
    }

    #[test]
    fn simba_completes_jobs_at_low_rate() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let sched = SimbaSched::new(arch.clone());
        let sim = Simulator::new(&arch, sched, quick_cfg(1.0));
        let (r, _) = sim.run();
        assert!(!r.jobs.is_empty(), "no jobs completed");
        assert!(r.throughput_jobs_s > 0.2, "throughput {}", r.throughput_jobs_s);
        for j in &r.jobs {
            assert!(j.exec_s > 0.0);
            assert!(j.e2e_s >= j.exec_s - 1e-9);
            assert!(j.energy_j > 0.0);
            assert!(j.ideal_exec_s > 0.0);
            assert!(j.exec_s >= j.ideal_exec_s * 0.5, "exec_s vs ideal sanity");
        }
        assert!(r.system_energy_j > 0.0);
        assert!(r.max_temp_k >= 300.0);
    }

    #[test]
    fn throughput_saturates_with_rate() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let lo = Simulator::new(&arch, SimbaSched::new(arch.clone()), quick_cfg(0.5))
            .run()
            .0
            .throughput_jobs_s;
        let hi = Simulator::new(&arch, SimbaSched::new(arch.clone()), quick_cfg(8.0))
            .run()
            .0
            .throughput_jobs_s;
        assert!(hi >= lo, "throughput should not fall with admit rate: {lo} vs {hi}");
        // At 8 jobs/s the system must be saturated well below the admit rate.
        assert!(hi < 8.0, "saturation expected, got {hi}");
    }

    #[test]
    fn memory_is_conserved() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let sched = SimbaSched::new(arch.clone());
        let mut sim = Simulator::new(&arch, sched, quick_cfg(2.0));
        let total = arch.total_memory_bits();
        for _ in 0..600 {
            sim.step();
            let free: u64 = sim.free_bits.iter().sum();
            let used: u64 = sim
                .active
                .iter()
                .map(|a| a.bits_per_chiplet.iter().sum::<u64>())
                .sum();
            assert_eq!(free + used, total, "memory leak at t={}", sim.now());
        }
    }

    #[test]
    fn e2e_latency_includes_queue_wait() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let sched = SimbaSched::new(arch.clone());
        let sim = Simulator::new(&arch, sched, quick_cfg(6.0));
        let (r, _) = sim.run();
        // Under heavy load, some jobs must wait: e2e > exec for someone.
        assert!(
            r.jobs.iter().any(|j| j.e2e_s > j.exec_s + 0.2),
            "expected queueing delay at high load"
        );
    }

    #[test]
    fn trace_recording() {
        let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
        let sched = SimbaSched::new(arch.clone());
        let mut cfg = quick_cfg(1.0);
        cfg.record_trace = true;
        cfg.warmup_s = 1.0;
        cfg.duration_s = 5.0;
        let (r, _) = Simulator::new(&arch, sched, cfg).run();
        assert_eq!(r.trace.len(), 60);
        for p in &r.trace {
            for cl in 0..4 {
                assert!(p.cluster_max_temp_k[cl] >= 299.0);
            }
        }
    }
}
