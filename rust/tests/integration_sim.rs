//! Whole-stack simulation integration: every scheduler drives the
//! streaming simulator end-to-end on the paper system; the THERMOS
//! scheduler additionally runs with the policy evaluated through the
//! PJRT artifact (the canonical request path).

use thermos::arch::Arch;
use thermos::experiments::{run_one, SchedKind};
use thermos::noi::NoiTopology;
#[cfg(feature = "pjrt")]
use thermos::runtime::Runtime;
use thermos::sched::policy::NativeDdt;
#[cfg(feature = "pjrt")]
use thermos::sched::state::StateEncoder;
use thermos::sched::state::{NUM_CLUSTERS, STATE_DIM};
#[cfg(feature = "pjrt")]
use thermos::sched::thermos::ThermosSched;
use thermos::sim::{SimConfig, Simulator};
use thermos::util::rng::Rng;
#[cfg(feature = "pjrt")]
use thermos::workload::ModelZoo;

fn quick_cfg(rate: f64) -> SimConfig {
    SimConfig {
        admit_rate: rate,
        warmup_s: 5.0,
        duration_s: 40.0,
        max_images: 600,
        mix_jobs: 60,
        seed: 77,
        ..SimConfig::default()
    }
}

#[test]
fn all_schedulers_complete_jobs() {
    let mut rng = Rng::new(9);
    let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
    let theta = NativeDdt::init(STATE_DIM, NUM_CLUSTERS, &mut rng).theta;
    let actor = thermos::sched::policy::NativeMlp::init(
        vec![
            thermos::sched::state::relmas_obs_dim(arch.num_chiplets()),
            128,
            128,
            arch.num_chiplets(),
        ],
        &mut rng,
    )
    .params;
    let kinds = vec![
        SchedKind::Simba,
        SchedKind::BigLittle,
        SchedKind::Relmas { actor },
        SchedKind::Thermos { theta, pref: [0.5, 0.5], label: "balanced" },
    ];
    for kind in kinds {
        let r = run_one(NoiTopology::Mesh, &kind, quick_cfg(1.5));
        assert!(
            !r.jobs.is_empty(),
            "{} completed no jobs in the window",
            kind.label()
        );
        assert!(r.mean_exec_s > 0.0);
        assert!(r.mean_energy_j > 0.0);
        assert!(r.max_temp_k >= 300.0 && r.max_temp_k < 400.0);
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn thermos_via_pjrt_policy_matches_native_schedule() {
    // The PJRT-backed policy and the native evaluator must produce the
    // SAME mappings (identical argmax decisions) on a deterministic run.
    let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
    let zoo = ModelZoo::new();
    let encoder = StateEncoder::new(&arch, &zoo, 600);
    let mut rng = Rng::new(5);
    let ddt = NativeDdt::init(STATE_DIM, NUM_CLUSTERS, &mut rng);

    let cfg = SimConfig {
        admit_rate: 1.0,
        warmup_s: 0.0,
        duration_s: 30.0,
        max_images: 400,
        mix_jobs: 20,
        seed: 3,
        ..SimConfig::default()
    };

    // Native run.
    let sched_n = ThermosSched::new(arch.clone(), encoder.clone(), ddt.clone(), [0.5, 0.5]);
    let (rn, _) = Simulator::new(&arch, sched_n, cfg.clone()).run();

    // PJRT run (same seed → same traffic → decisions must agree).
    let runtime = Runtime::open_default().expect("make artifacts first");
    let policy = thermos::runtime::PjrtPolicy::new(
        runtime,
        "ddt_policy",
        STATE_DIM,
        NUM_CLUSTERS,
        ddt.theta.clone(),
    )
    .unwrap();
    let sched_p = ThermosSched::new(arch.clone(), encoder, policy, [0.5, 0.5]);
    let (rp, _) = Simulator::new(&arch, sched_p, cfg).run();

    assert_eq!(rn.jobs.len(), rp.jobs.len(), "same completions");
    for (a, b) in rn.jobs.iter().zip(rp.jobs.iter()) {
        assert_eq!(a.id, b.id);
        assert!(
            (a.exec_s - b.exec_s).abs() < 1e-6,
            "job {}: exec {} vs {}",
            a.id,
            a.exec_s,
            b.exec_s
        );
        assert!((a.energy_j - b.energy_j).abs() < 1e-6);
    }
}

#[test]
fn higher_admit_rate_never_reduces_energy_use() {
    // System-level sanity across rates.
    let r1 = run_one(NoiTopology::Mesh, &SchedKind::Simba, quick_cfg(0.5));
    let r2 = run_one(NoiTopology::Mesh, &SchedKind::Simba, quick_cfg(3.0));
    assert!(r2.system_energy_j > r1.system_energy_j * 0.8);
    assert!(r2.throughput_jobs_s >= r1.throughput_jobs_s * 0.9);
}

#[test]
fn thermal_constraint_caps_violations() {
    let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
    let mut uncon = quick_cfg(5.0);
    uncon.thermal_constraint = false;
    uncon.duration_s = 60.0;
    let mut con = uncon.clone();
    con.thermal_constraint = true;
    let (ru, _) =
        Simulator::new(&arch, thermos::sched::SimbaSched::new(arch.clone()), uncon).run();
    let (rc, _) = Simulator::new(&arch, thermos::sched::SimbaSched::new(arch.clone()), con).run();
    // Constrained max temperature must not exceed unconstrained.
    assert!(rc.max_temp_k <= ru.max_temp_k + 1.0);
    // If the unconstrained system violated, the constrained one must
    // violate strictly less.
    if ru.violation_chiplet_s > 1.0 {
        assert!(
            rc.violation_chiplet_s < ru.violation_chiplet_s,
            "constrained {} vs unconstrained {}",
            rc.violation_chiplet_s,
            ru.violation_chiplet_s
        );
    }
}
