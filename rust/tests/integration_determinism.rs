//! Thread-count determinism: the work pool's contract is that sweeps and
//! training rollouts are byte-identical at `--threads 1` and `--threads N`.
//! These tests render results to strings (report rows / Debug forms) and
//! compare them exactly — the same digest-style check the benches rely on.

use thermos::experiments::report::result_cells;
use thermos::experiments::{sweep_averaged, SchedKind};
use thermos::noi::NoiTopology;
use thermos::rl::trainer::{TrainConfig, Trainer, PREFS};
use thermos::sim::SimConfig;
use thermos::util::pool::WorkPool;

fn small_cfg(rate: f64, seed: u64) -> SimConfig {
    SimConfig {
        admit_rate: rate,
        warmup_s: 2.0,
        duration_s: 15.0,
        max_images: 300,
        mix_jobs: 25,
        seed,
        ..SimConfig::default()
    }
}

/// Render a sweep grid the way the fig benches do — formatted report
/// rows — so "byte-identical" means identical printed artifacts.
fn render_grid(grid: &[Vec<thermos::sim::SimResult>], rates: &[f64]) -> String {
    let mut out = String::new();
    for row in grid {
        for (&rate, r) in rates.iter().zip(row) {
            out.push_str(&result_cells(rate, r).join(","));
            out.push('\n');
        }
    }
    out
}

#[test]
fn sweep_is_byte_identical_across_thread_counts() {
    let noi = NoiTopology::Mesh;
    let kinds = [SchedKind::Simba, SchedKind::BigLittle];
    let rates = [1.0, 2.0];
    let seeds = [5u64, 6];

    let serial = sweep_averaged(noi, &kinds, &rates, &seeds, &WorkPool::new(1), small_cfg);
    let pooled = sweep_averaged(noi, &kinds, &rates, &seeds, &WorkPool::new(4), small_cfg);

    let a = render_grid(&serial, &rates);
    let b = render_grid(&pooled, &rates);
    assert!(!a.is_empty());
    assert_eq!(a, b, "sweep output must not depend on the pool width");
}

#[test]
fn training_episode_rollouts_are_byte_identical_across_thread_counts() {
    let cfg = TrainConfig {
        jobs_per_episode: 5,
        max_images: 250,
        episode_max_s: 100.0,
        ..TrainConfig::default()
    };
    let trainer = Trainer::new(cfg);

    let serial = trainer.episode_rollouts(0x7e57_5eed, 2.0, &WorkPool::new(1));
    let pooled = trainer.episode_rollouts(0x7e57_5eed, 2.0, &WorkPool::new(4));

    assert_eq!(serial.len(), PREFS.len());
    assert!(serial.iter().any(|(ts, _, _)| !ts.is_empty()));
    // Transition carries no PartialEq; the Debug form covers every field
    // (states, masks, actions, log-probs, vector rewards).
    assert_eq!(
        format!("{serial:?}"),
        format!("{pooled:?}"),
        "episode rollouts must not depend on the pool width"
    );
}
