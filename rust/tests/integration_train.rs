//! Training-loop integration: a short MORL-PPO run through the AOT update
//! artifact must execute end-to-end, log sane losses, and produce a
//! parameter vector that still drives the scheduler.
#![cfg(feature = "pjrt")]

use thermos::noi::NoiTopology;
use thermos::rl::trainer::{TrainConfig, Trainer};
use thermos::runtime::Runtime;
use thermos::sched::policy::{ddt_theta_len, NativeDdt};
use thermos::sched::state::{NUM_CLUSTERS, STATE_DIM};
use thermos::sched::thermos::ThermosSched;
use thermos::sched::{Scheduler, SysSnapshot};
use thermos::workload::{DnnModel, Job, ModelZoo};

#[test]
fn short_training_run_end_to_end() {
    let mut runtime = Runtime::open_default().expect("make artifacts first");
    let cfg = TrainConfig {
        noi: NoiTopology::Mesh,
        episodes: 2,
        jobs_per_episode: 8,
        max_images: 400,
        episode_max_s: 120.0,
        epochs: 2,
        seed: 13,
        ..TrainConfig::default()
    };
    let mut trainer = Trainer::new(cfg);
    let before = trainer.params.clone();
    let params = trainer.train(&mut runtime).expect("training failed");
    assert_eq!(params.len(), runtime.abi.params_len());
    assert!(trainer.total_env_steps > 50, "steps {}", trainer.total_env_steps);
    assert_eq!(trainer.log.len(), 2);
    for e in &trainer.log {
        assert!(e.value_loss.is_finite());
        assert!(e.entropy.is_finite());
        for r in e.episode_reward {
            assert!(r <= 0.0, "rewards are negative costs: {r}");
        }
    }
    // Parameters moved.
    let delta: f32 =
        params.iter().zip(&before).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max);
    assert!(delta > 0.0, "params did not move");

    // Trained theta still schedules.
    let arch = thermos::arch::Arch::paper_heterogeneous(NoiTopology::Mesh);
    let zoo = ModelZoo::new();
    let encoder = thermos::sched::state::StateEncoder::new(&arch, &zoo, 400);
    let theta = params[..ddt_theta_len(STATE_DIM, NUM_CLUSTERS)].to_vec();
    let mut sched = ThermosSched::new(
        arch.clone(),
        encoder,
        NativeDdt::new(STATE_DIM, NUM_CLUSTERS, theta),
        [0.5, 0.5],
    );
    let job = Job { id: 0, dcg: zoo.dcg(DnnModel::ResNet18), images: 10, arrival_s: 0.0 };
    let snap = SysSnapshot::fresh(&arch);
    let mapping = sched.schedule(&job, &snap).expect("trained policy must map");
    assert_eq!(mapping.layers.len(), job.dcg.num_layers());

    // Log CSV round-trips.
    let path = std::env::temp_dir().join("thermos_train_log_test.csv");
    trainer.write_log_csv(path.to_str().unwrap()).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.lines().count() >= 3);
    std::fs::remove_file(path).ok();
}
