//! Integration: the sharded serving cluster — determinism of the merged
//! fleet report under real threads, throughput scaling with shard count,
//! and the merged-report contract (budget conservation, routing totals).

use thermos::cluster::{run_cluster, ClusterConfig, ClusterReport, ShardSchedSpec};
use thermos::serve::{PoissonSource, ServeConfig};
use thermos::sim::SimConfig;
use thermos::util::json::Json;

const MAX_IMAGES: u64 = 500;

fn cluster_cfg(shards: usize, duration_s: f64, seed: u64) -> ClusterConfig {
    ClusterConfig {
        shards,
        duration_s,
        drain_max_s: 20.0,
        serve: ServeConfig {
            duration_s,
            tenant_queue_cap: 32,
            max_wait_s: 30.0,
            snapshot_every_s: 0.0,
            pressure_depth: 48,
            sim: SimConfig {
                warmup_s: 0.0,
                max_images: MAX_IMAGES,
                seed,
                ..SimConfig::default()
            },
        },
        sched: ShardSchedSpec::Simba,
        ..ClusterConfig::default()
    }
}

fn run(shards: usize, rate: f64, duration_s: f64, seed: u64) -> ClusterReport {
    let cfg = cluster_cfg(shards, duration_s, seed);
    let source = Box::new(PoissonSource::new(rate, 60, MAX_IMAGES, [1.0, 1.0, 1.0], seed));
    run_cluster(cfg, source).expect("cluster run")
}

fn num(j: &Json, key: &str) -> f64 {
    j.get(key).as_f64().unwrap_or(0.0)
}

#[test]
fn four_shard_same_seed_reproduces_merged_digest() {
    let a = run(4, 4.0, 30.0, 42);
    let b = run(4, 4.0, 30.0, 42);
    // Real worker threads, byte-identical fleet telemetry: the epoch
    // barrier + sorted merge make interleaving invisible.
    assert_eq!(
        a.json.to_string_compact(),
        b.json.to_string_compact(),
        "same-seed cluster runs diverged"
    );
    assert_eq!(a.digest, b.digest);
    assert_eq!(a.snapshots.len(), b.snapshots.len());
    assert!(num(&a.json, "completed") > 0.0, "cluster completed no jobs");

    let c = run(4, 4.0, 30.0, 43);
    assert_ne!(a.digest, c.digest, "different seeds must change the digest");
}

#[test]
fn throughput_scales_with_shards() {
    // 8 jobs/s saturates one engine; adding shards adds both compute and
    // power budget, so completed image volume must grow.
    let done: Vec<f64> = [1usize, 2, 4]
        .iter()
        .map(|&s| num(&run(s, 8.0, 40.0, 7).json, "images_done"))
        .collect();
    assert!(done[0] > 0.0, "single shard did no work");
    // Soft monotonicity (routing skew can cost a few percent)...
    assert!(done[1] >= done[0] * 0.95, "2 shards regressed: {done:?}");
    assert!(done[2] >= done[1] * 0.95, "4 shards regressed: {done:?}");
    // ...and strictly more at the endpoints.
    assert!(done[2] > done[0], "sharding did not scale: {done:?}");
}

#[test]
fn merged_report_contract_holds() {
    let r = run(2, 3.0, 20.0, 5);
    let j = &r.json;
    for key in [
        "scheduler",
        "source",
        "shards",
        "offered",
        "coalesced_requests",
        "routed_per_shard",
        "completed",
        "images_done",
        "latency_e2e_s",
        "tenants",
        "power_budget_w",
        "arbiter",
        "shards_detail",
    ] {
        assert!(!matches!(j.get(key), Json::Null), "missing merged field `{key}`");
    }
    // Router conservation: per-shard routed counts sum to offered.
    let routed: f64 = j
        .get("routed_per_shard")
        .as_arr()
        .expect("routed_per_shard array")
        .iter()
        .map(|x| x.as_f64().unwrap())
        .sum();
    assert_eq!(routed, num(j, "offered"));
    // Arbiter conservation: final caps sum to the package budget.
    let caps: f64 = j
        .get("arbiter")
        .get("final_caps_w")
        .as_arr()
        .expect("final_caps_w array")
        .iter()
        .map(|x| x.as_f64().unwrap())
        .sum();
    assert!((caps - num(j, "power_budget_w")).abs() < 1e-6);
    // The epoch barrier ran every epoch.
    assert_eq!(num(j.get("arbiter"), "epochs"), 20.0);
    // Fault-free runs must not carry fault telemetry (digest stability).
    assert!(matches!(j.get("faults"), Json::Null), "fault-free run leaked a `faults` key");
    // Per-shard detail rows agree with the merge.
    let detail_done: f64 = j
        .get("shards_detail")
        .as_arr()
        .expect("shards_detail array")
        .iter()
        .map(|s| num(s, "images_done"))
        .sum();
    assert_eq!(detail_done, num(j, "images_done"));
}
