//! Cross-layer integration: the AOT HLO artifacts (L1 Pallas kernels +
//! L2 jax graphs) executed through PJRT must agree bit-for-bit (to f32
//! tolerance) with the native rust evaluators over the SAME flat
//! parameter layout — closing the ref == pallas == artifact == native
//! loop. Requires `make artifacts` and the `pjrt` feature.
#![cfg(feature = "pjrt")]

use thermos::runtime::{F32Tensor, Runtime};
use thermos::sched::policy::{ddt_theta_len, mlp_param_len, NativeDdt, NativeMlp};
use thermos::sched::state::{NUM_CLUSTERS, STATE_DIM};
use thermos::util::rng::Rng;
use thermos::util::testkit::vec_f32;

fn runtime() -> Runtime {
    Runtime::open_default().expect("run `make artifacts` before integration tests")
}

#[test]
fn abi_matches_rust_constants() {
    let rt = runtime();
    assert_eq!(rt.abi.state_dim, STATE_DIM);
    assert_eq!(rt.abi.num_clusters, NUM_CLUSTERS);
    assert_eq!(rt.abi.theta_len, ddt_theta_len(STATE_DIM, NUM_CLUSTERS));
    assert_eq!(rt.abi.phi_len, mlp_param_len(&rt.abi.critic_dims));
    assert!(rt.abi.artifacts.len() >= 7, "artifacts: {:?}", rt.abi.artifacts);
}

#[test]
fn ddt_artifact_matches_native_eval() {
    let mut rt = runtime();
    let mut rng = Rng::new(101);
    for trial in 0..5 {
        let ddt = NativeDdt::init(STATE_DIM, NUM_CLUSTERS, &mut rng);
        let x = vec_f32(&mut rng, STATE_DIM, -1.5, 1.5);
        let native = ddt.forward(&x);
        let art = rt.artifact("ddt_policy").unwrap();
        let out = art
            .run_f32(&[
                F32Tensor::vec(ddt.theta.clone()),
                F32Tensor::mat(x.clone(), 1, STATE_DIM),
            ])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), NUM_CLUSTERS);
        for (a, b) in native.iter().zip(&out[0]) {
            assert!(
                (a - b).abs() < 1e-4,
                "trial {trial}: native {a} vs artifact {b}"
            );
        }
    }
}

#[test]
fn ddt_batch_artifact_matches_native() {
    let mut rt = runtime();
    let mut rng = Rng::new(202);
    let batch = rt.abi.update_batch;
    let ddt = NativeDdt::init(STATE_DIM, NUM_CLUSTERS, &mut rng);
    let xs: Vec<Vec<f32>> = (0..batch).map(|_| vec_f32(&mut rng, STATE_DIM, -2.0, 2.0)).collect();
    let flat: Vec<f32> = xs.iter().flatten().copied().collect();
    let art = rt.artifact("ddt_policy_b256").unwrap();
    let out = art
        .run_f32(&[F32Tensor::vec(ddt.theta.clone()), F32Tensor::mat(flat, batch, STATE_DIM)])
        .unwrap();
    assert_eq!(out[0].len(), batch * NUM_CLUSTERS);
    for (i, x) in xs.iter().enumerate() {
        let native = ddt.forward(x);
        for a in 0..NUM_CLUSTERS {
            let got = out[0][i * NUM_CLUSTERS + a];
            assert!(
                (native[a] - got).abs() < 1e-4,
                "row {i} action {a}: {} vs {got}",
                native[a]
            );
        }
    }
}

#[test]
fn critic_artifact_matches_native_mlp() {
    let mut rt = runtime();
    let mut rng = Rng::new(303);
    let batch = rt.abi.update_batch;
    let dims = rt.abi.critic_dims.clone();
    let mlp = NativeMlp::init(dims.clone(), &mut rng);
    let xs: Vec<Vec<f32>> = (0..batch).map(|_| vec_f32(&mut rng, STATE_DIM, -1.0, 1.0)).collect();
    let flat: Vec<f32> = xs.iter().flatten().copied().collect();
    let art = rt.artifact("critic_b256").unwrap();
    let out = art
        .run_f32(&[F32Tensor::vec(mlp.params.clone()), F32Tensor::mat(flat, batch, STATE_DIM)])
        .unwrap();
    assert_eq!(out[0].len(), batch * 2);
    for (i, x) in xs.iter().enumerate().step_by(17) {
        let native = mlp.forward(x);
        for k in 0..2 {
            let got = out[0][i * 2 + k];
            // MLP accumulations tolerate slightly looser f32 error.
            assert!(
                (native[k] - got).abs() < 2e-3 * (1.0 + native[k].abs()),
                "row {i} out {k}: {} vs {got}",
                native[k]
            );
        }
    }
}

#[test]
fn relmas_artifact_matches_native() {
    let mut rt = runtime();
    let mut rng = Rng::new(404);
    let dims = rt.abi.relmas_actor_dims.clone();
    let obs = rt.abi.relmas_obs;
    let n = rt.abi.num_chiplets;
    let mlp = NativeMlp::init(dims, &mut rng);
    let x = vec_f32(&mut rng, obs, 0.0, 1.0);
    let native = mlp.forward(&x);
    let art = rt.artifact("relmas_policy").unwrap();
    let out = art
        .run_f32(&[F32Tensor::vec(mlp.params.clone()), F32Tensor::mat(x, 1, obs)])
        .unwrap();
    assert_eq!(out[0].len(), n);
    for (a, b) in native.iter().zip(&out[0]) {
        assert!((a - b).abs() < 2e-3 * (1.0 + a.abs()), "{a} vs {b}");
    }
}

#[test]
fn ppo_update_artifact_steps_and_learns() {
    let mut rt = runtime();
    let mut rng = Rng::new(505);
    let batch = rt.abi.update_batch;
    let plen = rt.abi.params_len();
    let theta_len = rt.abi.theta_len;

    // Init params exactly like the trainer.
    let ddt = NativeDdt::init(STATE_DIM, NUM_CLUSTERS, &mut rng);
    let critic = NativeMlp::init(rt.abi.critic_dims.clone(), &mut rng);
    let mut params: Vec<f32> = ddt.theta.clone();
    params.extend_from_slice(&critic.params);
    assert_eq!(params.len(), plen);

    // Fixed synthetic batch: always action 1 with positive advantage.
    let xs: Vec<f32> = (0..batch * STATE_DIM).map(|i| ((i as f32) * 0.137).sin()).collect();
    let mut a_onehot = vec![0.0f32; batch * NUM_CLUSTERS];
    for row in 0..batch {
        a_onehot[row * NUM_CLUSTERS + 1] = 1.0;
    }
    let mask = vec![1.0f32; batch * NUM_CLUSTERS];
    // logp_old from the native policy (masked softmax, all valid).
    let mut logp_old = Vec::with_capacity(batch);
    for row in 0..batch {
        let x = &xs[row * STATE_DIM..(row + 1) * STATE_DIM];
        let logits = ddt.forward(x);
        let probs =
            thermos::sched::policy::masked_softmax(&logits, &[true; NUM_CLUSTERS]);
        logp_old.push(probs[1].max(1e-12).ln());
    }
    let adv = vec![1.0f32; batch];
    let ret = vec![0.0f32; batch * 2];

    let prob1 = |theta: &[f32]| -> f32 {
        let d = NativeDdt::new(STATE_DIM, NUM_CLUSTERS, theta.to_vec());
        let logits = d.forward(&xs[..STATE_DIM]);
        thermos::sched::policy::masked_softmax(&logits, &[true; NUM_CLUSTERS])[1]
    };
    let p_before = prob1(&params[..theta_len]);

    let mut m = vec![0.0f32; plen];
    let mut v = vec![0.0f32; plen];
    let mut t = 0.0f32;
    for step in 0..10 {
        let art = rt.artifact("ppo_update_thermos").unwrap();
        let out = art
            .run_f32(&[
                F32Tensor::vec(params.clone()),
                F32Tensor::vec(m.clone()),
                F32Tensor::vec(v.clone()),
                F32Tensor::scalar1(t),
                F32Tensor::mat(xs.clone(), batch, STATE_DIM),
                F32Tensor::mat(a_onehot.clone(), batch, NUM_CLUSTERS),
                F32Tensor::mat(mask.clone(), batch, NUM_CLUSTERS),
                F32Tensor::vec(logp_old.clone()),
                F32Tensor::vec(adv.clone()),
                F32Tensor::mat(ret.clone(), batch, 2),
            ])
            .unwrap();
        params = out[0].clone();
        m = out[1].clone();
        v = out[2].clone();
        t = out[3][0];
        for o in &out[4..7] {
            assert!(o[0].is_finite(), "non-finite loss at step {step}");
        }
    }
    assert_eq!(t, 10.0);
    let p_after = prob1(&params[..theta_len]);
    assert!(
        p_after > p_before,
        "positive advantage must raise π(a=1): {p_before} → {p_after}"
    );
}
