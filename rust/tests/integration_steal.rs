//! Integration: deterministic work-stealing between shards — digest
//! reproducibility with stealing on, thread-width invariance, strict
//! drain improvement on a skewed single-model mix, and byte-compat of
//! steal-off runs against the checked-in golden digest.

use thermos::cluster::{run_cluster, ClusterConfig, ShardSchedSpec};
use thermos::serve::{PoissonSource, ServeConfig};
use thermos::sim::SimConfig;
use thermos::util::json::Json;
use thermos::util::testkit::ClusterScenario;
use thermos::workload::DnnModel;

fn num(j: &Json, key: &str) -> f64 {
    j.get(key).as_f64().unwrap_or(0.0)
}

#[test]
fn same_seed_steal_runs_reproduce_digest() {
    for shards in [2usize, 4, 8] {
        let sc = ClusterScenario::new(shards, 42).with_steal(true).with_duration(12.0);
        let a = sc.run();
        let b = sc.run();
        assert_eq!(
            a.json.to_string_compact(),
            b.json.to_string_compact(),
            "same-seed steal runs diverged at {shards} shards"
        );
        assert_eq!(a.digest, b.digest, "digest diverged at {shards} shards");
        // The steal plane is on, so its counters must be in the report
        // (and therefore under the digest).
        let steal = a.json.get("steal");
        assert!(!matches!(steal, Json::Null), "steal run missing `steal` key");
        assert!(num(steal, "migrated_requests") >= 0.0);
        assert!(num(&a.json, "completed") > 0.0, "steal run completed no jobs");
    }
}

#[test]
fn steal_digest_is_invariant_across_thread_widths() {
    let narrow = ClusterScenario::new(4, 7).with_steal(true).with_duration(15.0).with_threads(1);
    let wide = ClusterScenario::new(4, 7).with_steal(true).with_duration(15.0).with_threads(4);
    let a = narrow.run();
    let b = wide.run();
    assert_eq!(a.digest, b.digest, "--threads 1 vs 4 changed the steal digest");
    assert_eq!(a.json.to_string_compact(), b.json.to_string_compact());
}

#[test]
fn stealing_drains_a_skewed_mix_strictly_sooner() {
    // Every request is the same model, so consistent-hash routing piles
    // the whole stream onto one shard; the other three idle. Shedding is
    // off (max_wait 0) and the drain bound generous, so the merged
    // `duration_s` directly measures how late the fleet finished.
    let base = ClusterScenario::new(4, 11)
        .with_hot_model(DnnModel::ResNet50)
        .with_rate(12.0)
        .with_duration(20.0)
        .with_queue_cap(256)
        .with_max_wait(0.0)
        .with_drain_max(120.0);
    let off = base.clone().run();
    let on = base.with_steal(true).run();

    let late_off = num(&off.json, "duration_s") - 20.0;
    let late_on = num(&on.json, "duration_s") - 20.0;
    assert!(late_off > 0.0, "skewed mix did not overrun the horizon (late {late_off:.2}s)");
    assert!(
        late_on < late_off,
        "stealing must finish strictly sooner: on {late_on:.2}s vs off {late_off:.2}s late"
    );
    // And it actually migrated work to get there.
    assert!(num(on.json.get("steal"), "migrated_requests") > 0.0, "no requests migrated");
    assert!(num(on.json.get("steal"), "steal_epochs") > 0.0);
}

#[test]
fn scenario_expansion_matches_a_hand_built_config() {
    // `ClusterScenario::new(4, 42)` documents itself as the canonical
    // cluster config; pin that equivalence so the golden digest below
    // speaks for hand-built configs too.
    let cfg = ClusterConfig {
        shards: 4,
        duration_s: 30.0,
        drain_max_s: 20.0,
        serve: ServeConfig {
            duration_s: 30.0,
            tenant_queue_cap: 32,
            max_wait_s: 30.0,
            snapshot_every_s: 0.0,
            pressure_depth: 48,
            sim: SimConfig { warmup_s: 0.0, max_images: 500, seed: 42, ..SimConfig::default() },
        },
        sched: ShardSchedSpec::Simba,
        ..ClusterConfig::default()
    };
    let source = Box::new(PoissonSource::new(4.0, 60, 500, [1.0, 1.0, 1.0], 42));
    let hand = run_cluster(cfg, source).expect("hand-built cluster run");
    let scenario = ClusterScenario::new(4, 42).run();
    assert_eq!(hand.digest, scenario.digest, "scenario expansion drifted from the raw config");
    assert_eq!(hand.json.to_string_compact(), scenario.json.to_string_compact());
}

#[test]
fn steal_off_matches_the_golden_digest() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden/cluster_steal_off.digest");
    let digest = ClusterScenario::new(4, 42).run().digest;
    let pinned = std::fs::read_to_string(path).expect("read golden digest file");
    let pinned = pinned.trim();
    if pinned.is_empty() || pinned == "UNPINNED" {
        // First run on this toolchain: pin the digest (see golden/README.md).
        std::fs::write(path, format!("{digest}\n")).expect("pin golden digest");
        return;
    }
    assert_eq!(
        digest,
        pinned,
        "steal-off cluster digest moved — steal/standby must be digest-gated when off"
    );
}

#[test]
fn steal_and_spares_report_keys_are_gated() {
    // Off by default: no steal/spares/faults keys (digest stability).
    let plain = ClusterScenario::new(2, 9).with_duration(10.0).run();
    for key in ["steal", "spares", "faults"] {
        assert!(
            matches!(plain.json.get(key), Json::Null),
            "plain run leaked a `{key}` key into the merged report"
        );
    }
    // Spares on: the `spares` block appears, idle spares stay idle when
    // nothing crashes, and the digest differs from the plain run only
    // because the block exists.
    let spared = ClusterScenario::new(2, 9).with_duration(10.0).with_spares(1).run();
    let sp = spared.json.get("spares");
    assert!(!matches!(sp, Json::Null), "spares run missing `spares` key");
    assert_eq!(num(sp, "configured"), 1.0);
    assert_eq!(num(sp, "standby_promotions"), 0.0, "fault-free run promoted a standby");
    assert_eq!(num(sp, "idle_final"), 1.0);
    assert_eq!(num(&spared.json, "completed"), num(&plain.json, "completed"));
}
