//! Serve-subsystem integration: record a Poisson-driven run to an
//! in-memory replay log, then replay it twice — same seed must give a
//! byte-identical final telemetry report (asserted via its digest). Also
//! checks the report carries every field the ops story needs.

use std::sync::{Arc, Mutex};
use thermos::arch::Arch;
use thermos::noi::NoiTopology;
use thermos::sched::policy::NativeDdt;
use thermos::sched::state::{StateEncoder, NUM_CLUSTERS, STATE_DIM};
use thermos::sched::thermos::ThermosSched;
use thermos::serve::{
    PoissonSource, ReplayWriter, ServeConfig, ServeReport, Server, TenantRouter, TraceSource,
};
use thermos::sim::SimConfig;
use thermos::util::json::Json;
use thermos::util::rng::Rng;
use thermos::workload::ModelZoo;

fn serve_cfg(seed: u64) -> ServeConfig {
    ServeConfig {
        duration_s: 60.0,
        tenant_queue_cap: 32,
        max_wait_s: 25.0,
        snapshot_every_s: 20.0,
        pressure_depth: 48,
        sim: SimConfig { warmup_s: 0.0, max_images: 800, seed, ..SimConfig::default() },
    }
}

fn router(arch: &Arch, seed: u64) -> TenantRouter<NativeDdt> {
    let zoo = ModelZoo::new();
    let encoder = StateEncoder::new(arch, &zoo, 800);
    let mut rng = Rng::new(seed);
    let ddt = NativeDdt::init(STATE_DIM, NUM_CLUSTERS, &mut rng);
    TenantRouter::new(ThermosSched::new(arch.clone(), encoder, ddt, [0.5, 0.5]))
}

fn replay_run(arch: &Arch, trace: &str, seed: u64) -> ServeReport {
    let source = Box::new(TraceSource::from_text(trace).expect("parse recorded trace"));
    Server::new(arch, router(arch, seed), source, serve_cfg(seed)).run()
}

#[test]
fn recorded_trace_replays_to_identical_telemetry_digest() {
    let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);

    // Live run: Poisson traffic, recorded to an in-memory replay log
    // (the writer handle is `Send` — cluster shards record the same way).
    let writer = Arc::new(Mutex::new(ReplayWriter::in_memory()));
    let source = Box::new(PoissonSource::new(1.5, 60, 800, [1.0, 1.0, 1.0], 42));
    let live = Server::new(&arch, router(&arch, 42), source, serve_cfg(42))
        .with_replay(writer.clone())
        .run();
    assert!(live.json.get("completed").as_f64().unwrap() > 0.0, "live run completed nothing");

    let trace = Arc::try_unwrap(writer)
        .ok()
        .expect("server must release the replay writer")
        .into_inner()
        .unwrap()
        .into_string()
        .unwrap();
    assert!(trace.lines().any(|l| l.contains("\"ev\":\"req\"")), "log has requests");
    assert!(trace.lines().any(|l| l.contains("\"ev\":\"map\"")), "log has decisions");

    // Replay the recorded stream twice with the same seed.
    let a = replay_run(&arch, &trace, 42);
    let b = replay_run(&arch, &trace, 42);
    assert_eq!(
        a.json.to_string_compact(),
        b.json.to_string_compact(),
        "replay must be byte-identical"
    );
    assert_eq!(a.digest, b.digest);

    // The replay offered exactly the recorded requests.
    let offered_live = live.json.get("offered").as_f64().unwrap();
    assert_eq!(a.json.get("offered").as_f64().unwrap(), offered_live);

    // A different seed perturbs nothing on a trace-driven run with the
    // same scheduler weights only if the policy init matches; changing the
    // policy seed must change the digest (sanity that the digest bites).
    let c = replay_run(&arch, &trace, 43);
    assert_ne!(a.digest, c.digest, "digest should be sensitive to the run");
}

#[test]
fn serve_report_carries_ops_fields() {
    let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
    let source = Box::new(PoissonSource::new(2.0, 60, 800, [2.0, 1.0, 1.0], 7));
    let report = Server::new(&arch, router(&arch, 7), source, serve_cfg(7)).run();
    let j = &report.json;

    for key in [
        "scheduler",
        "source",
        "offered",
        "admitted",
        "rejected",
        "shed",
        "completed",
        "throughput_jobs_s",
        "queue_depth_max",
        "fifo_depth_max",
        "host_stalls",
        "throttle_events",
        "max_temp_k",
        "system_energy_j",
    ] {
        assert!(!matches!(j.get(key), Json::Null), "report missing `{key}`");
    }
    for q in ["p50", "p95", "p99"] {
        let v = j.get("latency_e2e_s").get(q).as_f64();
        assert!(v.is_some(), "latency_e2e_s missing {q}");
    }
    // One max-temperature entry per PIM cluster.
    match j.get("cluster_max_temp_k") {
        Json::Arr(xs) => {
            assert_eq!(xs.len(), arch.clusters.len());
            for x in xs {
                let t = x.as_f64().unwrap();
                assert!((250.0..450.0).contains(&t), "implausible cluster temp {t}");
            }
        }
        other => panic!("cluster_max_temp_k not an array: {other:?}"),
    }
    // Tenant breakdown in fixed order with conserved counts.
    let tenants = j.get("tenants");
    let mut offered_sum = 0.0;
    for name in ["exec", "balanced", "energy"] {
        let t = tenants.get(name);
        assert!(!matches!(t, Json::Null), "missing tenant `{name}`");
        offered_sum += t.get("offered").as_f64().unwrap();
    }
    assert_eq!(offered_sum, j.get("offered").as_f64().unwrap());
    assert_eq!(report.digest.len(), 16);
}
