//! Property-based integration tests: cross-module invariants checked over
//! randomized inputs (own testkit; seeds reproducible via
//! THERMOS_PROP_SEED).

use thermos::arch::Arch;
use thermos::noi::NoiTopology;
use thermos::pim::ComputeModel;
use thermos::sched::policy::{masked_softmax, NativeDdt, NativeMlp};
use thermos::sched::state::{relmas_obs_dim, StateEncoder, NUM_CLUSTERS, STATE_DIM};
use thermos::sched::thermos::ThermosSched;
use thermos::sched::{Scheduler, SysSnapshot};
use thermos::sim::{ExecProfile, Mapping};
use thermos::util::rng::Rng;
use thermos::util::testkit::{check, forall, vec_f32};
use thermos::workload::{DnnModel, Job, ModelZoo};

fn random_snapshot(arch: &Arch, rng: &mut Rng) -> SysSnapshot {
    let mut snap = SysSnapshot::fresh(arch);
    for c in 0..arch.num_chiplets() {
        // Random partial occupancy and throttle state.
        let cap = arch.spec(c).mem_bits;
        snap.free_bits[c] = (cap as f64 * rng.f64()) as u64;
        snap.temps[c] = 300.0 + 40.0 * rng.f64();
        snap.throttled[c] = rng.f64() < 0.15;
    }
    snap
}

/// Every scheduler, on any system state, either declines or produces a
/// complete, memory-feasible, unthrottled mapping.
#[test]
fn prop_schedulers_never_overcommit() {
    let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
    let zoo = ModelZoo::new();
    let encoder = StateEncoder::new(&arch, &zoo, 20_000);
    forall(40, |rng| {
        let snap = random_snapshot(&arch, rng);
        let model = *rng.choose(&DnnModel::all());
        let job = Job {
            id: rng.next_u64(),
            dcg: zoo.dcg(model),
            images: rng.range_usize(10, 5000) as u64,
            arrival_s: 0.0,
        };
        let policy = NativeDdt::init(STATE_DIM, NUM_CLUSTERS, rng);
        let mut schedulers: Vec<Box<dyn Scheduler>> = vec![
            Box::new(thermos::sched::SimbaSched::new(arch.clone())),
            Box::new(thermos::sched::BigLittleSched::new(arch.clone())),
            Box::new(ThermosSched::new(arch.clone(), encoder.clone(), policy, [0.5, 0.5])),
        ];
        for s in schedulers.iter_mut() {
            if let Some(m) = s.schedule(&job, &snap) {
                check(m.layers.len() == job.dcg.num_layers(), format!("{}: layer count", s.name()))?;
                for (i, la) in m.layers.iter().enumerate() {
                    check(
                        la.total_bits() == job.dcg.layers[i].weight_bits,
                        format!("{}: layer {i} incomplete", s.name()),
                    )?;
                }
                let per = m.bits_per_chiplet(arch.num_chiplets());
                for (c, &b) in per.iter().enumerate() {
                    check(b <= snap.free_bits[c], format!("{}: chiplet {c} overcommit", s.name()))?;
                }
            }
        }
        Ok(())
    });
}

/// THERMOS never places weights on throttled chiplets (§4.1).
#[test]
fn prop_thermos_avoids_throttled_chiplets() {
    let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
    let zoo = ModelZoo::new();
    let encoder = StateEncoder::new(&arch, &zoo, 20_000);
    forall(30, |rng| {
        let snap = random_snapshot(&arch, rng);
        let policy = NativeDdt::init(STATE_DIM, NUM_CLUSTERS, rng);
        let mut sched = ThermosSched::new(arch.clone(), encoder.clone(), policy, [1.0, 0.0]);
        let job = Job {
            id: 1,
            dcg: zoo.dcg(*rng.choose(&DnnModel::all())),
            images: 100,
            arrival_s: 0.0,
        };
        if let Some(m) = sched.schedule(&job, &snap) {
            for la in &m.layers {
                for &(c, _) in &la.parts {
                    check(!snap.throttled[c], format!("throttled chiplet {c} used"))?;
                }
            }
        }
        Ok(())
    });
}

/// The execution profile respects basic physics on any feasible mapping:
/// times/energies positive, more images never cheaper or faster.
#[test]
fn prop_exec_profile_monotone_in_images() {
    let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
    let zoo = ModelZoo::new();
    let cm = ComputeModel::default();
    let encoder = StateEncoder::new(&arch, &zoo, 20_000);
    forall(25, |rng| {
        let snap = SysSnapshot::fresh(&arch);
        let policy = NativeDdt::init(STATE_DIM, NUM_CLUSTERS, rng);
        let mut sched = ThermosSched::new(arch.clone(), encoder.clone(), policy, [0.5, 0.5]);
        let job = Job {
            id: 1,
            dcg: zoo.dcg(*rng.choose(&DnnModel::all())),
            images: 100,
            arrival_s: 0.0,
        };
        let m: Mapping = sched.schedule(&job, &snap).expect("empty system fits");
        let p = ExecProfile::compute(&arch, &cm, &job.dcg, &m);
        check(p.bottleneck_s > 0.0, "bottleneck positive")?;
        check(p.frame_latency_s >= p.bottleneck_s - 1e-12, "fill ≥ bottleneck")?;
        check(p.frame_energy_j > 0.0, "energy positive")?;
        let (a, b) = (rng.range_usize(1, 10_000) as u64, rng.range_usize(1, 10_000) as u64);
        let (lo, hi) = if a < b { (a, b) } else { (b, a) };
        check(p.ideal_exec_s(lo) <= p.ideal_exec_s(hi) + 1e-12, "time monotone in images")?;
        check(p.ideal_dynamic_j(lo) <= p.ideal_dynamic_j(hi) + 1e-12, "energy monotone")
    });
}

/// Masked softmax over random logits: probabilities sum to 1, invalid
/// actions get ~0 mass, and sampling only ever returns valid actions.
#[test]
fn prop_masked_softmax_and_sampling() {
    forall(200, |rng| {
        let n = rng.range_usize(2, 80);
        let logits = vec_f32(rng, n, -5.0, 5.0);
        let mut valid: Vec<bool> = (0..n).map(|_| rng.f64() < 0.6).collect();
        if !valid.iter().any(|&v| v) {
            valid[rng.below(n)] = true;
        }
        let probs = masked_softmax(&logits, &valid);
        let sum: f32 = probs.iter().sum();
        check((sum - 1.0).abs() < 1e-4, format!("sum {sum}"))?;
        for (i, &p) in probs.iter().enumerate() {
            if !valid[i] {
                check(p < 1e-6, format!("invalid action {i} has mass {p}"))?;
            }
        }
        for _ in 0..20 {
            let (a, _) = thermos::sched::policy::sample_action(&probs, rng);
            check(valid[a], format!("sampled invalid action {a}"))?;
        }
        Ok(())
    });
}

/// Native MLP forward is Lipschitz-continuous in its input (sanity on the
/// evaluator used for RELMAS and the critic): small input perturbations
/// yield bounded output changes.
#[test]
fn prop_mlp_continuity() {
    forall(30, |rng| {
        let dims = vec![relmas_obs_dim(78), 128, 128, 78];
        let mlp = NativeMlp::init(dims.clone(), rng);
        let x = vec_f32(rng, dims[0], 0.0, 1.0);
        let y1 = mlp.forward(&x);
        let mut x2 = x.clone();
        let idx = rng.below(x.len());
        x2[idx] += 1e-4;
        let y2 = mlp.forward(&x2);
        let max_delta = y1
            .iter()
            .zip(&y2)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        check(max_delta < 1.0, format!("output jumped {max_delta} for 1e-4 input step"))
    });
}

/// The state encoder is deterministic and scale-bounded for arbitrary
/// system states.
#[test]
fn prop_state_encoder_bounded() {
    let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
    let zoo = ModelZoo::new();
    let encoder = StateEncoder::new(&arch, &zoo, 20_000);
    forall(60, |rng| {
        let snap = random_snapshot(&arch, rng);
        let job = Job {
            id: 0,
            dcg: zoo.dcg(*rng.choose(&DnnModel::all())),
            images: rng.range_usize(1, 20_000) as u64,
            arrival_s: 0.0,
        };
        let li = rng.below(job.dcg.num_layers());
        let need = rng.range_usize(1, job.dcg.layers[li].weight_bits as usize) as u64;
        let w = rng.f32();
        let s1 = encoder.encode(&arch, &snap, &job, li, need, &[], [w, 1.0 - w]);
        let s2 = encoder.encode(&arch, &snap, &job, li, need, &[], [w, 1.0 - w]);
        check(s1 == s2, "encoder must be deterministic")?;
        for (i, &v) in s1.iter().enumerate() {
            check(v.is_finite() && (-2.0..=2.0).contains(&v), format!("feature {i} = {v}"))?;
        }
        Ok(())
    });
}
