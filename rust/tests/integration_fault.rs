//! Integration: deterministic fault injection on the cluster serving
//! path — chaos runs reproduce byte-identical merged digests, a mid-run
//! shard crash fails work over with at-most-once completion (no duplicate
//! ids across the per-shard replay logs), and hangs resolve without
//! failover.

use std::collections::HashSet;
use thermos::cluster::{run_cluster, ClusterConfig, ClusterReport, ShardSchedSpec};
use thermos::fault::{FaultEvent, FaultKind, FaultPlan};
use thermos::serve::{PoissonSource, ServeConfig};
use thermos::sim::SimConfig;
use thermos::util::json::Json;
use thermos::util::testkit::ClusterScenario;

const MAX_IMAGES: u64 = 400;

fn cluster_cfg(shards: usize, duration_s: f64, seed: u64) -> ClusterConfig {
    ClusterConfig {
        shards,
        duration_s,
        drain_max_s: 30.0,
        serve: ServeConfig {
            duration_s,
            tenant_queue_cap: 32,
            max_wait_s: 60.0,
            snapshot_every_s: 0.0,
            pressure_depth: 48,
            sim: SimConfig {
                warmup_s: 0.0,
                max_images: MAX_IMAGES,
                seed,
                ..SimConfig::default()
            },
        },
        sched: ShardSchedSpec::Simba,
        ..ClusterConfig::default()
    }
}

fn run(cfg: ClusterConfig, rate: f64, seed: u64) -> ClusterReport {
    let source = Box::new(PoissonSource::new(rate, 60, MAX_IMAGES, [1.0, 1.0, 1.0], seed));
    run_cluster(cfg, source).expect("cluster run")
}

fn num(j: &Json, key: &str) -> f64 {
    j.get(key).as_f64().unwrap_or(0.0)
}

fn fault_stat(j: &Json, key: &str) -> f64 {
    num(j.get("faults"), key)
}

#[test]
fn chaos_same_seed_reproduces_merged_digest() {
    let shards = 4;
    let duration_s = 30.0;
    let plan = FaultPlan::chaos(7, shards, 30);
    assert!(!plan.is_empty(), "chaos plan should schedule faults");
    let mk = || {
        let mut cfg = cluster_cfg(shards, duration_s, 42);
        cfg.faults = Some(plan.clone());
        cfg
    };
    let a = run(mk(), 4.0, 42);
    let b = run(mk(), 4.0, 42);
    // Crashes, failovers, restarts, and retries — all on real threads —
    // must still merge to byte-identical fleet telemetry.
    assert_eq!(
        a.json.to_string_compact(),
        b.json.to_string_compact(),
        "same-seed chaos runs diverged"
    );
    assert_eq!(a.digest, b.digest);
    assert!(fault_stat(&a.json, "faults_injected") > 0.0, "chaos injected nothing");
    assert!(fault_stat(&a.json, "failovers") > 0.0, "chaos crash did not fail over");
    assert!(num(&a.json, "completed") > 0.0, "faulted cluster completed no jobs");

    // A different chaos seed perturbs the run differently.
    let mut cfg = cluster_cfg(shards, duration_s, 42);
    cfg.faults = Some(FaultPlan::chaos(8, shards, 30));
    let c = run(cfg, 4.0, 42);
    assert_ne!(a.digest, c.digest, "different chaos seeds must change the digest");
}

#[test]
fn shard_crash_fails_over_with_at_most_once_completion() {
    let shards = 2;
    let duration_s = 20.0;
    let base = std::env::temp_dir().join("thermos_fault_crash_test");
    let _ = std::fs::remove_dir_all(&base);
    std::fs::create_dir_all(&base).expect("temp dir");
    let record_base = base.join("replay").to_string_lossy().into_owned();

    let mut cfg = cluster_cfg(shards, duration_s, 9);
    cfg.record_base = Some(record_base.clone());
    // Kill shard 1 at epoch 5; the supervisor restarts it at epoch 8.
    cfg.faults = Some(FaultPlan::new(vec![FaultEvent {
        epoch: 5,
        shard: 1,
        kind: FaultKind::ShardCrash { down_epochs: 3 },
    }]));
    let r = run(cfg, 3.0, 9);
    let j = &r.json;
    assert_eq!(fault_stat(j, "faults_injected"), 1.0);
    assert_eq!(fault_stat(j, "failovers"), 1.0);
    assert_eq!(fault_stat(j, "restarts"), 1.0);
    assert_eq!(fault_stat(j, "downtime_epochs"), 3.0, "dead for epochs 5..8");
    assert!(num(j, "completed") > 0.0);

    // At-most-once: every completion id appears exactly once across all
    // per-shard replay logs, and the done count matches the merged total.
    let mut seen: HashSet<u64> = HashSet::new();
    let mut done_lines = 0u64;
    for s in 0..shards {
        let path = format!("{record_base}.shard{s}.jsonl");
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("read replay log {path}: {e}"));
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let ev = Json::parse(line).expect("replay line parses");
            if ev.get("ev").as_str() != Some("done") {
                continue;
            }
            done_lines += 1;
            let id = ev.get("id").as_f64().expect("done id") as u64;
            assert!(seen.insert(id), "request id {id} completed twice (shard {s})");
        }
    }
    assert_eq!(
        done_lines,
        num(j, "completed") as u64,
        "replay `done` events disagree with the merged completion count"
    );
    let _ = std::fs::remove_dir_all(&base);
}

#[test]
fn warm_standby_cuts_downtime_and_keeps_at_most_once() {
    let shards = 2;
    let crash = FaultPlan::new(vec![FaultEvent {
        epoch: 5,
        shard: 1,
        kind: FaultKind::ShardCrash { down_epochs: 3 },
    }]);
    // Cold baseline: no spares, the supervisor restarts the shard after
    // its down window.
    let cold_sc = ClusterScenario::new(shards, 9).with_duration(20.0).with_faults(crash.clone());
    let cold = cold_sc.run();
    let cold_down = fault_stat(&cold.json, "downtime_epochs");
    assert!(cold_down >= 3.0, "cold restart should be down >= 3 epochs, got {cold_down}");
    assert_eq!(fault_stat(&cold.json, "restarts"), 1.0);

    // Warm standby: same plan, one prebuilt spare. The standby adopts the
    // dead shard's ring position at the crash barrier, so the fleet never
    // loses an epoch of capacity.
    let base = std::env::temp_dir().join("thermos_fault_standby_test");
    let _ = std::fs::remove_dir_all(&base);
    std::fs::create_dir_all(&base).expect("temp dir");
    let record_base = base.join("replay").to_string_lossy().into_owned();
    let warm = ClusterScenario::new(shards, 9)
        .with_duration(20.0)
        .with_faults(crash)
        .with_spares(1)
        .with_record_base(&record_base)
        .run();
    let j = &warm.json;
    let warm_down = fault_stat(j, "downtime_epochs");
    assert!(
        warm_down < cold_down,
        "standby adoption must cut downtime: warm {warm_down} vs cold {cold_down} epochs"
    );
    assert_eq!(num(j.get("spares"), "standby_promotions"), 1.0, "spare was not promoted");
    assert_eq!(fault_stat(j, "failovers"), 0.0, "promotion must not count as a cold failover");
    assert_eq!(fault_stat(j, "restarts"), 0.0, "promotion must not count as a restart");
    assert_eq!(fault_stat(j, "faults_injected"), 1.0);
    assert!(num(j, "completed") > 0.0);

    // At-most-once survives adoption: completion ids are globally unique
    // across every physical slot's replay log (shards + the spare), and
    // the done count matches the merged total.
    let mut seen: HashSet<u64> = HashSet::new();
    let mut done_lines = 0u64;
    for s in 0..shards + 1 {
        let path = format!("{record_base}.shard{s}.jsonl");
        // An idle spare may never open its log; missing is fine.
        let text = std::fs::read_to_string(&path).unwrap_or_default();
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let ev = Json::parse(line).expect("replay line parses");
            if ev.get("ev").as_str() != Some("done") {
                continue;
            }
            done_lines += 1;
            let id = ev.get("id").as_f64().expect("done id") as u64;
            assert!(seen.insert(id), "request id {id} completed twice (slot {s})");
        }
    }
    assert_eq!(
        done_lines,
        num(j, "completed") as u64,
        "replay `done` events disagree with the merged completion count"
    );
    let _ = std::fs::remove_dir_all(&base);
}

#[test]
fn short_hang_resolves_without_failover() {
    let shards = 2;
    let mut cfg = cluster_cfg(shards, 16.0, 21);
    // A 2-epoch hang sits exactly at supervisor patience: the shard is
    // drained from the ring, resumes, and is never crashed.
    cfg.faults = Some(FaultPlan::new(vec![FaultEvent {
        epoch: 4,
        shard: 0,
        kind: FaultKind::ShardHang { epochs: 2 },
    }]));
    let r = run(cfg, 3.0, 21);
    let j = &r.json;
    assert_eq!(fault_stat(j, "faults_injected"), 1.0);
    assert_eq!(fault_stat(j, "failovers"), 0.0, "a tolerated hang must not fail over");
    assert_eq!(fault_stat(j, "restarts"), 0.0);
    assert_eq!(fault_stat(j, "downtime_epochs"), 2.0);
    assert!(num(j, "completed") > 0.0, "hung cluster completed no jobs");
    // The run still reports one barrier per epoch.
    assert_eq!(num(j.get("arbiter"), "epochs"), 16.0);
}
