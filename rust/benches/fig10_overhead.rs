//! Fig. 10: THERMOS scheduling overhead (% of runtime and % of energy)
//! as the per-job image count grows — 1 000 … 500 000 images. The
//! per-call cost is fixed, so the relative overhead must fall sharply
//! (paper: < 1.5% even at 1 000 images, imperceptible beyond).
//!
//! Run: `cargo bench --bench fig10_overhead`

use thermos::arch::Arch;
use thermos::experiments::report::Table;
use thermos::noi::NoiTopology;
use thermos::pim::ComputeModel;
use thermos::sched::policy::{NativeDdt, PolicyEval};
use thermos::sched::proximity::assign_in_cluster;
use thermos::sched::state::{StateEncoder, NUM_CLUSTERS, STATE_DIM};
use thermos::sched::SysSnapshot;
use thermos::sim::{ExecProfile, LayerAssignment, Mapping};
use thermos::util::bench::{black_box, Group};
use thermos::util::rng::Rng;
use thermos::workload::{DnnModel, Job, ModelZoo};

const P_PROXY_W: f64 = 12.0; // CPU power proxy (see table6_overhead.rs)

fn main() {
    let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
    let zoo = ModelZoo::new();
    let encoder = StateEncoder::new(&arch, &zoo, 500_000);
    let snap = SysSnapshot::fresh(&arch);
    let mut rng = Rng::new(2);
    let mut ddt = NativeDdt::init(STATE_DIM, NUM_CLUSTERS, &mut rng);
    let job = Job { id: 0, dcg: zoo.dcg(DnnModel::ResNet50), images: 10_000, arrival_s: 0.0 };
    let state = encoder.encode(&arch, &snap, &job, 5, 50_000, &[(0, 1000)], [0.5, 0.5]);

    // Measure the per-decision cost once.
    let mut g = Group::new("Fig. 10: overhead scaling with image count");
    let pol = g.bench("policy_call", || ddt.logits(black_box(&state))).clone();
    let prev: Vec<(usize, u64)> = vec![(0, 500_000)];
    let free_template = snap.free_bits.clone();
    let prox = g
        .bench("proximity_call", || {
            let mut free = free_template.clone();
            assign_in_cluster(&arch, &snap, &mut free, 1, black_box(2_000_000), &prev)
        })
        .clone();
    let per_decision_s = (pol.mean_ns + prox.mean_ns) * 1e-9;
    let decisions = job.dcg.num_layers() as f64;
    let sched_s = per_decision_s * decisions;
    let sched_j = sched_s * P_PROXY_W;

    // Reference execution profile (shared-ADC mapping, as in Table 6).
    let ids = &arch.clusters[1];
    let cap = arch.specs[1].mem_bits;
    let mut freec: Vec<u64> = vec![cap; ids.len()];
    let mut layers = Vec::new();
    let mut k = 0usize;
    for l in &job.dcg.layers {
        let mut need = l.weight_bits;
        let mut parts = Vec::new();
        while need > 0 {
            let idx = k % ids.len();
            if freec[idx] == 0 {
                k += 1;
                continue;
            }
            let take = need.min(freec[idx]);
            parts.push((ids[idx], take));
            freec[idx] -= take;
            need -= take;
        }
        layers.push(LayerAssignment { parts });
    }
    let mapping = Mapping { layers };
    let profile = ExecProfile::compute(&arch, &ComputeModel::default(), &job.dcg, &mapping);

    let mut t = Table::new(&["images", "exec_s", "sched_overhead_pct", "energy_overhead_pct"]);
    println!();
    for images in [1_000u64, 5_000, 10_000, 50_000, 100_000, 500_000] {
        let exec_s = profile.ideal_exec_s(images);
        let exec_j = profile.ideal_dynamic_j(images);
        let time_pct = sched_s / exec_s * 100.0;
        let energy_pct = sched_j / exec_j * 100.0;
        println!(
            "  {:>7} images: exec {:>8.2} s | time overhead {:>8.5}% | energy overhead {:>8.5}%",
            images, exec_s, time_pct, energy_pct
        );
        t.row(vec![
            images.to_string(),
            format!("{:.3}", exec_s),
            format!("{:.6}", time_pct),
            format!("{:.6}", energy_pct),
        ]);
    }
    println!("\n(paper Fig. 10: <1.5% time and <0.25% energy at 1 000 images, falling fast)");
    match t.write_csv("fig10_overhead") {
        Ok(p) => println!("wrote {p}"),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
}
