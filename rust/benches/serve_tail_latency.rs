//! Serve tail latency: drive the online scheduling service across a load
//! sweep (Poisson) plus one bursty MMPP point and report end-to-end
//! p50/p95/p99 latency, loss (reject + shed) rate, and thermal pressure.
//! The open-loop knee — where p99 detaches from p50 and the admission
//! controller starts shedding — is the serving-side analogue of the
//! paper's Fig. 7 throughput saturation.
//!
//! Run: `cargo bench --bench serve_tail_latency`

use thermos::arch::Arch;
use thermos::experiments::report::Table;
use thermos::noi::NoiTopology;
use thermos::sched::policy::NativeDdt;
use thermos::sched::state::{StateEncoder, NUM_CLUSTERS, STATE_DIM};
use thermos::sched::thermos::ThermosSched;
use thermos::serve::{
    MmppSource, PoissonSource, ServeConfig, ServeReport, Server, TenantRouter, TrafficSource,
};
use thermos::sim::SimConfig;
use thermos::util::json::Json;
use thermos::util::rng::Rng;
use thermos::workload::ModelZoo;

const SEED: u64 = 11;
const MAX_IMAGES: u64 = 2_000;

fn run_point(arch: &Arch, source: Box<dyn TrafficSource>) -> ServeReport {
    let zoo = ModelZoo::new();
    let encoder = StateEncoder::new(arch, &zoo, MAX_IMAGES);
    let mut rng = Rng::new(SEED);
    let ddt = NativeDdt::init(STATE_DIM, NUM_CLUSTERS, &mut rng);
    let sched = TenantRouter::new(ThermosSched::new(arch.clone(), encoder, ddt, [0.5, 0.5]));
    let cfg = ServeConfig {
        duration_s: 180.0,
        tenant_queue_cap: 32,
        max_wait_s: 45.0,
        snapshot_every_s: 0.0,
        pressure_depth: 48,
        sim: SimConfig { warmup_s: 0.0, max_images: MAX_IMAGES, seed: SEED, ..SimConfig::default() },
    };
    Server::new(arch, sched, source, cfg).run()
}

fn num(j: &Json, key: &str) -> f64 {
    j.get(key).as_f64().unwrap_or(0.0)
}

fn main() {
    let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
    let mut t = Table::new(&[
        "load", "offered", "completed", "lost_pct", "p50_s", "p95_s", "p99_s", "depth_max",
        "throttles", "maxT_K",
    ]);

    let rates = [0.5, 1.0, 2.0, 4.0, 8.0];
    let mut reports: Vec<(String, ServeReport)> = rates
        .iter()
        .map(|&rate| {
            let src = Box::new(PoissonSource::new(rate, 80, MAX_IMAGES, [1.0, 1.0, 1.0], SEED));
            (format!("poisson_{rate}"), run_point(&arch, src))
        })
        .collect();
    // One bursty point with the same 2 jobs/s mean rate: 8/s in 10 s
    // bursts, silent for 30 s.
    let mmpp = Box::new(MmppSource::new(8.0, 0.0, 10.0, 30.0, 80, MAX_IMAGES, [1.0, 1.0, 1.0], SEED));
    reports.push(("mmpp_8x0.25".to_string(), run_point(&arch, mmpp)));

    for (label, r) in &reports {
        let j = &r.json;
        let offered = num(j, "offered");
        let lost = num(j, "rejected") + num(j, "shed");
        let lat = j.get("latency_e2e_s");
        t.row(vec![
            label.clone(),
            format!("{offered:.0}"),
            format!("{:.0}", num(j, "completed")),
            format!("{:.1}%", 100.0 * lost / offered.max(1.0)),
            format!("{:.3}", num(lat, "p50")),
            format!("{:.3}", num(lat, "p95")),
            format!("{:.3}", num(lat, "p99")),
            format!("{:.0}", num(j, "queue_depth_max") + num(j, "fifo_depth_max")),
            format!("{:.0}", num(j, "throttle_events")),
            format!("{:.1}", num(j, "max_temp_k")),
        ]);
    }
    println!("\n{}", t.render());
    println!("(p99/p50 detaching + nonzero loss marks the service knee; the MMPP row");
    println!(" shows how bursts inflate tails at the same mean rate)");
    match t.write_csv("serve_tail_latency") {
        Ok(p) => println!("wrote {p}"),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
}
