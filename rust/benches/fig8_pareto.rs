//! Fig. 8 (Mesh NoI): Pareto plots of average execution time vs average
//! energy per DNN under increasing throughput scenarios. The three
//! connected THERMOS points come from a SINGLE policy evaluated with
//! ω = [1,0], [0.5,0.5], [0,1]; baselines are single points.
//!
//! Also runs the finer ω grid (ablation: Pareto front of the single
//! preference-conditioned policy, Fig. 2b).
//!
//! Run: `cargo bench --bench fig8_pareto`

use thermos::experiments::report::Table;
use thermos::experiments::{
    fast_mode, load_thermos_theta, standard_contenders, sweep_standard, SchedKind,
};
use thermos::noi::NoiTopology;

fn main() {
    let noi = NoiTopology::Mesh;
    let rates: Vec<f64> =
        if fast_mode() { vec![1.5, 2.5] } else { vec![1.5, 2.0, 2.5, 3.0, 3.5, 4.0] };

    println!("== Fig. 8: Pareto exec-time vs energy per throughput scenario (mesh) ==");
    let contenders = standard_contenders(noi);
    // Pool the whole grid; print in the old rate-major order.
    let grid = sweep_standard(noi, &contenders, &rates);
    let mut table = Table::new(&["throughput_scenario", "scheduler", "exec_s", "energy_j", "edp"]);
    for (ri, &rate) in rates.iter().enumerate() {
        println!("\n-- scenario: {rate} DNN/s --");
        for ki in 0..contenders.len() {
            let r = &grid[ki][ri];
            println!(
                "  {:<22} exec {:>8.3} s  energy {:>9.4} J  (achieved {:>5.2} DNN/s)",
                r.scheduler, r.mean_exec_s, r.mean_energy_j, r.throughput_jobs_s
            );
            table.row(vec![
                format!("{rate}"),
                r.scheduler.clone(),
                format!("{:.4}", r.mean_exec_s),
                format!("{:.5}", r.mean_energy_j),
                format!("{:.5}", r.mean_edp),
            ]);
        }
    }

    // ω-grid ablation: the single policy swept over five preferences.
    println!("\n-- ω grid (single policy, 2 DNN/s): Pareto front --");
    let (theta, trained) = load_thermos_theta(noi);
    if !trained {
        println!("   (untrained policy — run `thermos train` for the real front)");
    }
    let omegas: [(f32, &str); 5] = [
        (1.0, "1.00/0.00"),
        (0.75, "0.75/0.25"),
        (0.5, "0.50/0.50"),
        (0.25, "0.25/0.75"),
        (0.0, "0.00/1.00"),
    ];
    let grid_kinds: Vec<SchedKind> = omegas
        .iter()
        .map(|&(wl, _)| SchedKind::Thermos {
            theta: theta.clone(),
            pref: [wl, 1.0 - wl],
            label: "grid",
        })
        .collect();
    let omega_grid = sweep_standard(noi, &grid_kinds, &[2.0]);
    for (&(_, label), row) in omegas.iter().zip(&omega_grid) {
        let r = &row[0];
        println!(
            "  ω = {label}   exec {:>8.3} s   energy {:>9.4} J",
            r.mean_exec_s, r.mean_energy_j
        );
        table.row(vec![
            "2.0-grid".into(),
            format!("omega_{label}"),
            format!("{:.4}", r.mean_exec_s),
            format!("{:.5}", r.mean_energy_j),
            format!("{:.5}", r.mean_edp),
        ]);
    }
    match table.write_csv("fig8_pareto") {
        Ok(p) => println!("\nwrote {p}"),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
}
