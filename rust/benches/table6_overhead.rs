//! Table 6: THERMOS scheduling overhead — per-call execution time (and an
//! energy proxy) of the RL policy, the proximity-driven algorithm, and
//! the combined scheduler, plus the relative overhead per DNN with
//! ~10 000 images. The paper measures a Jetson Xavier NX; we measure this
//! machine's CPU and report both the native evaluator and the canonical
//! PJRT-artifact path.
//!
//! Also reports the MFIT-substitute DSS step cost (§5.5's 15 µs/100 ms
//! figure).
//!
//! Run: `cargo bench --bench table6_overhead`

use thermos::arch::Arch;
use thermos::experiments::report::Table;
use thermos::noi::NoiTopology;
use thermos::pim::ComputeModel;
use thermos::sched::policy::{NativeDdt, PolicyEval};
use thermos::sched::proximity::assign_in_cluster;
use thermos::sched::state::{StateEncoder, NUM_CLUSTERS, STATE_DIM};
use thermos::sched::SysSnapshot;
use thermos::sim::ExecProfile;
use thermos::sim::{LayerAssignment, Mapping};
use thermos::thermal::DssModel;
use thermos::util::bench::{black_box, Group};
use thermos::util::rng::Rng;
use thermos::workload::{DnnModel, Job, ModelZoo};

/// CPU power proxy for the energy column (W per active core) — documented
/// in DESIGN.md §2 (platform substitution): energy/call = time × P_PROXY.
const P_PROXY_W: f64 = 12.0;

#[cfg(feature = "pjrt")]
fn bench_pjrt_policy(g: &mut Group, ddt: &NativeDdt, state: &[f32]) -> Option<f64> {
    match thermos::runtime::Runtime::open_default() {
        Ok(runtime) => {
            let mut pol = thermos::runtime::PjrtPolicy::new(
                runtime,
                "ddt_policy",
                STATE_DIM,
                NUM_CLUSTERS,
                ddt.theta.clone(),
            )
            .expect("compile ddt_policy");
            let r = g.bench("rl_policy_pjrt_artifact", || pol.logits(black_box(state)));
            Some(r.mean_ns)
        }
        Err(e) => {
            eprintln!("(pjrt path skipped: {e})");
            None
        }
    }
}

#[cfg(not(feature = "pjrt"))]
fn bench_pjrt_policy(_g: &mut Group, _ddt: &NativeDdt, _state: &[f32]) -> Option<f64> {
    eprintln!("(pjrt path skipped: built without the `pjrt` feature)");
    None
}

fn main() {
    let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
    let zoo = ModelZoo::new();
    let encoder = StateEncoder::new(&arch, &zoo, 20_000);
    let snap = SysSnapshot::fresh(&arch);
    let mut rng = Rng::new(1);
    let mut ddt = NativeDdt::init(STATE_DIM, NUM_CLUSTERS, &mut rng);
    let job = Job { id: 0, dcg: zoo.dcg(DnnModel::ResNet50), images: 10_000, arrival_s: 0.0 };
    let state = encoder.encode(&arch, &snap, &job, 10, 100_000, &[(0, 1000)], [0.5, 0.5]);

    let mut g = Group::new("Table 6: scheduler overhead per call");

    // -- RL policy (DDT forward), native evaluator.
    let policy = g.bench("rl_policy_native_ddt", || ddt.logits(black_box(&state))).clone();
    let policy_ns = policy.mean_ns;

    // -- RL policy through the PJRT artifact (canonical runtime path).
    let pjrt_ns = bench_pjrt_policy(&mut g, &ddt, &state);

    // -- proximity-driven algorithm (one cluster assignment).
    let prev: Vec<(usize, u64)> = vec![(0, 500_000), (5, 500_000)];
    let free_template = snap.free_bits.clone();
    let prox = g
        .bench("proximity_driven_algorithm", || {
            let mut free = free_template.clone();
            assign_in_cluster(&arch, &snap, &mut free, 1, black_box(2_000_000), &prev)
        })
        .clone();
    let prox_ns = prox.mean_ns;

    // -- thermal DSS step (§5.5: paper reports ~15 µs per 100 ms interval).
    let mut dss = DssModel::from_arch(&arch);
    let powers = vec![0.2f64; arch.num_chiplets()];
    let dss_r = g.bench("thermal_dss_step_100ms", || dss.step(black_box(&powers))).clone();

    // -- combined per-decision cost and relative overheads.
    let combined_ns = policy_ns + prox_ns;
    // Reference DNN execution: ResNet-50, 10 000 images on the shared-ADC
    // cluster (a representative mapping).
    let ids = &arch.clusters[1];
    let cap = arch.specs[1].mem_bits;
    let mut freec: Vec<u64> = vec![cap; ids.len()];
    let mut layers = Vec::new();
    let mut k = 0usize;
    for l in &job.dcg.layers {
        let mut need = l.weight_bits;
        let mut parts = Vec::new();
        while need > 0 {
            let idx = k % ids.len();
            if freec[idx] == 0 {
                k += 1;
                continue;
            }
            let take = need.min(freec[idx]);
            parts.push((ids[idx], take));
            freec[idx] -= take;
            need -= take;
        }
        layers.push(LayerAssignment { parts });
    }
    let profile =
        ExecProfile::compute(&arch, &ComputeModel::default(), &job.dcg, &Mapping { layers });
    let exec_s = profile.ideal_exec_s(job.images);
    let decisions = job.dcg.num_layers() as f64; // ≥1 call per layer

    let mut t = Table::new(&["component", "time_per_call", "energy_per_call", "pct_time_per_dnn_10k"]);
    let rowf = |name: &str, ns: f64| {
        vec![
            name.to_string(),
            format!("{:.2} us", ns / 1e3),
            format!("{:.2} uJ", ns * 1e-9 * P_PROXY_W * 1e6),
            format!("{:.4}%", ns * 1e-9 * decisions / exec_s * 100.0),
        ]
    };
    t.row(rowf("rl_policy (native)", policy_ns));
    if let Some(ns) = pjrt_ns {
        t.row(rowf("rl_policy (pjrt)", ns));
    }
    t.row(rowf("proximity_algorithm", prox_ns));
    t.row(rowf("thermos_combined", combined_ns));
    t.row(vec![
        "thermal_dss_step".into(),
        format!("{:.2} us", dss_r.mean_ns / 1e3),
        format!("{:.2} uJ", dss_r.mean_ns * 1e-9 * P_PROXY_W * 1e6),
        format!("{:.4}%", dss_r.mean_ns * 1e-9 / 0.1 * 100.0), // per 100 ms
    ]);
    println!("\n{}", t.render());
    println!(
        "reference DNN: resnet50 × 10k images, exec {:.2} s, {} scheduling decisions",
        exec_s, decisions as u64
    );
    println!("(paper Table 6: policy 0.6 µs, proximity 49.3 µs, combined 0.14% time/DNN)");
    match t.write_csv("table6_overhead") {
        Ok(p) => println!("wrote {p}"),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
}
