//! Fig. 9: the Fig. 8 Pareto comparison repeated on the alternative NoI
//! architectures — (a) Floret, (b) HexaMesh, (c) Kite — demonstrating
//! that the single adaptive THERMOS policy generalizes across
//! interconnects (§5.4).
//!
//! Run: `cargo bench --bench fig9_noi_pareto`

use thermos::experiments::report::Table;
use thermos::experiments::{exp_config, exp_seeds, fast_mode, run_averaged, standard_contenders};
use thermos::noi::NoiTopology;

fn main() {
    let nois = [NoiTopology::Floret, NoiTopology::HexaMesh, NoiTopology::Kite];
    let rates: Vec<f64> = if fast_mode() { vec![1.5, 2.5] } else { vec![1.5, 2.5, 3.5] };
    let seeds = exp_seeds();

    println!("== Fig. 9: Pareto comparison on Floret / HexaMesh / Kite ==");
    let mut table =
        Table::new(&["noi", "throughput_scenario", "scheduler", "exec_s", "energy_j", "edp"]);
    for &noi in &nois {
        println!("\n==== {} ====", noi.name());
        for &rate in &rates {
            println!("-- scenario {rate} DNN/s --");
            for kind in standard_contenders(noi) {
                let r = run_averaged(noi, &kind, &exp_config(rate, 1), &seeds);
                println!(
                    "  {:<22} exec {:>8.3} s  energy {:>9.4} J  (achieved {:>5.2} DNN/s)",
                    r.scheduler, r.mean_exec_s, r.mean_energy_j, r.throughput_jobs_s
                );
                table.row(vec![
                    noi.name().into(),
                    format!("{rate}"),
                    r.scheduler.clone(),
                    format!("{:.4}", r.mean_exec_s),
                    format!("{:.5}", r.mean_energy_j),
                    format!("{:.5}", r.mean_edp),
                ]);
            }
        }
    }
    match table.write_csv("fig9_noi_pareto") {
        Ok(p) => println!("\nwrote {p}"),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
}
