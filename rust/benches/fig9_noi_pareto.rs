//! Fig. 9: the Fig. 8 Pareto comparison repeated on the alternative NoI
//! architectures — (a) Floret, (b) HexaMesh, (c) Kite — demonstrating
//! that the single adaptive THERMOS policy generalizes across
//! interconnects (§5.4).
//!
//! Run: `cargo bench --bench fig9_noi_pareto`

use thermos::experiments::report::Table;
use thermos::experiments::{fast_mode, standard_contenders, sweep_standard};
use thermos::noi::NoiTopology;

fn main() {
    let nois = [NoiTopology::Floret, NoiTopology::HexaMesh, NoiTopology::Kite];
    let rates: Vec<f64> = if fast_mode() { vec![1.5, 2.5] } else { vec![1.5, 2.5, 3.5] };

    println!("== Fig. 9: Pareto comparison on Floret / HexaMesh / Kite ==");
    let mut table =
        Table::new(&["noi", "throughput_scenario", "scheduler", "exec_s", "energy_j", "edp"]);
    for &noi in &nois {
        println!("\n==== {} ====", noi.name());
        let contenders = standard_contenders(noi);
        // Pool the whole per-NoI grid; print in the old rate-major order.
        let grid = sweep_standard(noi, &contenders, &rates);
        for (ri, &rate) in rates.iter().enumerate() {
            println!("-- scenario {rate} DNN/s --");
            for ki in 0..contenders.len() {
                let r = &grid[ki][ri];
                println!(
                    "  {:<22} exec {:>8.3} s  energy {:>9.4} J  (achieved {:>5.2} DNN/s)",
                    r.scheduler, r.mean_exec_s, r.mean_energy_j, r.throughput_jobs_s
                );
                table.row(vec![
                    noi.name().into(),
                    format!("{rate}"),
                    r.scheduler.clone(),
                    format!("{:.4}", r.mean_exec_s),
                    format!("{:.5}", r.mean_energy_j),
                    format!("{:.5}", r.mean_edp),
                ]);
            }
        }
    }
    match table.write_csv("fig9_noi_pareto") {
        Ok(p) => println!("\nwrote {p}"),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
}
