//! Fault recovery: quantify what a mid-run shard crash costs the fleet.
//! The same saturating Poisson stream runs through a 4-shard cluster
//! three times — fault-free, with one 3-epoch shard crash (supervised
//! restart + failover), and under seeded chaos — and the table reports
//! completed volume, tail latency, and the degradation counters so the
//! recovery overhead is a number, not a vibe.
//!
//! Run: `cargo bench --bench fault_recovery`

use thermos::cluster::{run_cluster, ClusterConfig, ShardSchedSpec};
use thermos::experiments::report::Table;
use thermos::fault::{FaultEvent, FaultKind, FaultPlan};
use thermos::serve::{PoissonSource, ServeConfig};
use thermos::sim::SimConfig;
use thermos::util::json::Json;

const SEED: u64 = 11;
const MAX_IMAGES: u64 = 1_000;
const RATE_JOBS_S: f64 = 6.0;
const DURATION_S: f64 = 40.0;
const SHARDS: usize = 4;

fn num(j: &Json, key: &str) -> f64 {
    j.get(key).as_f64().unwrap_or(0.0)
}

fn run_point(faults: Option<FaultPlan>) -> Json {
    let cfg = ClusterConfig {
        shards: SHARDS,
        duration_s: DURATION_S,
        drain_max_s: 30.0,
        serve: ServeConfig {
            duration_s: DURATION_S,
            tenant_queue_cap: 32,
            max_wait_s: 45.0,
            snapshot_every_s: 0.0,
            pressure_depth: 48,
            sim: SimConfig {
                warmup_s: 0.0,
                max_images: MAX_IMAGES,
                seed: SEED,
                ..SimConfig::default()
            },
        },
        sched: ShardSchedSpec::Simba,
        faults,
        ..ClusterConfig::default()
    };
    let source = Box::new(PoissonSource::new(RATE_JOBS_S, 80, MAX_IMAGES, [1.0, 1.0, 1.0], SEED));
    run_cluster(cfg, source).expect("cluster run").json
}

fn main() {
    let crash = FaultPlan::new(vec![FaultEvent {
        epoch: 12,
        shard: 1,
        kind: FaultKind::ShardCrash { down_epochs: 3 },
    }]);
    let chaos = FaultPlan::chaos(7, SHARDS, DURATION_S as usize);
    let points: Vec<(&str, Option<FaultPlan>)> = vec![
        ("fault_free", None),
        ("one_crash", Some(crash)),
        ("chaos_s7", Some(chaos)),
    ];

    let mut t = Table::new(&[
        "scenario", "completed", "images_s", "p50_s", "p99_s", "injected", "failovers", "retries",
        "restarts", "down_ep", "dropped",
    ]);
    let mut completed = Vec::new();
    for (name, plan) in points {
        let j = run_point(plan);
        let lat = j.get("latency_e2e_s");
        let f = j.get("faults");
        completed.push((name, num(&j, "completed")));
        t.row(vec![
            name.to_string(),
            format!("{:.0}", num(&j, "completed")),
            format!("{:.0}", num(&j, "throughput_images_s")),
            format!("{:.3}", num(lat, "p50")),
            format!("{:.3}", num(lat, "p99")),
            format!("{:.0}", num(f, "faults_injected")),
            format!("{:.0}", num(f, "failovers")),
            format!("{:.0}", num(f, "retries")),
            format!("{:.0}", num(f, "restarts")),
            format!("{:.0}", num(f, "downtime_epochs")),
            format!("{:.0}", num(f, "dropped_requests")),
        ]);
    }
    println!("\n{}", t.render());
    let base = completed[0].1.max(1.0);
    for (name, done) in &completed[1..] {
        println!("{name}: retained {:.1}% of fault-free completions", 100.0 * done / base);
    }
    match t.write_csv("fault_recovery") {
        Ok(p) => println!("wrote {p}"),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
}
