//! Fault recovery: quantify what a mid-run shard crash costs the fleet.
//! The same saturating Poisson stream runs through a 4-shard cluster
//! three times — fault-free, with one 3-epoch shard crash (supervised
//! restart + failover), and under seeded chaos — and the table reports
//! completed volume, tail latency, and the degradation counters so the
//! recovery overhead is a number, not a vibe.
//!
//! Run: `cargo bench --bench fault_recovery`

use thermos::cluster::{run_cluster, ClusterConfig, ShardSchedSpec, StealConfig};
use thermos::experiments::report::{write_bench_json, Table};
use thermos::fault::{FaultEvent, FaultKind, FaultPlan};
use thermos::serve::{PoissonSource, ServeConfig};
use thermos::sim::SimConfig;
use thermos::util::json::Json;

const SEED: u64 = 11;
const MAX_IMAGES: u64 = 1_000;
const RATE_JOBS_S: f64 = 6.0;
const DURATION_S: f64 = 40.0;
const SHARDS: usize = 4;

fn num(j: &Json, key: &str) -> f64 {
    j.get(key).as_f64().unwrap_or(0.0)
}

fn run_point(faults: Option<FaultPlan>, spares: usize, steal: bool) -> Json {
    let cfg = ClusterConfig {
        shards: SHARDS,
        spares,
        steal: steal.then(|| StealConfig { seed: SEED, slack: 0.25 }),
        duration_s: DURATION_S,
        drain_max_s: 30.0,
        serve: ServeConfig {
            duration_s: DURATION_S,
            tenant_queue_cap: 32,
            max_wait_s: 45.0,
            snapshot_every_s: 0.0,
            pressure_depth: 48,
            sim: SimConfig {
                warmup_s: 0.0,
                max_images: MAX_IMAGES,
                seed: SEED,
                ..SimConfig::default()
            },
        },
        sched: ShardSchedSpec::Simba,
        faults,
        ..ClusterConfig::default()
    };
    let source = Box::new(PoissonSource::new(RATE_JOBS_S, 80, MAX_IMAGES, [1.0, 1.0, 1.0], SEED));
    run_cluster(cfg, source).expect("cluster run").json
}

fn main() {
    let crash = FaultPlan::new(vec![FaultEvent {
        epoch: 12,
        shard: 1,
        kind: FaultKind::ShardCrash { down_epochs: 3 },
    }]);
    let chaos = FaultPlan::chaos(7, SHARDS, DURATION_S as usize);
    // (name, plan, spares, steal): the standby and steal rows isolate how
    // much each plane buys back of the crash/chaos cost.
    let points: Vec<(&str, Option<FaultPlan>, usize, bool)> = vec![
        ("fault_free", None, 0, false),
        ("one_crash", Some(crash.clone()), 0, false),
        ("one_crash_spare", Some(crash), 1, false),
        ("chaos_s7", Some(chaos.clone()), 0, false),
        ("chaos_spare_steal", Some(chaos), 1, true),
    ];

    let mut t = Table::new(&[
        "scenario", "completed", "images_s", "p50_s", "p99_s", "injected", "failovers", "retries",
        "restarts", "down_ep", "dropped", "promoted", "stolen",
    ]);
    let mut completed = Vec::new();
    let mut rows = Vec::new();
    for (name, plan, spares, steal) in points {
        let j = run_point(plan, spares, steal);
        let lat = j.get("latency_e2e_s");
        let f = j.get("faults");
        let promoted = num(j.get("spares"), "standby_promotions");
        let stolen = num(j.get("steal"), "migrated_requests");
        completed.push((name, num(&j, "completed")));
        t.row(vec![
            name.to_string(),
            format!("{:.0}", num(&j, "completed")),
            format!("{:.0}", num(&j, "throughput_images_s")),
            format!("{:.3}", num(lat, "p50")),
            format!("{:.3}", num(lat, "p99")),
            format!("{:.0}", num(f, "faults_injected")),
            format!("{:.0}", num(f, "failovers")),
            format!("{:.0}", num(f, "retries")),
            format!("{:.0}", num(f, "restarts")),
            format!("{:.0}", num(f, "downtime_epochs")),
            format!("{:.0}", num(f, "dropped_requests")),
            format!("{promoted:.0}"),
            format!("{stolen:.0}"),
        ]);
        rows.push(Json::obj(vec![
            ("scenario", Json::Str(name.to_string())),
            ("spares", Json::Num(spares as f64)),
            ("steal", Json::Bool(steal)),
            ("completed", j.get("completed").clone()),
            ("downtime_epochs", Json::Num(num(f, "downtime_epochs"))),
            ("failovers", Json::Num(num(f, "failovers"))),
            ("standby_promotions", Json::Num(promoted)),
            ("migrated_requests", Json::Num(stolen)),
        ]));
    }
    println!("\n{}", t.render());
    let base = completed[0].1.max(1.0);
    for (name, done) in &completed[1..] {
        println!("{name}: retained {:.1}% of fault-free completions", 100.0 * done / base);
    }
    match t.write_csv("fault_recovery") {
        Ok(p) => println!("wrote {p}"),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
    let fields = vec![
        ("seed", Json::Num(SEED as f64)),
        ("shards", Json::Num(SHARDS as f64)),
        ("duration_s", Json::Num(DURATION_S)),
        ("scenarios", Json::Arr(rows)),
    ];
    match write_bench_json("fault_recovery", fields) {
        Ok(p) => println!("wrote {p}"),
        Err(e) => eprintln!("bench json write failed: {e}"),
    }
}
