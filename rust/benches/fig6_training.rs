//! Fig. 6: value-loss vs training steps for the four NoI topologies.
//! Replays the CSV logs written by `thermos train --noi <x>` and prints
//! raw + exponentially smoothed (α = 0.8, as in the paper) loss curves;
//! asserts the plateau criterion (loss stabilizes below its early value).
//!
//! If a log is missing, a short in-process training run generates one
//! (requires `make artifacts`).
//!
//! Run: `cargo bench --bench fig6_training`

use thermos::noi::NoiTopology;
use thermos::util::stats::ema;

fn read_log(noi: NoiTopology) -> Option<Vec<(usize, f64)>> {
    let path = format!("results/train_{}.csv", noi.name());
    let text = std::fs::read_to_string(&path).ok()?;
    let mut out = Vec::new();
    for line in text.lines().skip(1) {
        let cols: Vec<&str> = line.split(',').collect();
        if cols.len() >= 4 {
            let steps: usize = cols[1].parse().ok()?;
            let vl: f64 = cols[3].parse().ok()?;
            out.push((steps, vl));
        }
    }
    Some(out)
}

#[cfg(feature = "pjrt")]
fn train_quick(noi: NoiTopology) -> Option<Vec<(usize, f64)>> {
    let mut runtime = thermos::runtime::Runtime::open_default().ok()?;
    let cfg = thermos::rl::trainer::TrainConfig {
        noi,
        episodes: 6,
        jobs_per_episode: 20,
        max_images: 1_000,
        episode_max_s: 150.0,
        ..Default::default()
    };
    let mut tr = thermos::rl::trainer::Trainer::new(cfg);
    tr.train(&mut runtime).ok()?;
    tr.write_log_csv(&format!("results/train_{}.csv", noi.name())).ok()?;
    Some(tr.log.iter().map(|e| (e.env_steps, e.value_loss as f64)).collect())
}

#[cfg(not(feature = "pjrt"))]
fn train_quick(noi: NoiTopology) -> Option<Vec<(usize, f64)>> {
    eprintln!(
        "(cannot train a log for {} without the `pjrt` feature — run `thermos train`)",
        noi.name()
    );
    None
}

fn main() {
    println!("== Fig. 6: value loss vs training steps (4 NoIs, ema α=0.8) ==\n");
    for noi in NoiTopology::all() {
        let log = read_log(noi).or_else(|| {
            eprintln!("(no results/train_{}.csv — running a quick training)", noi.name());
            train_quick(noi)
        });
        let Some(log) = log else {
            println!("{:<9} NO LOG (run `thermos train --noi {}`)", noi.name(), noi.name());
            continue;
        };
        if log.is_empty() {
            continue;
        }
        let raw: Vec<f64> = log.iter().map(|&(_, v)| v).collect();
        let sm = ema(&raw, 0.8);
        println!("{} ({} updates):", noi.name(), raw.len());
        // Console sparkline of the smoothed curve.
        let max = sm.iter().cloned().fold(f64::MIN, f64::max).max(1e-9);
        let gl: Vec<char> = " ▁▂▃▄▅▆▇█".chars().collect();
        let line: String = sm
            .iter()
            .map(|&v| gl[((v / max) * (gl.len() - 1) as f64).round() as usize])
            .collect();
        println!("  |{line}|  first {:.4} → last {:.4}", sm[0], *sm.last().unwrap());
        let tail_start = sm.len() - (sm.len() / 3).max(1);
        let tail_mean: f64 =
            sm[tail_start..].iter().sum::<f64>() / (sm.len() - tail_start) as f64;
        let head_mean: f64 = sm[..(sm.len() / 3).max(1)].iter().sum::<f64>()
            / (sm.len() / 3).max(1) as f64;
        println!(
            "  plateau check: head {:.4} vs tail {:.4} — {}",
            head_mean,
            tail_mean,
            if tail_mean <= head_mean { "converging ✓" } else { "not yet (train longer)" }
        );
    }
    println!("\n(paper: all four curves plateau below 0.06 after ~15 M steps)");
}
