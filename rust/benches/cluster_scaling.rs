//! Cluster scaling: drive the same saturating Poisson stream (8 jobs/s,
//! well past one engine's knee) through 1..=4 shards and report fleet
//! throughput, tail latency, coalescing, and arbiter activity. The
//! acceptance property is throughput monotonicity: more shards, more
//! completed images per second, while the global power budget scales with
//! the shard count.
//!
//! Run: `cargo bench --bench cluster_scaling`

use thermos::cluster::{run_cluster, ClusterConfig, ShardSchedSpec, StealConfig};
use thermos::experiments::report::{write_bench_json, Table};
use thermos::serve::{PoissonSource, ServeConfig};
use thermos::sim::SimConfig;
use thermos::util::json::Json;

const SEED: u64 = 11;
const MAX_IMAGES: u64 = 1_000;
const RATE_JOBS_S: f64 = 8.0;
const DURATION_S: f64 = 40.0;

fn num(j: &Json, key: &str) -> f64 {
    j.get(key).as_f64().unwrap_or(0.0)
}

fn run_point(shards: usize, steal: bool) -> Json {
    let cfg = ClusterConfig {
        shards,
        steal: steal.then(|| StealConfig { seed: SEED, slack: 0.25 }),
        duration_s: DURATION_S,
        drain_max_s: 20.0,
        serve: ServeConfig {
            duration_s: DURATION_S,
            tenant_queue_cap: 32,
            max_wait_s: 45.0,
            snapshot_every_s: 0.0,
            pressure_depth: 48,
            sim: SimConfig {
                warmup_s: 0.0,
                max_images: MAX_IMAGES,
                seed: SEED,
                ..SimConfig::default()
            },
        },
        sched: ShardSchedSpec::Simba,
        ..ClusterConfig::default()
    };
    let source = Box::new(PoissonSource::new(RATE_JOBS_S, 80, MAX_IMAGES, [1.0, 1.0, 1.0], SEED));
    run_cluster(cfg, source).expect("cluster run").json
}

fn main() {
    let mut t = Table::new(&[
        "shards", "offered", "coalesced", "completed", "images_s", "steal_images_s", "migrated",
        "p50_s", "p99_s", "rebalances", "maxT_K", "budget_W",
    ]);
    let mut images_s = Vec::new();
    let mut points = Vec::new();
    for shards in 1..=4usize {
        let j = run_point(shards, false);
        let js = run_point(shards, true);
        let lat = j.get("latency_e2e_s");
        let rate = num(&j, "throughput_images_s");
        let steal_rate = num(&js, "throughput_images_s");
        let migrated = num(js.get("steal"), "migrated_requests");
        images_s.push(rate);
        t.row(vec![
            format!("{shards}"),
            format!("{:.0}", num(&j, "offered")),
            format!("{:.0}", num(&j, "coalesced_requests")),
            format!("{:.0}", num(&j, "completed")),
            format!("{rate:.0}"),
            format!("{steal_rate:.0}"),
            format!("{migrated:.0}"),
            format!("{:.3}", num(lat, "p50")),
            format!("{:.3}", num(lat, "p99")),
            format!("{:.0}", num(j.get("arbiter"), "rebalances")),
            format!("{:.1}", num(&j, "max_temp_k")),
            format!("{:.1}", num(&j, "power_budget_w")),
        ]);
        points.push(Json::obj(vec![
            ("shards", Json::Num(shards as f64)),
            ("completed", j.get("completed").clone()),
            ("throughput_images_s", Json::Num(rate)),
            ("steal_throughput_images_s", Json::Num(steal_rate)),
            ("steal_migrated_requests", Json::Num(migrated)),
            ("latency_p99_s", lat.get("p99").clone()),
            ("power_budget_w", j.get("power_budget_w").clone()),
        ]));
    }
    println!("\n{}", t.render());
    let monotone = images_s.windows(2).all(|w| w[1] >= w[0] * 0.95);
    println!(
        "throughput 1→4 shards: {} ({})",
        images_s.iter().map(|x| format!("{x:.0}")).collect::<Vec<_>>().join(" → "),
        if monotone && images_s[3] > images_s[0] {
            "monotone — sharding scales"
        } else {
            "NOT monotone — investigate"
        }
    );
    match t.write_csv("cluster_scaling") {
        Ok(p) => println!("wrote {p}"),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
    let fields = vec![
        ("seed", Json::Num(SEED as f64)),
        ("rate_jobs_s", Json::Num(RATE_JOBS_S)),
        ("duration_s", Json::Num(DURATION_S)),
        ("points", Json::Arr(points)),
    ];
    match write_bench_json("cluster", fields) {
        Ok(p) => println!("wrote {p}"),
        Err(e) => eprintln!("bench json write failed: {e}"),
    }
}
