//! Hot-path benchmarks: (a) simulation engine steps/sec on a steady
//! streaming load — the per-100 ms step path allocates nothing — and
//! (b) wall-clock of a 4-scheduler × 3-rate × 3-seed experiment sweep,
//! serial (1 thread) vs the global work pool, asserting the pooled grid
//! is identical to the serial one and reporting the speedup.
//!
//! Run: `cargo bench --bench hot_path`
//! (THERMOS_BENCH_FAST=1 shrinks windows for CI; THERMOS_THREADS=N sizes
//! the pool.) Emits `results/BENCH_hotpath.json`.

use thermos::arch::Arch;
use thermos::experiments::{load_relmas_actor, load_thermos_theta, sweep_averaged, SchedKind};
use thermos::noi::NoiTopology;
use thermos::sched::SimbaSched;
use thermos::sim::{SimConfig, SimResult, Simulator};
use thermos::util::bench::{time_once, Group};
use thermos::util::json::Json;
use thermos::util::pool::{global_threads, WorkPool};

fn fast() -> bool {
    std::env::var("THERMOS_BENCH_FAST").as_deref() == Ok("1")
}

/// Byte-identical determinism is the pool's contract, so the comparison
/// is exact `==` on every digested metric — no tolerance.
fn assert_grids_identical(a: &[Vec<SimResult>], b: &[Vec<SimResult>]) {
    assert_eq!(a.len(), b.len());
    for (ra, rb) in a.iter().zip(b) {
        assert_eq!(ra.len(), rb.len());
        for (x, y) in ra.iter().zip(rb) {
            assert_eq!(x.scheduler, y.scheduler);
            assert_eq!(x.throughput_jobs_s, y.throughput_jobs_s);
            assert_eq!(x.mean_exec_s, y.mean_exec_s);
            assert_eq!(x.mean_e2e_s, y.mean_e2e_s);
            assert_eq!(x.mean_energy_j, y.mean_energy_j);
            assert_eq!(x.mean_edp, y.mean_edp);
            assert_eq!(x.violation_chiplet_s, y.violation_chiplet_s);
            assert_eq!(x.system_energy_j, y.system_energy_j);
            assert_eq!(x.max_temp_k, y.max_temp_k);
            assert_eq!(x.throttle_events, y.throttle_events);
        }
    }
}

fn main() {
    // (a) Engine steps/sec on a loaded system.
    let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
    let cfg = SimConfig { admit_rate: 2.0, seed: 1, ..SimConfig::default() };
    let mut sim = Simulator::new(&arch, SimbaSched::new(arch.clone()), cfg);
    for _ in 0..50 {
        sim.step(); // reach a loaded steady state before measuring
    }
    let mut g = Group::new("simulation hot path");
    let step_mean_ns = g.bench("engine.step (mesh, simba, 2 DNN/s)", || sim.step()).mean_ns;
    let steps_per_sec = 1e9 / step_mean_ns;
    println!(
        "≈ {steps_per_sec:.0} steps/s ({:.0} sim-seconds per wall-second)",
        steps_per_sec * 0.1
    );

    // (b) Serial vs pooled sweep wall-clock.
    let noi = NoiTopology::Mesh;
    let (theta, _) = load_thermos_theta(noi);
    let (actor, _) = load_relmas_actor(noi, arch.num_chiplets());
    let kinds = vec![
        SchedKind::Simba,
        SchedKind::BigLittle,
        SchedKind::Relmas { actor },
        SchedKind::Thermos { theta, pref: [0.5, 0.5], label: "balanced" },
    ];
    let rates = [1.0, 2.0, 4.0];
    let seeds = [11u64, 22, 33];
    let (warmup_s, duration_s, max_images, mix_jobs) =
        if fast() { (2.0, 12.0, 400, 40) } else { (5.0, 40.0, 1_500, 100) };
    let cfg_of = move |rate: f64, seed: u64| SimConfig {
        admit_rate: rate,
        warmup_s,
        duration_s,
        max_images,
        mix_jobs,
        seed,
        ..SimConfig::default()
    };

    let tasks = kinds.len() * rates.len() * seeds.len();
    println!(
        "\n== sweep: {} schedulers × {} rates × {} seeds = {tasks} runs ==",
        kinds.len(),
        rates.len(),
        seeds.len()
    );
    let (serial, serial_t) =
        time_once(|| sweep_averaged(noi, &kinds, &rates, &seeds, &WorkPool::new(1), cfg_of));
    let threads = global_threads();
    let (pooled, pooled_t) =
        time_once(|| sweep_averaged(noi, &kinds, &rates, &seeds, &WorkPool::global(), cfg_of));
    assert_grids_identical(&serial, &pooled);
    let serial_s = serial_t.as_secs_f64();
    let pooled_s = pooled_t.as_secs_f64();
    let speedup = serial_s / pooled_s.max(1e-9);
    println!("serial (1 thread):   {serial_s:.2} s");
    println!("pooled ({threads} threads):  {pooled_s:.2} s  → {speedup:.2}× speedup");
    println!("pooled grid identical to serial grid ✓");

    let json = Json::obj(vec![
        ("bench", Json::Str("hot_path".into())),
        ("steps_per_sec", Json::from(steps_per_sec)),
        ("step_mean_ns", Json::from(step_mean_ns)),
        ("sweep_tasks", Json::from(tasks as f64)),
        ("serial_s", Json::from(serial_s)),
        ("pooled_s", Json::from(pooled_s)),
        ("speedup", Json::from(speedup)),
        ("threads", Json::from(threads as f64)),
        ("fast_mode", Json::Bool(fast())),
    ]);
    std::fs::create_dir_all("results").expect("create results/");
    let path = "results/BENCH_hotpath.json";
    std::fs::write(path, json.to_string_pretty() + "\n").expect("write bench json");
    println!("wrote {path}");
}
