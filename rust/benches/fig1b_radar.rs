//! Fig. 1b: radar comparison of homogeneous vs heterogeneous PIM systems
//! at equal processing area — execution time, energy, memory density, and
//! thermal sensitivity. Each homogeneous system replaces the paper's
//! four-cluster mix with one PIM type sized to the same total area; the
//! heterogeneous system should dominate the aggregate trade-off.
//!
//! Run: `cargo bench --bench fig1b_radar`

use thermos::arch::{Arch, PimType};
use thermos::experiments::report::Table;
use thermos::experiments::{fast_mode, run_one, SchedKind};
use thermos::noi::NoiTopology;
use thermos::sim::SimConfig;

fn main() {
    let rate = 1.5;
    let cfg = SimConfig {
        admit_rate: rate,
        warmup_s: if fast_mode() { 10.0 } else { 30.0 },
        duration_s: if fast_mode() { 60.0 } else { 180.0 },
        max_images: 2_000,
        mix_jobs: 200,
        seed: 17,
        ..SimConfig::default()
    };

    println!("== Fig. 1b: homogeneous vs heterogeneous at equal area (@{rate} DNN/s) ==\n");
    let mut t = Table::new(&[
        "system", "chiplets", "mem_MB", "exec_s", "energy_j", "mem_density_MB_mm2",
        "violation_chiplet_s", "max_temp_k", "throughput",
    ]);

    // Homogeneous systems of each PIM type + the heterogeneous system.
    let mut systems: Vec<(String, Arch)> = PimType::all()
        .into_iter()
        .map(|p| {
            (
                format!("homogeneous_{}", p.name()),
                Arch::homogeneous_equal_area(NoiTopology::Mesh, p),
            )
        })
        .collect();
    systems.push(("heterogeneous".into(), Arch::paper_heterogeneous(NoiTopology::Mesh)));

    for (name, arch) in &systems {
        // Simba scheduling is type-blind, making it a fair common policy.
        let sched = thermos::sched::SimbaSched::new(arch.clone());
        let (r, _) = thermos::sim::Simulator::new(arch, sched, cfg.clone()).run();
        let mem_mb = arch.total_memory_bits() as f64 / 8e6;
        let density = mem_mb / arch.total_area_mm2();
        if r.jobs.is_empty() {
            // e.g. the all-ADC-less system cannot even hold AlexNet's
            // weights — the radar's "memory density" axis at its extreme.
            println!(
                "{:<28} cannot sustain the mix (total weight memory {:.1} MB too small)",
                name, mem_mb
            );
            t.row(vec![
                name.clone(),
                arch.num_chiplets().to_string(),
                format!("{:.1}", mem_mb),
                "inf".into(),
                "inf".into(),
                format!("{:.3}", density),
                format!("{:.2}", r.violation_chiplet_s),
                format!("{:.1}", r.max_temp_k),
                "0".into(),
            ]);
            continue;
        }
        println!(
            "{:<28} exec {:>7.3} s  energy {:>8.4} J  density {:>5.2} MB/mm²  viol {:>7.1} c·s  maxT {:>5.1} K",
            name, r.mean_exec_s, r.mean_energy_j, density, r.violation_chiplet_s, r.max_temp_k
        );
        t.row(vec![
            name.clone(),
            arch.num_chiplets().to_string(),
            format!("{:.1}", mem_mb),
            format!("{:.4}", r.mean_exec_s),
            format!("{:.5}", r.mean_energy_j),
            format!("{:.3}", density),
            format!("{:.2}", r.violation_chiplet_s),
            format!("{:.1}", r.max_temp_k),
            format!("{:.3}", r.throughput_jobs_s),
        ]);
    }
    println!("\n(radar shape: standard=fast/hot, adc-less=efficient/small-memory,");
    println!(" accumulator=dense, shared-adc=balanced; heterogeneous=best overall)");
    match t.write_csv("fig1b_radar") {
        Ok(p) => println!("wrote {p}"),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
    let _ = run_one(NoiTopology::Mesh, &SchedKind::Simba, cfg); // keep linkage honest
}
