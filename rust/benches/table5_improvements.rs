//! Table 5: average percentage improvement of the single multi-objective
//! THERMOS policy over Simba, Big-Little, and RELMAS across all four NoI
//! architectures — % speedup (THERMOS.exe_time), % energy reduction
//! (THERMOS.energy), % EDP improvement (THERMOS.balanced), averaged over
//! throughput scenarios.
//!
//! Run: `cargo bench --bench table5_improvements`

use thermos::experiments::report::{pct_improvement, Table};
use thermos::experiments::{fast_mode, standard_contenders, sweep_standard};
use thermos::noi::NoiTopology;
use thermos::util::stats::mean;

fn main() {
    let rates: Vec<f64> = if fast_mode() { vec![1.5, 2.5] } else { vec![1.5, 2.5, 3.5] };

    println!("== Table 5: average % improvement of THERMOS vs baselines ==");
    let mut table = Table::new(&[
        "noi",
        "speedup_vs_simba", "speedup_vs_biglittle", "speedup_vs_relmas",
        "energy_vs_simba", "energy_vs_biglittle", "energy_vs_relmas",
        "edp_vs_simba", "edp_vs_biglittle", "edp_vs_relmas",
    ]);

    for noi in NoiTopology::all() {
        // Pool the per-NoI grid, then accumulate per-rate metrics per
        // scheduler in the old rate-major visit order.
        let contenders = standard_contenders(noi);
        let grid = sweep_standard(noi, &contenders, &rates);
        let mut exec: std::collections::HashMap<String, Vec<f64>> = Default::default();
        let mut energy: std::collections::HashMap<String, Vec<f64>> = Default::default();
        let mut edp: std::collections::HashMap<String, Vec<f64>> = Default::default();
        for ri in 0..rates.len() {
            for ki in 0..contenders.len() {
                let r = &grid[ki][ri];
                if r.jobs.is_empty() {
                    continue; // scheduler saturated below this rate
                }
                exec.entry(r.scheduler.clone()).or_default().push(r.mean_exec_s);
                energy.entry(r.scheduler.clone()).or_default().push(r.mean_energy_j);
                edp.entry(r.scheduler.clone()).or_default().push(r.mean_edp);
            }
        }
        let avg = |m: &std::collections::HashMap<String, Vec<f64>>, k: &str| -> f64 {
            m.get(k).map(|v| mean(v)).unwrap_or(f64::NAN)
        };
        let pct = |m: &std::collections::HashMap<String, Vec<f64>>, ours: &str, base: &str| {
            pct_improvement(avg(m, base), avg(m, ours))
        };
        let row = vec![
            noi.name().to_string(),
            format!("{:.1}", pct(&exec, "thermos.exec_time", "simba")),
            format!("{:.1}", pct(&exec, "thermos.exec_time", "big_little")),
            format!("{:.1}", pct(&exec, "thermos.exec_time", "relmas")),
            format!("{:.1}", pct(&energy, "thermos.energy", "simba")),
            format!("{:.1}", pct(&energy, "thermos.energy", "big_little")),
            format!("{:.1}", pct(&energy, "thermos.energy", "relmas")),
            format!("{:.1}", pct(&edp, "thermos.balanced", "simba")),
            format!("{:.1}", pct(&edp, "thermos.balanced", "big_little")),
            format!("{:.1}", pct(&edp, "thermos.balanced", "relmas")),
        ];
        println!(
            "{}: speedup [{} {} {}]  energy [{} {} {}]  EDP [{} {} {}]",
            row[0], row[1], row[2], row[3], row[4], row[5], row[6], row[7], row[8], row[9]
        );
        table.row(row);
    }
    println!("\n{}", table.render());
    println!("(paper Table 5 shape: all entries positive; Big-Little column largest,");
    println!(" Simba/RELMAS moderate; energy gains smaller than speedups.)");
    match table.write_csv("table5_improvements") {
        Ok(p) => println!("wrote {p}"),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
}
