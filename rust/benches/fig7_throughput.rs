//! Fig. 7 (Mesh NoI): (a) achieved throughput vs host admit rate and
//! (b) end-to-end latency vs achieved throughput, for the three baselines
//! and the single THERMOS policy under its three runtime preferences.
//!
//! Run: `cargo bench --bench fig7_throughput`
//! (THERMOS_EXP_FAST=1 for a CI-scale run; THERMOS_THREADS=N to size the
//! work pool — rows are identical for any value.)

use thermos::experiments::report::{result_cells, Table, RESULT_HEADERS};
use thermos::experiments::{fast_mode, standard_contenders, sweep_standard};
use thermos::noi::NoiTopology;

fn main() {
    let noi = NoiTopology::Mesh;
    let rates: Vec<f64> = if fast_mode() {
        vec![1.0, 2.0, 4.0]
    } else {
        vec![0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 4.0, 6.0]
    };
    let contenders = standard_contenders(noi);

    println!("== Fig. 7: throughput vs admit rate, e2e latency vs throughput (mesh) ==");
    // Every (scheduler × rate × seed) run executes on the work pool up
    // front; the grid comes back kind-major, matching the old serial
    // loop's row order exactly.
    let grid = sweep_standard(noi, &contenders, &rates);
    let mut table = Table::new(&RESULT_HEADERS);
    for (kind, row) in contenders.iter().zip(&grid) {
        let mut saturated = 0.0f64;
        for (&rate, r) in rates.iter().zip(row) {
            saturated = saturated.max(r.throughput_jobs_s);
            table.row(result_cells(rate, r));
        }
        println!(
            "{:<22} max achieved throughput: {:.2} DNN/s",
            kind.label(),
            saturated
        );
    }
    println!("\n{}", table.render());
    match table.write_csv("fig7_throughput") {
        Ok(p) => println!("wrote {p}"),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
}
