//! §5.3 "Thermal Constraint Effectiveness": unconstrained vs
//! thermally-constrained scheduling. Without Eq. 2 throttling the system
//! sustains long violations of the ReRAM 330 K limit; with it, violations
//! collapse to brief excursions at a modest throughput cost.
//!
//! Run: `cargo bench --bench thermal_effectiveness`

use thermos::arch::Arch;
use thermos::experiments::fast_mode;
use thermos::experiments::report::Table;
use thermos::noi::NoiTopology;
use thermos::sched::SimbaSched;
use thermos::sim::{SimConfig, Simulator};

fn main() {
    let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
    let rates = if fast_mode() { vec![2.0, 4.0] } else { vec![1.0, 2.0, 3.0, 4.0, 5.0] };

    println!("== §5.3: thermal constraint effectiveness (mesh, Simba load) ==\n");
    let mut t = Table::new(&[
        "admit_rate", "constrained", "throughput", "violation_chiplet_s", "max_temp_k",
        "throttle_events", "mean_exec_s",
    ]);
    for &rate in &rates {
        for constrained in [false, true] {
            let cfg = SimConfig {
                admit_rate: rate,
                warmup_s: 0.0,
                duration_s: if fast_mode() { 80.0 } else { 240.0 },
                max_images: 3_000,
                mix_jobs: 300,
                seed: 23,
                thermal_constraint: constrained,
                ..SimConfig::default()
            };
            let (r, _) = Simulator::new(&arch, SimbaSched::new(arch.clone()), cfg).run();
            println!(
                "rate {:>4.1}  constrained={:<5}  viol {:>8.1} chiplet·s  maxT {:>6.1} K  throttles {:>4}  thpt {:>5.2}",
                rate, constrained, r.violation_chiplet_s, r.max_temp_k, r.throttle_events,
                r.throughput_jobs_s
            );
            t.row(vec![
                format!("{rate}"),
                constrained.to_string(),
                format!("{:.3}", r.throughput_jobs_s),
                format!("{:.2}", r.violation_chiplet_s),
                format!("{:.2}", r.max_temp_k),
                r.throttle_events.to_string(),
                format!("{:.3}", r.mean_exec_s),
            ]);
        }
    }
    println!("\n(expected shape: constrained runs bound max_temp near the 330 K ReRAM");
    println!(" limit and cut violation time by orders of magnitude vs unconstrained)");
    match t.write_csv("thermal_effectiveness") {
        Ok(p) => println!("wrote {p}"),
        Err(e) => eprintln!("csv write failed: {e}"),
    }
}
