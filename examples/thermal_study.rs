//! Thermal study: stream a hot workload mix, trace per-cluster peak
//! temperatures, and show Eq. 2 throttling protecting the ReRAM clusters
//! (330 K) while SRAM clusters ride to their higher 358 K limit.
//!
//! Run: `cargo run --release --example thermal_study [rate]`

use thermos::arch::Arch;
use thermos::noi::NoiTopology;
use thermos::sched::SimbaSched;
use thermos::sim::{SimConfig, Simulator};

fn main() {
    let rate: f64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4.0);
    let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);

    for constrained in [true, false] {
        let cfg = SimConfig {
            admit_rate: rate,
            warmup_s: 0.0,
            duration_s: 120.0,
            max_images: 3_000,
            mix_jobs: 200,
            seed: 3,
            thermal_constraint: constrained,
            record_trace: true,
            ..SimConfig::default()
        };
        let sched = SimbaSched::new(arch.clone());
        let (r, _) = Simulator::new(&arch, sched, cfg).run();
        println!(
            "\n=== thermal constraint {} ===",
            if constrained { "ENABLED (Eq. 2 throttling)" } else { "DISABLED" }
        );
        println!(
            "max temp {:.1} K | violation {:.1} chiplet·s | throttle events {} | throughput {:.2} DNN/s",
            r.max_temp_k, r.violation_chiplet_s, r.throttle_events, r.throughput_jobs_s
        );
        // ASCII temperature trace: peak ReRAM-cluster temp over time.
        println!("peak standard-ReRAM cluster temperature (· = 1 s, limit 330 K):");
        let tmax = 330.0;
        for chunk in r.trace.chunks(100) {
            // 100 × 0.1 s = 10 s per row
            let peak = chunk
                .iter()
                .map(|p| p.cluster_max_temp_k[0])
                .fold(f64::MIN, f64::max);
            let bar_len = ((peak - 300.0) / 1.0).clamp(0.0, 60.0) as usize;
            let marker = if peak > tmax { " ⚠ OVER" } else { "" };
            println!(
                "  t={:>5.0}s {:>6.1} K |{}{}",
                chunk[0].t_s,
                peak,
                "#".repeat(bar_len),
                marker
            );
        }
    }
    println!("\nthermal_study OK");
}
