//! Quickstart: build the paper's 78-chiplet heterogeneous PIM system,
//! schedule a ResNet-50 with the two-level THERMOS scheduler, and inspect
//! the resulting mapping and execution profile.
//!
//! Run: `cargo run --release --example quickstart`

use thermos::arch::Arch;
use thermos::noi::NoiTopology;
use thermos::pim::ComputeModel;
use thermos::sched::policy::NativeDdt;
use thermos::sched::state::{StateEncoder, NUM_CLUSTERS, STATE_DIM};
use thermos::sched::thermos::{ThermosSched, PREF_BALANCED, PREF_ENERGY, PREF_EXEC_TIME};
use thermos::sched::{Scheduler, SysSnapshot};
use thermos::sim::ExecProfile;
use thermos::util::rng::Rng;
use thermos::workload::{DnnModel, Job, ModelZoo};

fn main() {
    // 1. The Table 3 system on a mesh NoI.
    let arch = Arch::paper_heterogeneous(NoiTopology::Mesh);
    println!(
        "system: {} chiplets, {:.1} MB crossbar memory, {:.0} mm², {} NoI links",
        arch.num_chiplets(),
        arch.total_memory_bits() as f64 / 8e6,
        arch.total_area_mm2(),
        arch.topology.num_links
    );

    // 2. A workload: ResNet-50 over 5 000 images.
    let zoo = ModelZoo::new();
    let job = Job { id: 0, dcg: zoo.dcg(DnnModel::ResNet50), images: 5_000, arrival_s: 0.0 };
    println!(
        "workload: {} — {} layers, {:.1}M params, {:.2}G MACs/image",
        job.dcg.model.name(),
        job.dcg.num_layers(),
        job.dcg.total_weight_bits() as f64 / 8e6,
        job.dcg.total_macs() as f64 / 1e9
    );

    // 3. THERMOS two-level scheduling with the balanced preference.
    //    (Use `results/thermos_mesh.params` after `thermos train` for the
    //    trained policy; the quickstart uses a fresh DDT.)
    let theta = match thermos::runtime::params_io::load("results/thermos_mesh.params") {
        Ok(p) => {
            println!("policy: trained (results/thermos_mesh.params)");
            p[..thermos::sched::policy::ddt_theta_len(STATE_DIM, NUM_CLUSTERS)].to_vec()
        }
        Err(_) => {
            println!("policy: untrained DDT (run `thermos train` for the trained one)");
            NativeDdt::init(STATE_DIM, NUM_CLUSTERS, &mut Rng::new(1)).theta
        }
    };
    let pref = match std::env::args().nth(1).as_deref() {
        Some("exec") => PREF_EXEC_TIME,
        Some("energy") => PREF_ENERGY,
        _ => PREF_BALANCED,
    };
    println!("preference ω = [{}, {}]", pref[0], pref[1]);
    let encoder = StateEncoder::new(&arch, &zoo, 20_000);
    let policy = NativeDdt::new(STATE_DIM, NUM_CLUSTERS, theta);
    let mut sched = ThermosSched::new(arch.clone(), encoder, policy, pref);

    let snap = SysSnapshot::fresh(&arch);
    let mapping = sched.schedule(&job, &snap).expect("fits in the empty system");

    // 4. Inspect the mapping: which clusters got which layers.
    let mut per_cluster = [0u64; 4];
    for la in &mapping.layers {
        for &(c, bits) in &la.parts {
            per_cluster[arch.chiplets[c].pim as usize] += bits;
        }
    }
    println!("\nweight placement by PIM cluster:");
    for (cl, &bits) in per_cluster.iter().enumerate() {
        println!(
            "  {:<12} {:>8.2} MB ({:>4.1}% of model)",
            arch.specs[cl].pim.name(),
            bits as f64 / 8e6,
            100.0 * bits as f64 / job.dcg.total_weight_bits() as f64
        );
    }

    // 5. The deterministic execution profile (primary-reward basis).
    let profile = ExecProfile::compute(&arch, &ComputeModel::default(), &job.dcg, &mapping);
    println!("\nexecution profile:");
    println!("  pipeline fill latency : {:>9.3} ms/frame", profile.frame_latency_s * 1e3);
    println!("  bottleneck stage      : {:>9.3} ms/frame", profile.bottleneck_s * 1e3);
    println!("  steady throughput     : {:>9.1} frames/s", 1.0 / profile.bottleneck_s);
    println!("  dynamic energy        : {:>9.3} mJ/frame", profile.frame_energy_j * 1e3);
    println!("  weight-load time      : {:>9.3} s", profile.load_time_s);
    println!(
        "  {} images → exec {:.2} s, energy {:.2} J",
        job.images,
        profile.ideal_exec_s(job.images),
        profile.ideal_dynamic_j(job.images)
    );
    println!("\nquickstart OK");
}
