//! Multi-NoI comparison (the §5.4 scenario): run the same streaming
//! workload over Mesh, Kite, Floret, and HexaMesh interposer networks and
//! compare topology quality and end-to-end metrics.
//!
//! Run: `cargo run --release --example multi_noi [rate]`

use thermos::arch::Arch;
use thermos::experiments::report::Table;
use thermos::experiments::{self, SchedKind};
use thermos::noi::NoiTopology;
use thermos::sim::SimConfig;

fn main() {
    let rate: f64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(2.0);

    println!("NoI topology properties (78-chiplet system):\n");
    let mut tprops = Table::new(&["noi", "links", "mean_hops", "diameter"]);
    for noi in NoiTopology::all() {
        let arch = Arch::paper_heterogeneous(noi);
        tprops.row(vec![
            noi.name().to_string(),
            arch.topology.num_links.to_string(),
            format!("{:.2}", arch.topology.mean_hops()),
            arch.topology.diameter().to_string(),
        ]);
    }
    println!("{}", tprops.render());

    let cfg = SimConfig {
        admit_rate: rate,
        warmup_s: 20.0,
        duration_s: 100.0,
        max_images: 2_000,
        mix_jobs: 150,
        seed: 5,
        ..SimConfig::default()
    };
    println!("streaming comparison @ {rate} DNN/s (Simba nearest-neighbour scheduler):\n");
    let mut t = Table::new(&["noi", "throughput", "exec_s", "e2e_s", "energy_j", "max_temp_k"]);
    for noi in NoiTopology::all() {
        let r = experiments::run_one(noi, &SchedKind::Simba, cfg.clone());
        t.row(vec![
            noi.name().to_string(),
            format!("{:.3}", r.throughput_jobs_s),
            format!("{:.3}", r.mean_exec_s),
            format!("{:.3}", r.mean_e2e_s),
            format!("{:.4}", r.mean_energy_j),
            format!("{:.1}", r.max_temp_k),
        ]);
    }
    println!("{}", t.render());
    println!("multi_noi OK");
}
