//! End-to-end driver: exercises ALL layers of the stack on a real small
//! workload —
//!
//!   L1/L2 artifacts (Pallas DDT kernel + PPO update graph, AOT HLO)
//!     → loaded by the rust PJRT runtime,
//!   L3 trainer: PPO episodes over the streaming simulator, updating the
//!     policy through the `ppo_update_thermos` artifact,
//!   then an evaluation streaming run comparing the trained single
//!   multi-preference policy against the Simba/Big-Little baselines and
//!   reporting the paper's headline metrics (throughput, execution time,
//!   energy, EDP).
//!
//! Requires `make artifacts` first. Run:
//!   cargo run --release --example end_to_end [episodes] [rate]

use thermos::experiments::{self, SchedKind};
use thermos::noi::NoiTopology;
use thermos::rl::trainer::{TrainConfig, Trainer};
use thermos::runtime::Runtime;
use thermos::sched::policy::ddt_theta_len;
use thermos::sched::state::{NUM_CLUSTERS, STATE_DIM};
use thermos::sim::SimConfig;
use thermos::util::stats::ema;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let episodes: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(12);
    let rate: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(2.0);

    // ---- 1. open the AOT artifacts through PJRT --------------------------
    let mut runtime = Runtime::open_default()?;
    println!(
        "runtime: platform={} artifacts={} (abi v. state_dim={} θ={} φ={})",
        runtime.platform(),
        runtime.abi.artifacts.len(),
        runtime.abi.state_dim,
        runtime.abi.theta_len,
        runtime.abi.phi_len
    );

    // ---- 2. train the MORL policy (3 preference envs / episode) ---------
    let cfg = TrainConfig {
        noi: NoiTopology::Mesh,
        episodes,
        jobs_per_episode: 40,
        max_images: 2_000,
        episode_max_s: 240.0,
        seed: 42,
        ..TrainConfig::default()
    };
    println!("\ntraining: {episodes} episodes × 3 preference environments …");
    let mut trainer = Trainer::new(cfg);
    let t0 = std::time::Instant::now();
    let params = trainer.train(&mut runtime)?;
    println!(
        "trained {} env steps in {:.1} s ({} policy updates)",
        trainer.total_env_steps,
        t0.elapsed().as_secs_f64(),
        trainer.log.len()
    );
    let losses: Vec<f64> = trainer.log.iter().map(|e| e.value_loss as f64).collect();
    if losses.len() >= 4 {
        let sm = ema(&losses, 0.8);
        println!(
            "value loss: first {:.4} → last {:.4} (smoothed, Fig. 6 criterion: plateau)",
            sm[0],
            sm[sm.len() - 1]
        );
    }

    // ---- 3. evaluation: trained THERMOS vs baselines ---------------------
    let theta = params[..ddt_theta_len(STATE_DIM, NUM_CLUSTERS)].to_vec();
    let eval_cfg = SimConfig {
        admit_rate: rate,
        warmup_s: 20.0,
        duration_s: 120.0,
        max_images: 2_000,
        mix_jobs: 200,
        seed: 99,
        ..SimConfig::default()
    };
    let contenders = vec![
        SchedKind::Simba,
        SchedKind::BigLittle,
        SchedKind::Thermos { theta: theta.clone(), pref: [1.0, 0.0], label: "exec_time" },
        SchedKind::Thermos { theta: theta.clone(), pref: [0.5, 0.5], label: "balanced" },
        SchedKind::Thermos { theta, pref: [0.0, 1.0], label: "energy" },
    ];
    println!("\nevaluation @ {rate} DNN/s admit rate (mesh NoI):");
    let mut table = thermos::experiments::report::Table::new(&[
        "scheduler", "throughput", "exec_s", "energy_j", "edp",
    ]);
    let mut base_exec = 0.0;
    let mut best_exec = f64::MAX;
    for kind in &contenders {
        let r = experiments::run_averaged(NoiTopology::Mesh, kind, &eval_cfg, &[99, 123]);
        if kind.label() == "simba" {
            base_exec = r.mean_exec_s;
        }
        if kind.label().starts_with("thermos") {
            best_exec = best_exec.min(r.mean_exec_s);
        }
        table.row(vec![
            r.scheduler.clone(),
            format!("{:.3}", r.throughput_jobs_s),
            format!("{:.3}", r.mean_exec_s),
            format!("{:.4}", r.mean_energy_j),
            format!("{:.4}", r.mean_edp),
        ]);
    }
    println!("{}", table.render());
    if base_exec > 0.0 && best_exec < f64::MAX {
        println!(
            "headline: THERMOS best-pref execution time {:.1}% vs Simba ({})",
            (base_exec - best_exec) / best_exec * 100.0,
            if best_exec <= base_exec { "faster ✓" } else { "slower — train longer" },
        );
    }
    println!("\nend_to_end OK");
    Ok(())
}
