"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps batch sizes and input magnitudes; assert_allclose against
ref.py is THE core correctness signal for the kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.kernels import ddt as ddt_mod
from compile.kernels import mlp as mlp_mod
from compile.kernels.ref import ddt_forward_ref, mlp_forward_ref

SET = settings(max_examples=25, deadline=None)


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(key, shape, dtype=jnp.float32)


class TestDdtKernel:
    @SET
    @given(
        batch=st.sampled_from([1, 2, 3, 7, 16, 64, 128]),
        seed=st.integers(0, 2**31 - 1),
        scale=st.sampled_from([0.1, 1.0, 5.0]),
    )
    def test_matches_ref_across_shapes(self, batch, seed, scale):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        theta = M.init_ddt(k1)
        x = rand(k2, (batch, M.STATE_DIM), scale)
        got = M.policy_logits_pallas(theta, x)
        want = ddt_forward_ref(theta, x, state_dim=M.STATE_DIM, num_actions=M.NUM_CLUSTERS)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)

    def test_grid_tiled_batch_matches_ref(self):
        # B=256 exercises the multi-tile BlockSpec path (block_b=128).
        k1, k2 = jax.random.split(jax.random.PRNGKey(3))
        theta = M.init_ddt(k1)
        x = rand(k2, (256, M.STATE_DIM))
        got = M.policy_logits_pallas(theta, x)
        want = ddt_forward_ref(theta, x, state_dim=M.STATE_DIM, num_actions=M.NUM_CLUSTERS)
        assert got.shape == (256, M.NUM_CLUSTERS)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)

    def test_theta_len_matches_abi(self):
        assert ddt_mod.theta_len(M.STATE_DIM, M.NUM_CLUSTERS) == 872
        assert M.THETA_LEN == 872

    def test_path_probabilities_sum_to_one(self):
        # Uniform leaves of 1.0 => output exactly 1 for every action.
        theta = M.init_ddt(jax.random.PRNGKey(0))
        wlen = ddt_mod.INTERNAL * M.STATE_DIM
        theta = theta.at[wlen + 2 * ddt_mod.INTERNAL :].set(1.0)
        x = rand(jax.random.PRNGKey(1), (16, M.STATE_DIM), 2.0)
        out = M.policy_logits_pallas(theta, x)
        np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-5)

    def test_output_within_leaf_hull(self):
        # Convex mixture: outputs bounded by per-action leaf min/max.
        theta = M.init_ddt(jax.random.PRNGKey(7))
        _, _, _, leaves = ddt_mod.unpack(theta, M.STATE_DIM, M.NUM_CLUSTERS)
        x = rand(jax.random.PRNGKey(8), (32, M.STATE_DIM))
        out = np.asarray(M.policy_logits_pallas(theta, x))
        lo = np.asarray(leaves).min(axis=0) - 1e-5
        hi = np.asarray(leaves).max(axis=0) + 1e-5
        assert (out >= lo).all() and (out <= hi).all()


class TestMlpKernel:
    @SET
    @given(
        batch=st.sampled_from([1, 5, 64, 128]),
        seed=st.integers(0, 2**31 - 1),
        dims=st.sampled_from([(22, 64, 64, 64, 2), (10, 16, 3), (168, 128, 128, 78)]),
    )
    def test_matches_ref_across_dims(self, batch, seed, dims):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        params = M.init_mlp(k1, dims)
        x = rand(k2, (batch, dims[0]))
        got = mlp_mod.mlp_forward(params, x, dims=dims)
        want = mlp_forward_ref(params, x, dims=dims)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)

    def test_grid_tiled_batch(self):
        dims = M.CRITIC_DIMS
        params = M.init_mlp(jax.random.PRNGKey(0), dims)
        x = rand(jax.random.PRNGKey(1), (256, dims[0]))
        got = mlp_mod.mlp_forward(params, x, dims=dims)
        want = mlp_forward_ref(params, x, dims=dims)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)

    def test_param_len(self):
        assert mlp_mod.param_len(M.CRITIC_DIMS) == 9922
        assert mlp_mod.param_len(M.RELMAS_ACTOR_DIMS) == M.RELMAS_THETA_LEN

    def test_relu_clamps_hidden(self):
        # All-negative first-layer weights + all-positive input => hidden 0
        # => output equals final bias (0).
        dims = (4, 8, 2)
        n = mlp_mod.param_len(dims)
        params = jnp.zeros(n)
        params = params.at[: 4 * 8].set(-1.0)
        x = jnp.ones((3, 4), dtype=jnp.float32)
        out = mlp_mod.mlp_forward(params, x, dims=dims)
        np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-7)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
