"""AOT exporter checks: every artifact lowers to parseable HLO text and
the abi manifest stays consistent with the model constants."""

import json

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M


class TestAbi:
    def test_abi_dims_consistent(self):
        abi = aot.abi()
        assert abi["theta_len"] == 31 * (22 + 2) + 32 * 4 == 872
        assert abi["phi_len"] == M.PHI_LEN
        assert abi["relmas_obs"] == 2 * abi["num_chiplets"] + 12
        assert abi["update_batch"] % 128 == 0, "batch must tile the kernels"
        assert abi["lr"] == pytest.approx(5e-4)
        assert abi["clip_eps"] == pytest.approx(0.1)

    def test_abi_is_json_serializable(self):
        text = json.dumps(aot.abi())
        back = json.loads(text)
        assert back["state_dim"] == 22


class TestLowering:
    def test_policy_artifact_lowers_to_hlo_text(self):
        arts = aot.artifact_specs()
        fn, specs, io = arts["ddt_policy"]
        text = aot.to_hlo_text(fn, *specs)
        # HLO text structure: a module with an ENTRY computation.
        assert "HloModule" in text
        assert "ENTRY" in text
        assert "f32[872]" in text, "theta input shape present"
        assert io["outputs"] == ["logits[1,4]"]

    def test_update_artifact_has_all_io(self):
        arts = aot.artifact_specs()
        fn, specs, io = arts["ppo_update_thermos"]
        assert len(specs) == 10
        assert len(io["inputs"]) == 10
        assert len(io["outputs"]) == 7
        text = aot.to_hlo_text(fn, *specs)
        p = M.THETA_LEN + M.PHI_LEN
        assert f"f32[{p}]" in text

    def test_every_artifact_lowers(self):
        # Smoke-lower each (cheap: lowering only, no compile/execute).
        for name, (fn, specs, _) in aot.artifact_specs().items():
            text = aot.to_hlo_text(fn, *specs)
            assert text.startswith("HloModule"), name
            assert len(text) > 500, f"{name} suspiciously small"


class TestUpdateGraphSemantics:
    def test_update_is_pure_function_of_inputs(self):
        # Same inputs -> identical outputs (no hidden state; required for
        # the AOT contract with the rust driver).
        key = jax.random.PRNGKey(0)
        theta = M.init_ddt(key)
        phi = M.init_mlp(jax.random.PRNGKey(1), M.CRITIC_DIMS)
        params = jnp.concatenate([theta, phi])
        P = params.shape[0]
        B = M.UPDATE_BATCH
        x = jax.random.normal(jax.random.PRNGKey(2), (B, M.STATE_DIM), dtype=jnp.float32)
        a = jax.nn.one_hot(jnp.zeros(B, dtype=jnp.int32), 4, dtype=jnp.float32)
        mask = jnp.ones((B, 4), dtype=jnp.float32)
        logp = jnp.full((B,), -1.0, dtype=jnp.float32)
        adv = jnp.ones(B, dtype=jnp.float32)
        ret = jnp.zeros((B, 2), dtype=jnp.float32)
        args = (params, jnp.zeros(P), jnp.zeros(P), jnp.zeros(1), x, a, mask, logp, adv, ret)
        out1 = M.ppo_update_thermos(*args)
        out2 = M.ppo_update_thermos(*args)
        for o1, o2 in zip(out1, out2):
            assert jnp.array_equal(o1, o2)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
