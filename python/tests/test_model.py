"""L2 correctness: PPO losses, masking, Adam, and update-step behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def make_batch(key, batch=32):
    ks = jax.random.split(key, 8)
    x = jax.random.normal(ks[0], (batch, M.STATE_DIM), dtype=jnp.float32)
    actions = jax.random.randint(ks[1], (batch,), 0, M.NUM_CLUSTERS)
    a_onehot = jax.nn.one_hot(actions, M.NUM_CLUSTERS, dtype=jnp.float32)
    mask = jnp.ones((batch, M.NUM_CLUSTERS), dtype=jnp.float32)
    adv = jax.random.normal(ks[2], (batch,), dtype=jnp.float32)
    ret = jax.random.normal(ks[3], (batch, 2), dtype=jnp.float32)
    return x, a_onehot, mask, adv, ret


def init_params(key):
    k1, k2 = jax.random.split(key)
    theta = M.init_ddt(k1)
    phi = M.init_mlp(k2, M.CRITIC_DIMS)
    return jnp.concatenate([theta, phi])


class TestMaskedLogSoftmax:
    def test_invalid_actions_get_tiny_probability(self):
        logits = jnp.array([[1.0, 2.0, 3.0, 4.0]])
        mask = jnp.array([[1.0, 0.0, 1.0, 0.0]])
        lp = M.masked_log_softmax(logits, mask)
        probs = np.asarray(jnp.exp(lp))[0]
        assert probs[1] < 1e-8 and probs[3] < 1e-8
        assert abs(probs.sum() - 1.0) < 1e-5

    def test_all_valid_is_plain_softmax(self):
        logits = jnp.array([[0.5, -1.0, 2.0, 0.0]])
        mask = jnp.ones((1, 4))
        lp = M.masked_log_softmax(logits, mask)
        want = jax.nn.log_softmax(logits, axis=-1)
        np.testing.assert_allclose(np.asarray(lp), np.asarray(want), rtol=1e-6)


class TestPpoUpdate:
    def test_update_shapes_and_finiteness(self):
        params = init_params(jax.random.PRNGKey(0))
        P = params.shape[0]
        m = jnp.zeros(P)
        v = jnp.zeros(P)
        t = jnp.zeros(1)
        x, a, mask, adv, ret = make_batch(jax.random.PRNGKey(1), M.UPDATE_BATCH)
        logits = M.thermos_actor_fwd(params[: M.THETA_LEN], x)
        logp_old = jnp.sum(M.masked_log_softmax(logits, mask) * a, axis=-1)
        out = M.ppo_update_thermos(params, m, v, t, x, a, mask, logp_old, adv, ret)
        p2, m2, v2, t2, pl_, vl, ent = out
        assert p2.shape == (P,)
        assert float(t2[0]) == 1.0
        for arr in out:
            assert np.isfinite(np.asarray(arr)).all()
        assert float(ent) > 0.0
        # Parameters actually moved.
        assert float(jnp.abs(p2 - params).max()) > 0.0

    def test_value_loss_decreases_over_steps(self):
        # With zero advantage the update trains only the critic; the value
        # loss on a fixed batch must fall.
        params = init_params(jax.random.PRNGKey(2))
        P = params.shape[0]
        m = jnp.zeros(P)
        v = jnp.zeros(P)
        t = jnp.zeros(1)
        x, a, mask, _, ret = make_batch(jax.random.PRNGKey(3), M.UPDATE_BATCH)
        adv = jnp.zeros(M.UPDATE_BATCH)
        logits = M.thermos_actor_fwd(params[: M.THETA_LEN], x)
        logp_old = jnp.sum(M.masked_log_softmax(logits, mask) * a, axis=-1)
        first_vl = None
        last_vl = None
        for i in range(30):
            params, m, v, t, pl_, vl, ent = M.ppo_update_thermos(
                params, m, v, t, x, a, mask, logp_old, adv, ret
            )
            if i == 0:
                first_vl = float(vl)
            last_vl = float(vl)
        assert last_vl < first_vl * 0.9, f"{first_vl} -> {last_vl}"

    def test_positive_advantage_raises_action_probability(self):
        # Single repeated state, always action 2 with positive advantage:
        # after a few updates pi(2|s) must increase.
        params = init_params(jax.random.PRNGKey(4))
        P = params.shape[0]
        m = jnp.zeros(P)
        v = jnp.zeros(P)
        t = jnp.zeros(1)
        x = jnp.tile(
            jax.random.normal(jax.random.PRNGKey(5), (1, M.STATE_DIM)), (M.UPDATE_BATCH, 1)
        ).astype(jnp.float32)
        a = jnp.tile(jax.nn.one_hot(jnp.array([2]), 4), (M.UPDATE_BATCH, 1)).astype(jnp.float32)
        mask = jnp.ones((M.UPDATE_BATCH, 4), dtype=jnp.float32)
        adv = jnp.ones(M.UPDATE_BATCH)
        ret = jnp.zeros((M.UPDATE_BATCH, 2))

        def prob2(p):
            logits = M.thermos_actor_fwd(p[: M.THETA_LEN], x[:1])
            return float(jnp.exp(M.masked_log_softmax(logits, mask[:1]))[0, 2])

        p_before = prob2(params)
        logits = M.thermos_actor_fwd(params[: M.THETA_LEN], x)
        logp_old = jnp.sum(M.masked_log_softmax(logits, mask) * a, axis=-1)
        for _ in range(20):
            params, m, v, t, *_ = M.ppo_update_thermos(
                params, m, v, t, x, a, mask, logp_old, adv, ret
            )
        p_after = prob2(params)
        assert p_after > p_before, f"{p_before} -> {p_after}"

    def test_relmas_update_runs(self):
        k = jax.random.PRNGKey(6)
        k1, k2, k3 = jax.random.split(k, 3)
        theta = M.init_mlp(k1, M.RELMAS_ACTOR_DIMS)
        phi = M.init_mlp(k2, M.RELMAS_CRITIC_DIMS)
        params = jnp.concatenate([theta, phi])
        P = params.shape[0]
        B = M.UPDATE_BATCH
        x = jax.random.normal(k3, (B, M.RELMAS_OBS), dtype=jnp.float32)
        actions = jax.random.randint(jax.random.PRNGKey(7), (B,), 0, M.NUM_CHIPLETS)
        a = jax.nn.one_hot(actions, M.NUM_CHIPLETS, dtype=jnp.float32)
        mask = jnp.ones((B, M.NUM_CHIPLETS), dtype=jnp.float32)
        logits = M.relmas_actor_fwd(theta, x)
        logp_old = jnp.sum(M.masked_log_softmax(logits, mask) * a, axis=-1)
        adv = jnp.ones(B)
        ret = jnp.zeros((B, 1))
        out = M.ppo_update_relmas(
            params, jnp.zeros(P), jnp.zeros(P), jnp.zeros(1), x, a, mask, logp_old, adv, ret
        )
        assert out[0].shape == (P,)
        for arr in out:
            assert np.isfinite(np.asarray(arr)).all()


class TestAdam:
    def test_adam_converges_on_quadratic(self):
        # Minimize ||p - target||^2 with the module's _adam.
        target = jnp.array([1.0, -2.0, 3.0])
        p = jnp.zeros(3)
        m = jnp.zeros(3)
        v = jnp.zeros(3)
        for t in range(1, 12001):
            g = 2.0 * (p - target)
            p, m, v = M._adam(p, g, m, v, float(t))
        np.testing.assert_allclose(np.asarray(p), np.asarray(target), atol=1e-2)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
