"""Pure-jnp oracles for the Pallas kernels — the CORE correctness anchor.

``ddt_forward_ref`` / ``mlp_forward_ref`` implement the identical math
with plain jax.numpy. pytest (python/tests) asserts allclose between the
Pallas kernels and these references across shape/dtype sweeps (hypothesis),
and the rust integration tests assert the native rust evaluators match the
AOT artifacts built from the kernels — closing the loop
ref == pallas == artifact == native-rust.

These reference functions are also what the PPO update graph
(compile/model.py) differentiates through: Pallas interpret-mode kernels
do not define VJPs, and the update graph is a build-time artifact where
XLA fuses the jnp ops anyway (DESIGN.md 8, L2).
"""

import jax
import jax.numpy as jnp

from . import ddt as ddt_mod


def ddt_forward_ref(theta, x, *, state_dim: int, num_actions: int):
    """Soft decision tree forward, vectorized jnp. x: (B, D) -> (B, A)."""
    w, b, beta, leaves = ddt_mod.unpack(theta, state_dim, num_actions)
    z = jax.nn.sigmoid(beta[None, :] * (x @ w.T + b[None, :]))  # (B, 31)
    probs = [None] * (2 * ddt_mod.INTERNAL + 1)
    probs[0] = jnp.ones(x.shape[0], dtype=x.dtype)
    for j in range(ddt_mod.INTERNAL):
        probs[2 * j + 1] = probs[j] * z[:, j]
        probs[2 * j + 2] = probs[j] * (1.0 - z[:, j])
    leaf_probs = jnp.stack(probs[ddt_mod.INTERNAL :], axis=1)  # (B, 32)
    return leaf_probs @ leaves


def mlp_forward_ref(params, x, *, dims):
    """ReLU MLP forward, plain jnp. x: (B, dims[0]) -> (B, dims[-1])."""
    from . import mlp as mlp_mod

    act = x
    layers = mlp_mod.unpack(params, tuple(dims))
    for li, (w, b) in enumerate(layers):
        act = act @ w.T + b[None, :]
        if li < len(layers) - 1:
            act = jnp.maximum(act, 0.0)
    return act
