"""Layer-1 Pallas kernel: soft differentiable-decision-tree forward pass.

This is the compute hot-spot of the THERMOS request path: every Level-1
scheduling decision evaluates the DDT policy (paper 4.3.1, Fig. 3a).
The whole forward — node linear projections, sigmoid routing, static
path-product over the 32 leaves, and the leaf-logit mixture — runs as one
fused Pallas kernel so parameters and activations make a single trip
through VMEM (DESIGN.md 8: ~3.5 KB of parameters + B x 22 activations per
tile; no HBM round-trips between stages).

Hardware adaptation: the paper benchmarks its policy on a Jetson; on TPU
the natural mapping is one VMEM-resident tile per batch block with the
(B,22)x(22,31) projection feeding the MXU. ``interpret=True`` everywhere —
the CPU PJRT plugin cannot execute Mosaic custom-calls, and interpret-mode
lowering emits plain HLO that the rust runtime executes byte-for-byte like
any other fusion.

Parameter layout matches ``rust/src/sched/policy.rs::NativeDdt`` and is
pinned in ``artifacts/abi.json``:
    [w: 31x22 | b: 31 | beta: 31 | leaves: 32x4]  (row-major, f32)
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEPTH = 5
INTERNAL = (1 << DEPTH) - 1  # 31
LEAVES = 1 << DEPTH  # 32


def theta_len(state_dim: int, num_actions: int) -> int:
    """Flat parameter length (must equal rust's ddt_theta_len)."""
    return INTERNAL * (state_dim + 2) + LEAVES * num_actions


def unpack(theta, state_dim: int, num_actions: int):
    """Split a flat theta into (w, b, beta, leaves)."""
    wlen = INTERNAL * state_dim
    w = theta[:wlen].reshape(INTERNAL, state_dim)
    b = theta[wlen : wlen + INTERNAL]
    beta = theta[wlen + INTERNAL : wlen + 2 * INTERNAL]
    leaves = theta[wlen + 2 * INTERNAL :].reshape(LEAVES, num_actions)
    return w, b, beta, leaves


def _ddt_kernel(x_ref, w_ref, b_ref, beta_ref, leaves_ref, o_ref):
    """One batch tile: (B_t, D) -> (B_t, A) leaf-mixture logits."""
    x = x_ref[...]  # (B_t, D)
    w = w_ref[...]  # (INTERNAL, D)
    b = b_ref[...]  # (INTERNAL,)
    beta = beta_ref[...]
    leaves = leaves_ref[...]  # (LEAVES, A)

    # Node activations sigma(beta (w.x + b)): one (B,D)x(D,31) matmul —
    # the MXU-bound op of the kernel.
    z = jax.nn.sigmoid(beta[None, :] * (jnp.dot(x, w.T) + b[None, :]))

    # Static heap-indexed path products (children of j are 2j+1 / 2j+2).
    # The tree is tiny and fixed-depth, so the product tree is unrolled at
    # trace time: probs[k] has shape (B_t,).
    probs = [None] * (2 * INTERNAL + 1)
    probs[0] = jnp.ones(x.shape[0], dtype=x.dtype)
    for j in range(INTERNAL):
        probs[2 * j + 1] = probs[j] * z[:, j]
        probs[2 * j + 2] = probs[j] * (1.0 - z[:, j])
    leaf_probs = jnp.stack(probs[INTERNAL:], axis=1)  # (B_t, LEAVES)

    # Mixture of leaf logit rows.
    o_ref[...] = jnp.dot(leaf_probs, leaves)


@functools.partial(jax.jit, static_argnames=("state_dim", "num_actions", "block_b"))
def ddt_forward(theta, x, *, state_dim: int, num_actions: int, block_b: int = 128):
    """Pallas DDT forward: theta[theta_len], x[B, state_dim] -> [B, actions].

    The batch is tiled into ``block_b``-row VMEM blocks; parameters are
    broadcast to every grid step (index_map pins them to block 0).
    """
    w, b, beta, leaves = unpack(theta, state_dim, num_actions)
    bsz = x.shape[0]
    if bsz <= block_b:
        # Single tile: no grid.
        return pl.pallas_call(
            _ddt_kernel,
            out_shape=jax.ShapeDtypeStruct((bsz, num_actions), x.dtype),
            interpret=True,
        )(x, w, b, beta, leaves)
    assert bsz % block_b == 0, f"batch {bsz} must be a multiple of {block_b}"
    grid = (bsz // block_b,)
    return pl.pallas_call(
        _ddt_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, state_dim), lambda i: (i, 0)),
            pl.BlockSpec((INTERNAL, state_dim), lambda i: (0, 0)),
            pl.BlockSpec((INTERNAL,), lambda i: (0,)),
            pl.BlockSpec((INTERNAL,), lambda i: (0,)),
            pl.BlockSpec((LEAVES, num_actions), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, num_actions), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, num_actions), x.dtype),
        interpret=True,
    )(x, w, b, beta, leaves)
