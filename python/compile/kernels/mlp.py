"""Layer-1 Pallas kernel: fused ReLU-MLP forward.

Used for the vector-valued critic V_phi(s, omega) (22->64->64->64->2) and
the RELMAS baseline's flat actor/critic. All layers execute inside one
kernel so the (tiny) weight set stays VMEM-resident across layers instead
of bouncing to HBM between matmuls; batch tiled like the DDT kernel.

Parameter layout matches ``rust/src/sched/policy.rs::NativeMlp``:
per layer ``W (out x in, row-major) | b (out)``, concatenated; pinned in
``artifacts/abi.json``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def param_len(dims) -> int:
    return sum(i * o + o for i, o in zip(dims[:-1], dims[1:]))


def unpack(params, dims):
    """Split flat params into [(W, b), ...]."""
    out = []
    off = 0
    for fin, fout in zip(dims[:-1], dims[1:]):
        w = params[off : off + fin * fout].reshape(fout, fin)
        off += fin * fout
        b = params[off : off + fout]
        off += fout
        out.append((w, b))
    return out


def _make_kernel(num_layers):
    def kernel(x_ref, *refs):
        o_ref = refs[-1]
        act = x_ref[...]
        for li in range(num_layers):
            w = refs[2 * li][...]
            b = refs[2 * li + 1][...]
            act = jnp.dot(act, w.T) + b[None, :]
            if li < num_layers - 1:
                act = jnp.maximum(act, 0.0)
        o_ref[...] = act

    return kernel


@functools.partial(jax.jit, static_argnames=("dims", "block_b"))
def mlp_forward(params, x, *, dims, block_b: int = 128):
    """Pallas MLP forward: params[param_len(dims)], x[B, dims[0]] -> [B, dims[-1]]."""
    dims = tuple(dims)
    layers = unpack(params, dims)
    flat = []
    for w, b in layers:
        flat.extend((w, b))
    kernel = _make_kernel(len(layers))
    bsz = x.shape[0]
    out_dim = dims[-1]
    if bsz <= block_b:
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct((bsz, out_dim), x.dtype),
            interpret=True,
        )(x, *flat)
    assert bsz % block_b == 0, f"batch {bsz} must be a multiple of {block_b}"
    in_specs = [pl.BlockSpec((block_b, dims[0]), lambda i: (i, 0))]
    for w, b in layers:
        in_specs.append(pl.BlockSpec(w.shape, lambda i: (0, 0)))
        in_specs.append(pl.BlockSpec(b.shape, lambda i: (0,)))
    return pl.pallas_call(
        kernel,
        grid=(bsz // block_b,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_b, out_dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, out_dim), x.dtype),
        interpret=True,
    )(x, *flat)
