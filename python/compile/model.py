"""Layer-2: the MORL actor-critic and the vectorized PPO update (4.3).

Everything here is build-time jax that gets lowered once to HLO text by
``aot.py``; the rust coordinator then drives the artifacts through PJRT.

* Actor pi_theta(a|s, omega): soft DDT (kernels/ddt.py at inference;
  the differentiable jnp reference inside the update graph).
* Critic V_phi(s, omega): vector-valued MLP (one value per objective) —
  Eq. 3's vectorized advantage needs a (B, 2) value head.
* Update: PPO clip loss on the omega-scalarized advantage (Eq. 4), MSE
  vector critic loss (Eq. 5), entropy bonus, invalid-action masking
  (-1e7 pre-softmax, 4.2.2), and Adam — one fused jitted step over a
  fixed-size minibatch so the whole optimizer is a single artifact.

Parameter vectors are FLAT f32 arrays whose layout matches the rust
native evaluators; Adam state is a flat pair (m, v) over the
concatenation [theta | phi]. Hyperparameters (Table 4): lr 5e-4,
clip 0.1, gamma 0.95 — gamma lives in the rust GAE, not here.
"""

import jax
import jax.numpy as jnp

from .kernels import ddt as ddt_mod
from .kernels import mlp as mlp_mod
from .kernels.ref import ddt_forward_ref, mlp_forward_ref

# ---- dimensions (single source of truth; exported into abi.json) -------
STATE_DIM = 22
NUM_CLUSTERS = 4
CRITIC_DIMS = (STATE_DIM, 64, 64, 64, 2)
THETA_LEN = ddt_mod.theta_len(STATE_DIM, NUM_CLUSTERS)  # 872
PHI_LEN = mlp_mod.param_len(CRITIC_DIMS)  # 9922
UPDATE_BATCH = 256

# RELMAS baseline (flat chiplet-level policy) for the 78-chiplet system.
NUM_CHIPLETS = 78
RELMAS_OBS = 2 * NUM_CHIPLETS + 12  # 168
RELMAS_ACTOR_DIMS = (RELMAS_OBS, 128, 128, NUM_CHIPLETS)
RELMAS_CRITIC_DIMS = (RELMAS_OBS, 128, 128, 1)
RELMAS_THETA_LEN = mlp_mod.param_len(RELMAS_ACTOR_DIMS)
RELMAS_PHI_LEN = mlp_mod.param_len(RELMAS_CRITIC_DIMS)

# PPO hyperparameters (Table 4 + standard PPO auxiliaries).
LR = 5.0e-4
CLIP_EPS = 0.1
VALUE_COEF = 0.5
ENTROPY_COEF = 0.01
MASK_NEG = -1.0e7
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1.0e-8


# ---- inference graphs (these call the L1 Pallas kernels) ----------------

def policy_logits_pallas(theta, x):
    """DDT actor forward via the Pallas kernel. x: (B, 22) -> (B, 4)."""
    return ddt_mod.ddt_forward(
        theta, x, state_dim=STATE_DIM, num_actions=NUM_CLUSTERS
    )


def critic_values_pallas(phi, x):
    """Vector critic forward via the Pallas MLP kernel: (B, 22) -> (B, 2)."""
    return mlp_mod.mlp_forward(phi, x, dims=CRITIC_DIMS)


def relmas_logits_pallas(theta, x):
    """RELMAS flat actor: (B, 168) -> (B, 78)."""
    return mlp_mod.mlp_forward(theta, x, dims=RELMAS_ACTOR_DIMS)


def relmas_values_pallas(phi, x):
    return mlp_mod.mlp_forward(phi, x, dims=RELMAS_CRITIC_DIMS)


# ---- shared PPO machinery ------------------------------------------------

def masked_log_softmax(logits, mask):
    """Invalid-action masking (4.2.2): -1e7 added pre-softmax."""
    masked = logits + (1.0 - mask) * MASK_NEG
    return jax.nn.log_softmax(masked, axis=-1)


def _adam(params, grads, m, v, t):
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * grads
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * grads * grads
    mhat = m / (1.0 - ADAM_B1**t)
    vhat = v / (1.0 - ADAM_B2**t)
    params = params - LR * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return params, m, v


def _ppo_losses(logits, mask, a_onehot, logp_old, adv, values, ret):
    """Clip loss (Eq. 4) on scalarized advantage + vector MSE (Eq. 5)."""
    logp_all = masked_log_softmax(logits, mask)
    logp = jnp.sum(logp_all * a_onehot, axis=-1)
    ratio = jnp.exp(logp - logp_old)
    clipped = jnp.clip(ratio, 1.0 - CLIP_EPS, 1.0 + CLIP_EPS)
    policy_loss = -jnp.mean(jnp.minimum(ratio * adv, clipped * adv))
    # Entropy over the *valid* actions only.
    probs = jnp.exp(logp_all)
    entropy = -jnp.mean(jnp.sum(probs * logp_all * mask, axis=-1))
    value_loss = jnp.mean(jnp.sum((values - ret) ** 2, axis=-1))
    return policy_loss, value_loss, entropy


def make_ppo_update(actor_fwd, critic_fwd, theta_len, phi_len):
    """Build a fused PPO+Adam step over flat [theta | phi] parameters.

    Returns fn(params, m, v, t, x, a_onehot, mask, logp_old, adv, ret) ->
    (params', m', v', t', policy_loss, value_loss, entropy).
    `adv` is the omega-scalarized advantage (omega^T A, Eq. 4) computed by
    the rust GAE; `ret` is the vector TD(lambda) return target (Eq. 5).
    The preference omega rides inside the state x (4.2.1), so a single
    update graph trains the single preference-conditioned policy.
    """
    del phi_len  # implied by params length; kept for call-site clarity

    def loss_fn(params, x, a_onehot, mask, logp_old, adv, ret):
        theta = params[:theta_len]
        phi = params[theta_len:]
        logits = actor_fwd(theta, x)
        values = critic_fwd(phi, x)
        pl_, vl, ent = _ppo_losses(logits, mask, a_onehot, logp_old, adv, values, ret)
        total = pl_ + VALUE_COEF * vl - ENTROPY_COEF * ent
        return total, (pl_, vl, ent)

    def update(params, m, v, t, x, a_onehot, mask, logp_old, adv, ret):
        grad_fn = jax.grad(loss_fn, has_aux=True)
        grads, (pl_, vl, ent) = grad_fn(params, x, a_onehot, mask, logp_old, adv, ret)
        t = t + 1.0
        params, m, v = _adam(params, grads, m, v, t[0])
        return params, m, v, t, pl_, vl, ent

    return update


# ---- the two concrete update graphs -------------------------------------

def thermos_actor_fwd(theta, x):
    return ddt_forward_ref(theta, x, state_dim=STATE_DIM, num_actions=NUM_CLUSTERS)


def thermos_critic_fwd(phi, x):
    return mlp_forward_ref(phi, x, dims=CRITIC_DIMS)


def relmas_actor_fwd(theta, x):
    return mlp_forward_ref(theta, x, dims=RELMAS_ACTOR_DIMS)


def relmas_critic_fwd(phi, x):
    return mlp_forward_ref(phi, x, dims=RELMAS_CRITIC_DIMS)


ppo_update_thermos = make_ppo_update(
    thermos_actor_fwd, thermos_critic_fwd, THETA_LEN, PHI_LEN
)
ppo_update_relmas = make_ppo_update(
    relmas_actor_fwd, relmas_critic_fwd, RELMAS_THETA_LEN, RELMAS_PHI_LEN
)


# ---- reference init (mirrors rust NativeDdt::init / NativeMlp::init) ----

def init_ddt(key):
    """Xavier-ish DDT init: w ~ N(0, 1/D), b = 0, beta = 1, leaves ~ 0.1 N."""
    kw, kl = jax.random.split(key)
    wlen = ddt_mod.INTERNAL * STATE_DIM
    w = jax.random.normal(kw, (wlen,)) / jnp.sqrt(STATE_DIM)
    b = jnp.zeros(ddt_mod.INTERNAL)
    beta = jnp.ones(ddt_mod.INTERNAL)
    leaves = 0.1 * jax.random.normal(kl, (ddt_mod.LEAVES * NUM_CLUSTERS,))
    return jnp.concatenate([w, b, beta, leaves]).astype(jnp.float32)


def init_mlp(key, dims):
    """He init, zero biases, flat layout."""
    parts = []
    for fin, fout in zip(dims[:-1], dims[1:]):
        key, kw = jax.random.split(key)
        w = jax.random.normal(kw, (fout * fin,)) * jnp.sqrt(2.0 / fin)
        parts.append(w)
        parts.append(jnp.zeros(fout))
    return jnp.concatenate(parts).astype(jnp.float32)
