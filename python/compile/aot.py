"""AOT exporter: lower every jax graph to HLO TEXT artifacts + abi.json.

Run once at build time (``make artifacts``); the rust runtime
(rust/src/runtime) loads the text through
``HloModuleProto::from_text_file`` and executes via the PJRT CPU client.

HLO *text* is the interchange format, NOT serialized protos: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(fn, *example_args) -> str:
    """jit -> lower -> stablehlo -> XlaComputation -> HLO text."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def artifact_specs():
    """Every artifact: name -> (fn, example args, io description)."""
    B = M.UPDATE_BATCH
    P_T = M.THETA_LEN + M.PHI_LEN
    P_R = M.RELMAS_THETA_LEN + M.RELMAS_PHI_LEN

    def tup(fn):
        # Multi-output graphs already return tuples; single outputs are
        # wrapped so every artifact uniformly returns a tuple.
        def wrapped(*a):
            out = fn(*a)
            return out if isinstance(out, tuple) else (out,)

        return wrapped

    arts = {
        # Hot-path policy inference (B=1) — the Pallas DDT kernel.
        "ddt_policy": (
            tup(M.policy_logits_pallas),
            [spec(M.THETA_LEN), spec(1, M.STATE_DIM)],
            {"inputs": ["theta", "x[1,22]"], "outputs": ["logits[1,4]"]},
        ),
        # Batched policy forward (training-time evaluation + tests).
        "ddt_policy_b256": (
            tup(M.policy_logits_pallas),
            [spec(M.THETA_LEN), spec(B, M.STATE_DIM)],
            {"inputs": ["theta", f"x[{B},22]"], "outputs": [f"logits[{B},4]"]},
        ),
        # Vector critic (GAE values) — Pallas MLP kernel.
        "critic_b256": (
            tup(M.critic_values_pallas),
            [spec(M.PHI_LEN), spec(B, M.STATE_DIM)],
            {"inputs": ["phi", f"x[{B},22]"], "outputs": [f"v[{B},2]"]},
        ),
        # Fused PPO + Adam update for the THERMOS actor-critic.
        "ppo_update_thermos": (
            M.ppo_update_thermos,
            [
                spec(P_T),  # params [theta|phi]
                spec(P_T),  # adam m
                spec(P_T),  # adam v
                spec(1),  # t
                spec(B, M.STATE_DIM),
                spec(B, M.NUM_CLUSTERS),  # a_onehot
                spec(B, M.NUM_CLUSTERS),  # mask
                spec(B),  # logp_old
                spec(B),  # adv (omega-scalarized)
                spec(B, 2),  # vector returns
            ],
            {
                "inputs": [
                    "params", "m", "v", "t", "x", "a_onehot", "mask",
                    "logp_old", "adv", "ret",
                ],
                "outputs": ["params", "m", "v", "t", "policy_loss", "value_loss", "entropy"],
            },
        ),
        # RELMAS baseline: flat actor inference + its update graph.
        "relmas_policy": (
            tup(M.relmas_logits_pallas),
            [spec(M.RELMAS_THETA_LEN), spec(1, M.RELMAS_OBS)],
            {"inputs": ["thetaR", "x[1,168]"], "outputs": ["logits[1,78]"]},
        ),
        "relmas_critic_b256": (
            tup(M.relmas_values_pallas),
            [spec(M.RELMAS_PHI_LEN), spec(B, M.RELMAS_OBS)],
            {"inputs": ["phiR", f"x[{B},168]"], "outputs": [f"v[{B},1]"]},
        ),
        "ppo_update_relmas": (
            M.ppo_update_relmas,
            [
                spec(P_R),
                spec(P_R),
                spec(P_R),
                spec(1),
                spec(B, M.RELMAS_OBS),
                spec(B, M.NUM_CHIPLETS),
                spec(B, M.NUM_CHIPLETS),
                spec(B),
                spec(B),
                spec(B, 1),
            ],
            {
                "inputs": [
                    "params", "m", "v", "t", "x", "a_onehot", "mask",
                    "logp_old", "adv", "ret",
                ],
                "outputs": ["params", "m", "v", "t", "policy_loss", "value_loss", "entropy"],
            },
        ),
    }
    return arts


def abi() -> dict:
    """Dimension/layout contract consumed by rust/src/runtime/abi.rs."""
    return {
        "version": 1,
        "state_dim": M.STATE_DIM,
        "num_clusters": M.NUM_CLUSTERS,
        "ddt_depth": 5,
        "ddt_internal": 31,
        "ddt_leaves": 32,
        "theta_len": M.THETA_LEN,
        "phi_len": M.PHI_LEN,
        "critic_dims": list(M.CRITIC_DIMS),
        "update_batch": M.UPDATE_BATCH,
        "num_chiplets": M.NUM_CHIPLETS,
        "relmas_obs": M.RELMAS_OBS,
        "relmas_actor_dims": list(M.RELMAS_ACTOR_DIMS),
        "relmas_critic_dims": list(M.RELMAS_CRITIC_DIMS),
        "relmas_theta_len": M.RELMAS_THETA_LEN,
        "relmas_phi_len": M.RELMAS_PHI_LEN,
        "lr": M.LR,
        "clip_eps": M.CLIP_EPS,
        "value_coef": M.VALUE_COEF,
        "entropy_coef": M.ENTROPY_COEF,
        "mask_neg": M.MASK_NEG,
        "theta_layout": "w[31*22] | b[31] | beta[31] | leaves[32*4] (row-major f32)",
        "mlp_layout": "per layer: W[out*in] row-major | b[out]",
        "params_layout": "[theta | phi]",
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    arts = artifact_specs()
    selected = set(args.only.split(",")) if args.only else set(arts)
    manifest = {"abi": abi(), "artifacts": {}}
    for name, (fn, specs, io) in arts.items():
        if name not in selected:
            continue
        text = to_hlo_text(fn, *specs)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "bytes": len(text),
            **io,
        }
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "abi.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out_dir, 'abi.json')}")


if __name__ == "__main__":
    main()
